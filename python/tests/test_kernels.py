"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and seeds; every case asserts allclose.  This is the
core correctness signal for the serving-path artifacts: the HLO the rust
runtime executes is lowered from exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.common import DEFAULT_CONFIG, ModelConfig, init_block_params, \
    init_head_params, init_embed_params
from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.exit_head import exit_head
from compile.kernels.ffn import ffn

TOL = dict(rtol=2e-5, atol=2e-5)


def _x(seed: int, b: int, t: int, d: int) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed), (b, t, d), jnp.float32)


def _cfg(t: int, d: int, heads: int, ff: int) -> ModelConfig:
    return ModelConfig(seq_len=t, d_model=d, n_heads=heads, d_ff=ff)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 2, 5, 8]),
    t=st.sampled_from([4, 16, 32]),
    heads=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, t, heads, seed):
    d = 16 * heads
    cfg = _cfg(t, d, heads, 2 * d)
    p = init_block_params(jax.random.PRNGKey(seed), cfg)
    x = _x(seed ^ 0x5A5A, b, t, d)
    got = attention(x, p, heads)
    want = ref.attention_ref(x, p, heads)
    np.testing.assert_allclose(got, want, **TOL)


def test_attention_residual_identity_weights():
    """With zero projection output weights the block must be the identity."""
    cfg = DEFAULT_CONFIG
    p = init_block_params(jax.random.PRNGKey(0), cfg)
    p = dict(p)
    p["wo"] = jnp.zeros_like(p["wo"])
    p["bo"] = jnp.zeros_like(p["bo"])
    x = _x(3, 2, cfg.seq_len, cfg.d_model)
    np.testing.assert_allclose(attention(x, p, cfg.n_heads), x, **TOL)


def test_attention_default_config_shape():
    cfg = DEFAULT_CONFIG
    p = init_block_params(jax.random.PRNGKey(1), cfg)
    x = _x(7, 8, cfg.seq_len, cfg.d_model)
    out = attention(x, p, cfg.n_heads)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 3, 8]),
    t=st.sampled_from([4, 32]),
    d=st.sampled_from([16, 64]),
    ff=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(b, t, d, ff, seed):
    cfg = _cfg(t, d, 4, ff)
    p = init_block_params(jax.random.PRNGKey(seed), cfg)
    x = _x(seed ^ 0xC3C3, b, t, d)
    np.testing.assert_allclose(ffn(x, p), ref.ffn_ref(x, p), **TOL)


def test_ffn_residual_identity_weights():
    cfg = DEFAULT_CONFIG
    p = dict(init_block_params(jax.random.PRNGKey(0), cfg))
    p["w2"] = jnp.zeros_like(p["w2"])
    p["b2"] = jnp.zeros_like(p["b2"])
    x = _x(5, 2, cfg.seq_len, cfg.d_model)
    np.testing.assert_allclose(ffn(x, p), x, **TOL)


# ---------------------------------------------------------------------------
# exit head
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 4, 8]),
    c=st.sampled_from([2, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exit_head_matches_ref(b, c, seed):
    cfg = DEFAULT_CONFIG
    p = init_head_params(jax.random.PRNGKey(seed), cfg, c)
    x = _x(seed ^ 0x0F0F, b, cfg.seq_len, cfg.d_model)
    got = exit_head(x, p)
    want = ref.exit_head_ref(x, p)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, **TOL)


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 8]), c=st.sampled_from([2, 3]),
       seed=st.integers(0, 2**31 - 1))
def test_exit_head_invariants(b, c, seed):
    """probs on the simplex; conf = max prob; entropy within [0, ln C]."""
    cfg = DEFAULT_CONFIG
    p = init_head_params(jax.random.PRNGKey(seed), cfg, c)
    x = _x(seed, b, cfg.seq_len, cfg.d_model)
    probs, conf, ent = exit_head(x, p)
    np.testing.assert_allclose(jnp.sum(probs, axis=-1), jnp.ones(b), **TOL)
    assert bool(jnp.all(probs >= 0))
    np.testing.assert_allclose(conf, jnp.max(probs, axis=-1), **TOL)
    assert bool(jnp.all(ent >= -1e-6))
    assert bool(jnp.all(ent <= np.log(c) + 1e-5))


def test_exit_head_uses_cls_token_only():
    """Changing non-CLS positions must not change the head output."""
    cfg = DEFAULT_CONFIG
    p = init_head_params(jax.random.PRNGKey(2), cfg, 2)
    x = _x(11, 4, cfg.seq_len, cfg.d_model)
    y = x.at[:, 1:, :].set(0.0)
    got_x = exit_head(x, p)
    got_y = exit_head(y, p)
    for g, h in zip(got_x, got_y):
        np.testing.assert_allclose(g, h, **TOL)


# ---------------------------------------------------------------------------
# layer norm oracle sanity
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_layernorm_zero_mean_unit_var(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64), jnp.float32) * 5 + 3
    g = jnp.ones((64,))
    b = jnp.zeros((64,))
    y = ref.layer_norm(x, g, b)
    np.testing.assert_allclose(jnp.mean(y, axis=-1), jnp.zeros(4), atol=1e-5)
    np.testing.assert_allclose(
        jnp.var(y, axis=-1), jnp.ones(4), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def test_embed_shapes_and_determinism():
    cfg = DEFAULT_CONFIG
    p = init_embed_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((2, cfg.seq_len), jnp.int32)
    h = ref.embed_ref(tok, p)
    assert h.shape == (2, cfg.seq_len, cfg.d_model)
    np.testing.assert_allclose(h[0], h[1], **TOL)  # same tokens -> same rows

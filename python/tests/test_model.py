"""L2 model invariants: pallas/ref agreement at model scope, shapes, and the
canonical flatten/unflatten round-trip the AOT artifacts depend on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import flat_arg_specs, flatten_args, unflatten_args
from compile.common import (BLOCK_PARAM_ORDER, DEFAULT_CONFIG, EMBED_PARAM_ORDER,
                            HEAD_PARAM_ORDER, init_model_params)
from compile.model import (block_fn, chain_fn, forward_all_exits,
                           forward_logits_all_exits)


@pytest.fixture(scope="module")
def params():
    return init_model_params(0, DEFAULT_CONFIG, 3)


@pytest.fixture(scope="module")
def tokens():
    key = jax.random.PRNGKey(9)
    return jax.random.randint(key, (4, DEFAULT_CONFIG.seq_len), 0,
                              DEFAULT_CONFIG.vocab, jnp.int32)


def test_forward_shapes(params, tokens):
    cfg = DEFAULT_CONFIG
    probs, conf, ent = forward_all_exits(params, tokens, cfg)
    assert probs.shape == (cfg.n_layers, 4, 3)
    assert conf.shape == (cfg.n_layers, 4)
    assert ent.shape == (cfg.n_layers, 4)


def test_pallas_path_matches_ref_path(params, tokens):
    """The full 12-layer pallas composition must agree with the jnp reference
    — this is what licenses using the ref path for the prefix_full artifact."""
    cfg = DEFAULT_CONFIG
    p_probs, p_conf, p_ent = forward_all_exits(params, tokens, cfg, use_pallas=True)
    r_probs, r_conf, r_ent = forward_all_exits(params, tokens, cfg, use_pallas=False)
    np.testing.assert_allclose(p_probs, r_probs, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(p_conf, r_conf, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(p_ent, r_ent, rtol=1e-4, atol=1e-4)


def test_probs_on_simplex(params, tokens):
    probs, conf, ent = forward_all_exits(params, tokens, DEFAULT_CONFIG)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=-1),
                               np.ones((DEFAULT_CONFIG.n_layers, 4)),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(conf) <= 1.0 + 1e-6)
    assert np.all(np.asarray(conf) >= 1.0 / 3 - 1e-6)  # max prob >= 1/C


def test_logits_match_probs(params, tokens):
    cfg = DEFAULT_CONFIG
    logits = forward_logits_all_exits(params, tokens, cfg)
    probs, _, _ = forward_all_exits(params, tokens, cfg)
    soft = jax.nn.softmax(logits, axis=-1)
    np.testing.assert_allclose(soft, probs, rtol=1e-5, atol=1e-5)


def test_flatten_unflatten_roundtrip(params):
    cfg = DEFAULT_CONFIG
    flat = flatten_args(params)
    rebuilt = unflatten_args(flat, cfg, 3)
    for k in EMBED_PARAM_ORDER:
        np.testing.assert_array_equal(rebuilt["embed"][k], params["embed"][k])
    for i in range(cfg.n_layers):
        for k in BLOCK_PARAM_ORDER:
            np.testing.assert_array_equal(rebuilt["blocks"][i][k],
                                          params["blocks"][i][k])
        for k in HEAD_PARAM_ORDER:
            np.testing.assert_array_equal(rebuilt["heads"][i][k],
                                          params["heads"][i][k])


def test_flat_arg_specs_match_flatten(params):
    cfg = DEFAULT_CONFIG
    flat = flatten_args(params)
    specs = flat_arg_specs(cfg, 3)
    assert len(flat) == len(specs)
    for a, s in zip(flat, specs):
        assert a.shape == s.shape, (a.shape, s.shape)
        assert a.dtype == s.dtype


def test_chain_fn_matches_iterated_blocks(params):
    """The *jitted* fused range module (what aot.py lowers as `chain{n}`)
    must be bit-identical to iterating the *jitted* single-block module
    (what the rust per-block path executes) — the python-side mirror of the
    rust integration suite's fused-vs-per-block bit-exactness property."""
    import functools
    cfg = DEFAULT_CONFIG
    key = jax.random.PRNGKey(3)
    h0 = jax.random.normal(key, (2, cfg.seq_len, cfg.d_model), jnp.float32)
    jit_block = jax.jit(functools.partial(block_fn, n_heads=cfg.n_heads,
                                          use_pallas=True))
    for start, n in [(0, 4), (2, 3), (0, cfg.n_layers)]:
        blocks = params["blocks"][start:start + n]
        flat = [blk[k] for blk in blocks for k in BLOCK_PARAM_ORDER]
        jit_chain = jax.jit(functools.partial(chain_fn, n_blocks=n,
                                              n_heads=cfg.n_heads,
                                              use_pallas=True))
        fused = jit_chain(h0, *flat)
        step = h0
        for blk in blocks:
            step = jit_block(step, *[blk[k] for k in BLOCK_PARAM_ORDER])
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(step),
                                      err_msg=f"range start={start} n={n}")


def test_deterministic_forward(params, tokens):
    cfg = DEFAULT_CONFIG
    a = forward_all_exits(params, tokens, cfg)
    b = forward_all_exits(params, tokens, cfg)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

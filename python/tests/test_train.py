"""Training-pipeline smoke + calibration correctness (fast settings)."""

import numpy as np
import pytest

from compile.common import DEFAULT_CONFIG
from compile.datagen import SPECS, DatasetSpec, DifficultyMix, generate
from compile.train import (adam_init, adam_update, calibrate_alpha,
                           calibrate_tau, eval_all_exits, joint_loss,
                           split_train_val, train_elasticbert, train_deebert,
                           _cascade_acc_conf, _cascade_acc_ent)

CFG = DEFAULT_CONFIG


@pytest.fixture(scope="module")
def tiny_data():
    spec = DatasetSpec("tiny", "sentiment", 2, 1200,
                       DifficultyMix(.5, .2, .1, .15, .05),
                       700, 950, 1.3, 7, "source")
    tokens, labels, _ = generate(spec, CFG.seq_len, CFG.vocab)
    return tokens, labels


@pytest.fixture(scope="module")
def trained(tiny_data):
    tokens, labels = tiny_data
    tr_t, tr_l, va_t, va_l = split_train_val(tokens, labels, 0)
    params = train_elasticbert(tr_t, tr_l, CFG, 2, 0, steps=50,
                               log=lambda *a: None)
    return params, va_t, va_l


def test_loss_decreases(tiny_data):
    import jax.numpy as jnp
    from compile.common import init_model_params
    tokens, labels = tiny_data
    params = init_model_params(0, CFG, 2)
    l0 = float(joint_loss(params, jnp.asarray(tokens[:64]),
                          jnp.asarray(labels[:64]), CFG))
    trained = train_elasticbert(tokens, labels, CFG, 2, 0, steps=40,
                                log=lambda *a: None)
    l1 = float(joint_loss(trained, jnp.asarray(tokens[:64]),
                          jnp.asarray(labels[:64]), CFG))
    assert l1 < l0 * 0.8, (l0, l1)


def test_eval_outputs(trained):
    params, va_t, va_l = trained
    acc, conf, ent, pred = eval_all_exits(params, va_t, va_l, CFG)
    L, N = conf.shape
    assert L == CFG.n_layers and N == len(va_l)
    assert acc.shape == (L,)
    assert np.all(acc >= 0) and np.all(acc <= 1)
    assert np.all(conf > 0) and np.all(conf <= 1 + 1e-6)
    assert np.all(ent >= -1e-6)
    # trained model must beat chance at the deepest exit
    assert acc[-1] > 0.6


def test_calibrated_alpha_preserves_accuracy(trained):
    params, va_t, va_l = trained
    acc, conf, ent, pred = eval_all_exits(params, va_t, va_l, CFG)
    alpha = calibrate_alpha(conf, pred, va_l)
    assert 0.5 <= alpha <= 0.98
    cascade = _cascade_acc_conf(conf, pred, va_l, alpha)
    assert cascade >= acc[-1] - 0.005 - 1e-9


def test_calibrated_tau_preserves_accuracy(trained):
    params, va_t, va_l = trained
    acc, conf, ent, pred = eval_all_exits(params, va_t, va_l, CFG)
    tau = calibrate_tau(ent, pred, va_l, 2)
    assert 0 < tau < np.log(2) + 1e-9
    cascade = _cascade_acc_ent(ent, pred, va_l, tau)
    assert cascade >= acc[-1] - 0.005 - 1e-9


def test_deebert_two_stage_runs(tiny_data):
    tokens, labels = tiny_data
    params = train_deebert(tokens[:600], labels[:600], CFG, 2, 0,
                           steps1=25, steps2=20, log=lambda *a: None)
    assert len(params["heads"]) == CFG.n_layers
    acc, *_ = eval_all_exits(params, tokens[600:900], labels[600:900], CFG)
    assert acc[-1] > 0.55  # stage-1 fine-tuning must beat chance


def test_adam_moves_params():
    import jax.numpy as jnp
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 2.0)}
    st = adam_init(params)
    new, st2 = adam_update(params, grads, st, lr=0.1)
    assert st2["t"] == 1
    assert np.all(np.asarray(new["w"]) < 1.0)


def test_split_train_val_disjoint_and_complete(tiny_data):
    tokens, labels = tiny_data
    tr_t, tr_l, va_t, va_l = split_train_val(tokens, labels, 3)
    assert len(tr_t) + len(va_t) == len(tokens)
    assert len(va_t) == int(len(tokens) * 0.15)
    # determinism
    tr_t2, *_ = split_train_val(tokens, labels, 3)
    np.testing.assert_array_equal(tr_t, tr_t2)

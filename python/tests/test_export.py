"""Binary export format round-trips (pure-python readers mirror the rust ones)."""

import struct
from pathlib import Path

import numpy as np
import pytest

from compile import export
from compile.common import DEFAULT_CONFIG, init_model_params


def read_weights(path: Path):
    """Python mirror of rust/src/model/weights.rs for round-trip testing."""
    out = {}
    with open(path, "rb") as f:
        magic, version, n = struct.unpack("<III", f.read(12))
        assert magic == export.WEIGHTS_MAGIC and version == export.VERSION
        for _ in range(n):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            numel = int(np.prod(dims)) if ndim else 1
            raw = f.read(numel * 4)
            np_dtype = np.float32 if dtype == export.DTYPE_F32 else np.int32
            out[name] = np.frombuffer(raw, np_dtype).reshape(dims)
        assert f.read() == b""
    return out


def read_dataset(path: Path):
    with open(path, "rb") as f:
        magic, version, n, t, c = struct.unpack("<IIIII", f.read(20))
        assert magic == export.DATA_MAGIC and version == export.VERSION
        tokens = np.frombuffer(f.read(4 * n * t), np.int32).reshape(n, t)
        labels = np.frombuffer(f.read(4 * n), np.int32)
        diff = np.frombuffer(f.read(4 * n), np.int32)
        assert f.read() == b""
    return tokens, labels, diff, c


def test_weights_roundtrip(tmp_path):
    params = init_model_params(0, DEFAULT_CONFIG, 2)
    tensors = export.flatten_params(params)
    path = tmp_path / "w.bin"
    export.write_weights(path, tensors)
    loaded = read_weights(path)
    assert len(loaded) == len(tensors)
    for name, arr in tensors:
        np.testing.assert_array_equal(loaded[name], np.asarray(arr))


def test_flatten_params_naming():
    params = init_model_params(0, DEFAULT_CONFIG, 2)
    names = [n for n, _ in export.flatten_params(params)]
    assert names[0] == "embed.tok"
    assert "block0.wq" in names
    assert "block11.b2" in names
    assert "head11.bc" in names
    assert len(names) == 4 + 12 * 16 + 12 * 4


def test_dataset_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 1024, size=(50, 32)).astype(np.int32)
    labels = rng.integers(0, 3, size=50).astype(np.int32)
    diff = rng.integers(0, 5, size=50).astype(np.int32)
    path = tmp_path / "d.bin"
    export.write_dataset(path, tokens, labels, diff, 3)
    t2, l2, d2, c = read_dataset(path)
    np.testing.assert_array_equal(tokens, t2)
    np.testing.assert_array_equal(labels, l2)
    np.testing.assert_array_equal(diff, d2)
    assert c == 3


def test_weights_rejects_bad_dtype(tmp_path):
    with pytest.raises(ValueError):
        export.write_weights(tmp_path / "b.bin",
                             [("x", np.zeros(3, np.float64))])


def test_fixture_entry_shapes():
    L, B, C = 12, 4, 2
    fx = export.fixture_entry(
        np.zeros((B, 32), np.int32), np.zeros(B, np.int32),
        np.zeros((L, B, C)), np.zeros((L, B)), np.zeros((L, B)))
    assert len(fx["tokens"]) == B
    assert len(fx["probs"]) == L
    assert len(fx["probs"][0]) == B
    assert len(fx["conf"]) == L

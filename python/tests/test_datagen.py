"""Dataset-substrate invariants: token layout, label mechanism, determinism,
difficulty mixture composition."""

import numpy as np
import pytest

from compile.common import DEFAULT_CONFIG
from compile.datagen import (CLS_ID, EVAL_TO_SOURCE, FLIP_ID, SPECS,
                             DatasetSpec, DifficultyMix, generate,
                             topic_tokens)

CFG = DEFAULT_CONFIG


@pytest.fixture(scope="module")
def small():
    spec = DatasetSpec("t", "sentiment", 2, 3000,
                       DifficultyMix(.3, .2, .1, .3, .1),
                       700, 950, 1.3, 42, "eval")
    return spec, generate(spec, CFG.seq_len, CFG.vocab)


def test_shapes_and_dtypes(small):
    spec, (tokens, labels, diff) = small
    assert tokens.shape == (3000, CFG.seq_len)
    assert tokens.dtype == np.int32
    assert labels.shape == (3000,) and labels.dtype == np.int32
    assert diff.shape == (3000,) and diff.dtype == np.int32


def test_token_ranges(small):
    spec, (tokens, labels, diff) = small
    assert tokens.min() >= 0
    assert tokens.max() < CFG.vocab
    assert np.all(tokens[:, 0] == CLS_ID)


def test_labels_in_range(small):
    spec, (tokens, labels, diff) = small
    assert labels.min() >= 0 and labels.max() < spec.n_classes


def test_flip_mechanism(small):
    """Label == (topic class + #flips) mod C: verify via reconstruction."""
    spec, (tokens, labels, diff) = small
    topics = topic_tokens(spec.family, spec.n_classes)
    for i in range(500):
        flips = int((tokens[i] == FLIP_ID).sum())
        # infer topic class from topic-token majority
        counts = [np.isin(tokens[i], topics[c]).sum() for c in range(2)]
        if counts[0] == counts[1]:
            continue  # ambiguous surface, skip
        c = int(np.argmax(counts))
        expected_flips = {0: 0, 1: 0, 2: 0, 3: 1, 4: 2}[int(diff[i])]
        assert flips == expected_flips, (i, flips, diff[i])
        assert labels[i] == (c + flips) % 2, (i, c, flips, labels[i])


def test_difficulty_mixture_proportions(small):
    spec, (tokens, labels, diff) = small
    weights = [.3, .2, .1, .3, .1]
    for cfg_idx, w in enumerate(weights):
        frac = (diff == cfg_idx).mean()
        assert abs(frac - w) < 0.03, (cfg_idx, frac, w)


def test_determinism():
    spec = SPECS["imdb"]
    a = generate(spec, CFG.seq_len, CFG.vocab)
    b = generate(spec, CFG.seq_len, CFG.vocab)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_source_eval_pairing_families_match():
    for ev, src in EVAL_TO_SOURCE.items():
        assert SPECS[ev].family == SPECS[src].family
        assert SPECS[ev].n_classes == SPECS[src].n_classes
        assert SPECS[src].role == "source"
        assert SPECS[ev].role == "eval"


def test_all_specs_token_layout_valid():
    for name, s in SPECS.items():
        t = topic_tokens(s.family, s.n_classes)
        assert t.max() < s.bg_lo <= s.bg_hi <= CFG.vocab, name


def test_class_balance(small):
    spec, (tokens, labels, diff) = small
    frac = labels.mean()
    assert 0.4 < frac < 0.6, frac


def test_domain_shift_changes_background():
    """Source and eval of the same family must differ in background tokens."""
    src_tok, _, _ = generate(SPECS["sst2"], CFG.seq_len, CFG.vocab)
    ev_tok, _, _ = generate(SPECS["imdb"], CFG.seq_len, CFG.vocab)
    # eval background reaches ids the source never uses
    assert ev_tok.max() > src_tok.max()

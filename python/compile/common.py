"""Shared model configuration and parameter utilities.

The multi-exit encoder reproduced here stands in for ElasticBERT-base
(see DESIGN.md section 2): a 12-layer pre-LN transformer encoder with an exit
head attached after every layer.  All shapes are fixed at AOT time so the
lowered HLO has static signatures the rust runtime can rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of the multi-exit encoder."""

    vocab: int = 1024
    seq_len: int = 32
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 128
    n_layers: int = 12

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


DEFAULT_CONFIG = ModelConfig()

# Keys of one transformer block's parameters, in the canonical argument order
# used by the AOT-lowered `block` graph.  The rust runtime feeds literals in
# exactly this order (exported in artifacts/manifest.json).
BLOCK_PARAM_ORDER: List[str] = [
    "ln1_g", "ln1_b",
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b",
    "w1", "b1", "w2", "b2",
]

# Exit-head parameter order for the `exit_head` graph.
HEAD_PARAM_ORDER: List[str] = ["ln_g", "ln_b", "wc", "bc"]

# Embedding parameter order for the `embed` graph.
EMBED_PARAM_ORDER: List[str] = ["tok", "pos", "ln_g", "ln_b"]


def init_block_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Initialise one transformer block (pre-LN attention + FFN)."""
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    s_attn = 1.0 / np.sqrt(d)
    s_ff = 1.0 / np.sqrt(d)
    s_ff2 = 1.0 / np.sqrt(f)
    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "wq": jax.random.normal(ks[0], (d, d), jnp.float32) * s_attn,
        "bq": jnp.zeros((d,), jnp.float32),
        "wk": jax.random.normal(ks[1], (d, d), jnp.float32) * s_attn,
        "bk": jnp.zeros((d,), jnp.float32),
        "wv": jax.random.normal(ks[2], (d, d), jnp.float32) * s_attn,
        "bv": jnp.zeros((d,), jnp.float32),
        "wo": jax.random.normal(ks[3], (d, d), jnp.float32) * s_attn,
        "bo": jnp.zeros((d,), jnp.float32),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": jax.random.normal(ks[4], (d, f), jnp.float32) * s_ff,
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": jax.random.normal(ks[5], (f, d), jnp.float32) * s_ff2,
        "b2": jnp.zeros((d,), jnp.float32),
    }


def init_head_params(key: jax.Array, cfg: ModelConfig, n_classes: int) -> Dict[str, jax.Array]:
    """Initialise one exit head ([CLS] LayerNorm + linear classifier)."""
    d = cfg.d_model
    return {
        "ln_g": jnp.ones((d,), jnp.float32),
        "ln_b": jnp.zeros((d,), jnp.float32),
        "wc": jax.random.normal(key, (d, n_classes), jnp.float32) / np.sqrt(d),
        "bc": jnp.zeros((n_classes,), jnp.float32),
    }


def init_embed_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Initialise token + positional embeddings and the embedding LayerNorm."""
    k1, k2 = jax.random.split(key)
    return {
        "tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "pos": jax.random.normal(k2, (cfg.seq_len, cfg.d_model), jnp.float32) * 0.02,
        "ln_g": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_b": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def init_model_params(seed: int, cfg: ModelConfig, n_classes: int) -> Dict:
    """Full multi-exit model: embeddings, L blocks, L exit heads."""
    key = jax.random.PRNGKey(seed)
    k_embed, k_blocks, k_heads = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    head_keys = jax.random.split(k_heads, cfg.n_layers)
    return {
        "embed": init_embed_params(k_embed, cfg),
        "blocks": [init_block_params(k, cfg) for k in block_keys],
        "heads": [init_head_params(k, cfg, n_classes) for k in head_keys],
    }


def block_param_list(p: Dict[str, jax.Array]) -> List[jax.Array]:
    """Block params in canonical argument order (see BLOCK_PARAM_ORDER)."""
    return [p[k] for k in BLOCK_PARAM_ORDER]


def head_param_list(p: Dict[str, jax.Array]) -> List[jax.Array]:
    return [p[k] for k in HEAD_PARAM_ORDER]


def embed_param_list(p: Dict[str, jax.Array]) -> List[jax.Array]:
    return [p[k] for k in EMBED_PARAM_ORDER]

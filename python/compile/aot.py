"""AOT build pipeline: datagen -> train -> lower HLO text -> export artifacts.

Run once via ``make artifacts``; python never appears on the request path.
Output tree (all consumed by the rust side)::

    artifacts/
      manifest.json              everything the rust loader needs to know
      hlo/
        embed_b{B}.hlo.txt       tokens + embed params -> h0
        block_b{B}.hlo.txt       h + block params -> h        (Pallas kernels)
        chain{N}_b{B}.hlo.txt    h + N blocks' params -> h    (fused range;
                                 N in 2..n_layers — the rust partition graphs
                                 run blocks[i..j) as ONE launch; length-1
                                 ranges reuse block_b{B})
        head_c{C}_b{B}.hlo.txt   h + head params -> probs/conf/ent  (Pallas)
        prefix_full_c{C}_b{BC}.hlo.txt
                                 tokens + all params -> per-layer probs/conf/ent
                                 (jnp reference path; cache-builder throughput)
      weights/{task}_{style}.bin trained parameters (SPLW format)
      data/{dataset}.bin         token sequences + labels (SPLD format)
      fixtures/{task}.json       golden per-layer outputs for integration tests

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5 emits
64-bit instruction ids that the image's xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

All graphs except ``prefix_full`` lower the interpret-mode Pallas kernels;
``prefix_full`` lowers the pure-jnp reference (pytest proves them allclose,
and the interpret-mode grid loop would serialize the batch — EXPERIMENTS.md
section Perf quantifies this).
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, export
from .common import (BLOCK_PARAM_ORDER, EMBED_PARAM_ORDER, HEAD_PARAM_ORDER,
                     DEFAULT_CONFIG, ModelConfig, init_model_params)
from .model import (block_fn, chain_fn, embed_fn, exit_head_fn,
                    forward_all_exits, make_prefix_full_fn)
from .train import (calibrate_alpha, calibrate_tau, eval_all_exits,
                    split_train_val, train_deebert, train_elasticbert)

BATCH_SIZES = (1, 8)
CACHE_BATCH = 32
STYLES = ("elasticbert", "deebert")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_graphs(cfg: ModelConfig, out_hlo: Path, log=print) -> dict:
    """Lower every serving graph; returns the manifest 'hlo' section."""
    out_hlo.mkdir(parents=True, exist_ok=True)
    f32 = jnp.float32
    d, t, v, f = cfg.d_model, cfg.seq_len, cfg.vocab, cfg.d_ff
    hlo_index: dict = {"embed": {}, "block": {}, "head_c2": {}, "head_c3": {},
                       "prefix_full_c2": {}, "prefix_full_c3": {}}

    def dump(name: str, text: str) -> str:
        rel = f"hlo/{name}.hlo.txt"
        (out_hlo / f"{name}.hlo.txt").write_text(text)
        log(f"    wrote {rel} ({len(text) / 1e3:.0f} kB)")
        return rel

    embed_shapes = [
        jax.ShapeDtypeStruct((v, d), f32),  # tok
        jax.ShapeDtypeStruct((t, d), f32),  # pos
        jax.ShapeDtypeStruct((d,), f32),    # ln_g
        jax.ShapeDtypeStruct((d,), f32),    # ln_b
    ]
    block_shapes = {
        "ln1_g": (d,), "ln1_b": (d,),
        "wq": (d, d), "bq": (d,), "wk": (d, d), "bk": (d,),
        "wv": (d, d), "bv": (d,), "wo": (d, d), "bo": (d,),
        "ln2_g": (d,), "ln2_b": (d,),
        "w1": (d, f), "b1": (f,), "w2": (f, d), "b2": (d,),
    }

    for b in BATCH_SIZES:
        tok_spec = jax.ShapeDtypeStruct((b, t), jnp.int32)
        h_spec = jax.ShapeDtypeStruct((b, t, d), f32)

        lowered = jax.jit(embed_fn).lower(tok_spec, *embed_shapes)
        hlo_index["embed"][str(b)] = dump(f"embed_b{b}", to_hlo_text(lowered))

        blk_arg_specs = [jax.ShapeDtypeStruct(block_shapes[k], f32)
                         for k in BLOCK_PARAM_ORDER]
        fn = functools.partial(block_fn, n_heads=cfg.n_heads, use_pallas=True)
        lowered = jax.jit(fn).lower(h_spec, *blk_arg_specs)
        hlo_index["block"][str(b)] = dump(f"block_b{b}", to_hlo_text(lowered))

        # Fused block-range graphs: one module per range length, weights as
        # args, so the same executable serves every blocks[i..j) window of
        # that length.  Length 1 is exactly `block`, so it is not duplicated.
        for n in range(2, cfg.n_layers + 1):
            fn = functools.partial(chain_fn, n_blocks=n, n_heads=cfg.n_heads,
                                   use_pallas=True)
            lowered = jax.jit(fn).lower(h_spec, *(blk_arg_specs * n))
            hlo_index.setdefault(f"chain{n}", {})[str(b)] = dump(
                f"chain{n}_b{b}", to_hlo_text(lowered))

        for c in (2, 3):
            head_arg_specs = [
                jax.ShapeDtypeStruct((d,), f32),   # ln_g
                jax.ShapeDtypeStruct((d,), f32),   # ln_b
                jax.ShapeDtypeStruct((d, c), f32), # wc
                jax.ShapeDtypeStruct((c,), f32),   # bc
            ]
            fn = functools.partial(exit_head_fn, use_pallas=True)
            lowered = jax.jit(fn).lower(h_spec, *head_arg_specs)
            hlo_index[f"head_c{c}"][str(b)] = dump(
                f"head_c{c}_b{b}", to_hlo_text(lowered))

    # prefix_full: weights-as-args full forward, reference path, cache batch.
    for c in (2, 3):

        def prefix(tokens, *flat):
            params = unflatten_args(list(flat), cfg, c)
            return forward_all_exits(params, tokens, cfg, use_pallas=False)

        arg_specs = flat_arg_specs(cfg, c)
        tok_spec = jax.ShapeDtypeStruct((CACHE_BATCH, t), jnp.int32)
        lowered = jax.jit(prefix).lower(tok_spec, *arg_specs)
        hlo_index[f"prefix_full_c{c}"][str(CACHE_BATCH)] = dump(
            f"prefix_full_c{c}_b{CACHE_BATCH}", to_hlo_text(lowered))
    return hlo_index


def flat_arg_specs(cfg: ModelConfig, n_classes: int):
    """ShapeDtypeStructs for the canonical flat parameter order:
    embed params, then block0..L-1 params, then head0..L-1 params."""
    f32 = jnp.float32
    d, t, v, f = cfg.d_model, cfg.seq_len, cfg.vocab, cfg.d_ff
    shapes = [(v, d), (t, d), (d,), (d,)]
    block_shape = {
        "ln1_g": (d,), "ln1_b": (d,),
        "wq": (d, d), "bq": (d,), "wk": (d, d), "bk": (d,),
        "wv": (d, d), "bv": (d,), "wo": (d, d), "bo": (d,),
        "ln2_g": (d,), "ln2_b": (d,),
        "w1": (d, f), "b1": (f,), "w2": (f, d), "b2": (d,),
    }
    for _ in range(cfg.n_layers):
        shapes += [block_shape[k] for k in BLOCK_PARAM_ORDER]
    head_shape = {"ln_g": (d,), "ln_b": (d,), "wc": (d, n_classes), "bc": (n_classes,)}
    for _ in range(cfg.n_layers):
        shapes += [head_shape[k] for k in HEAD_PARAM_ORDER]
    return [jax.ShapeDtypeStruct(s, f32) for s in shapes]


def unflatten_args(flat: list, cfg: ModelConfig, n_classes: int) -> dict:
    """Inverse of the canonical flat order used by ``flat_arg_specs``."""
    i = 0

    def take(n):
        nonlocal i
        chunk = flat[i:i + n]
        i += n
        return chunk

    embed = dict(zip(EMBED_PARAM_ORDER, take(len(EMBED_PARAM_ORDER))))
    blocks = [dict(zip(BLOCK_PARAM_ORDER, take(len(BLOCK_PARAM_ORDER))))
              for _ in range(cfg.n_layers)]
    heads = [dict(zip(HEAD_PARAM_ORDER, take(len(HEAD_PARAM_ORDER))))
             for _ in range(cfg.n_layers)]
    assert i == len(flat)
    return {"embed": embed, "blocks": blocks, "heads": heads}


def flatten_args(params: dict) -> list:
    """Model params -> canonical flat list (same order as flat_arg_specs)."""
    flat = [params["embed"][k] for k in EMBED_PARAM_ORDER]
    for blk in params["blocks"]:
        flat += [blk[k] for k in BLOCK_PARAM_ORDER]
    for head in params["heads"]:
        flat += [head[k] for k in HEAD_PARAM_ORDER]
    return flat


def build(out_dir: Path, cfg: ModelConfig, quick: bool, log=print) -> None:
    t_start = time.time()
    out_dir.mkdir(parents=True, exist_ok=True)
    # a rebuild invalidates any rust-side confidence caches
    import shutil
    shutil.rmtree(out_dir / "cache", ignore_errors=True)
    for sub in ("hlo", "weights", "data", "fixtures"):
        (out_dir / sub).mkdir(exist_ok=True)

    # ---- 1. datasets -----------------------------------------------------
    log("[1/4] generating datasets")
    data = {}
    for name, spec in datagen.SPECS.items():
        n_cap = 2000 if quick else spec.n_samples
        spec_eff = spec if n_cap == spec.n_samples else \
            datagen.DatasetSpec(**{**spec.__dict__, "n_samples": n_cap})
        tokens, labels, diff = datagen.generate(spec_eff, cfg.seq_len, cfg.vocab)
        data[name] = (tokens, labels, diff, spec_eff)
        export.write_dataset(out_dir / "data" / f"{name}.bin",
                             tokens, labels, diff, spec.n_classes)
        log(f"    {name}: {len(tokens)} samples, C={spec.n_classes}")

    # ---- 2. training ------------------------------------------------------
    log("[2/4] training multi-exit models")
    steps = {"eb": 60, "db1": 50, "db2": 40} if quick else \
            {"eb": 550, "db1": 300, "db2": 150}
    tasks = {}
    fixtures = {}
    for task in ("sst2", "rte", "mnli", "mrpc"):
        tokens, labels, diff, spec = data[task]
        tr_t, tr_l, va_t, va_l = split_train_val(tokens, labels, spec.seed)
        c = spec.n_classes
        task_info = {"classes": c, "weights": {}, "styles": list(STYLES)}
        for style in STYLES:
            log(f"  training {task} [{style}]")
            if style == "elasticbert":
                params = train_elasticbert(tr_t, tr_l, cfg, c, spec.seed,
                                           steps=steps["eb"], log=log)
            else:
                params = train_deebert(tr_t, tr_l, cfg, c, spec.seed,
                                       steps1=steps["db1"], steps2=steps["db2"],
                                       log=log)
            acc, conf, ent, pred = eval_all_exits(params, va_t, va_l, cfg)
            if style == "elasticbert":
                task_info["alpha"] = calibrate_alpha(conf, pred, va_l)
                task_info["val_acc_per_exit"] = [round(float(a), 4) for a in acc]
            else:
                task_info["tau"] = calibrate_tau(ent, pred, va_l, c)
                task_info["deebert_val_acc_per_exit"] = [round(float(a), 4) for a in acc]
            rel = f"weights/{task}_{style}.bin"
            export.write_weights(out_dir / rel, export.flatten_params(params))
            task_info["weights"][style] = rel
            log(f"    {task} [{style}] final-exit val acc {acc[-1]:.4f}")

            if style == "elasticbert":
                # golden fixture: 8 val samples, per-layer outputs
                fx_t, fx_l = va_t[:8], va_l[:8]
                probs, cf, en = forward_all_exits(params, jnp.asarray(fx_t), cfg)
                fixtures[task] = export.fixture_entry(
                    fx_t, fx_l, np.asarray(probs), np.asarray(cf), np.asarray(en))
        tasks[task] = task_info

    for task, fx in fixtures.items():
        export.write_json(out_dir / "fixtures" / f"{task}.json", fx)

    # ---- 3. HLO lowering ---------------------------------------------------
    log("[3/4] lowering graphs to HLO text")
    hlo_index = lower_graphs(cfg, out_dir / "hlo", log=log)

    # ---- 4. manifest -------------------------------------------------------
    log("[4/4] writing manifest")
    datasets = {}
    for name, (tokens, labels, diff, spec) in data.items():
        entry = {
            "file": f"data/{name}.bin",
            "classes": spec.n_classes,
            "samples": len(tokens),
            "role": spec.role,
            "paper_name": spec.paper_name,
            "paper_samples": datagen.SPECS[name].n_samples,
            "family": spec.family,
        }
        if spec.role == "eval":
            entry["source"] = datagen.EVAL_TO_SOURCE[name]
        datasets[name] = entry

    manifest = {
        "format_version": 1,
        "model": {
            "vocab": cfg.vocab, "seq_len": cfg.seq_len, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "n_layers": cfg.n_layers,
        },
        "batch_sizes": list(BATCH_SIZES),
        "cache_batch": CACHE_BATCH,
        "arg_order": {
            "embed": EMBED_PARAM_ORDER,
            "block": BLOCK_PARAM_ORDER,
            "chain": "h, then BLOCK_PARAM_ORDER per covered layer, ascending",
            "head": HEAD_PARAM_ORDER,
            "prefix_full": "tokens, embed params, block0..L-1 params, head0..L-1 params",
        },
        "tasks": tasks,
        "datasets": datasets,
        "hlo": hlo_index,
        "quick": quick,
    }
    export.write_json(out_dir / "manifest.json", manifest)
    log(f"artifacts complete in {time.time() - t_start:.0f}s -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true",
                    help="tiny datasets + few training steps (CI smoke)")
    args = ap.parse_args()
    build(Path(args.out), DEFAULT_CONFIG, args.quick)


if __name__ == "__main__":
    main()

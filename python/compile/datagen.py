"""Synthetic dataset substrate standing in for the paper's GLUE/ELUE data.

The paper evaluates on IMDb / Yelp / SciTail / SNLI / QQP after fine-tuning
ElasticBERT on SST-2 / RTE / MNLI / MRPC (same task family, shifted
distribution).  None of those corpora — nor the pre-trained backbone — are
available in this offline environment (repro band 0/5), so we rebuild the
*decision problem* with synthetic token sequences whose generative mechanism
controls exactly the properties SplitEE is sensitive to:

  * **Depth-dependent accuracy** — each sample carries a *topic class* encoded
    in the marginal distribution of its tokens (a bag-of-words signal shallow
    exits can read) and optionally FLIP tokens that invert the label
    (``label = (topic + #flips) mod C``).  Counting flip tokens and composing
    them with the topic evidence requires attention depth, so deep exits
    dominate shallow ones exactly on the "hard" population.
  * **Per-sample difficulty** — a mixture over (signal strength, #flips)
    configurations; easy samples saturate confidence at early exits, hard
    ones only at depth.  Mixture weights differ per dataset, which moves the
    optimal split layer the bandit must find.
  * **Domain shift** — source (fine-tuning) and target (evaluation) datasets
    share topic tokens but differ in background token distribution and
    difficulty mixture, reproducing the unsupervised-transfer setup.
  * **QQP's "confidently wrong" anomaly** (paper section 5.6) — a large
    single-flip share makes early exits confidently predict the surface topic
    (wrong), so accuracy *rises* with offloading cost on that dataset.

Token id layout (vocab = 1024):
  0            [CLS] (position 0 of every sequence)
  1            FLIP
  2 .. 2+C*K   topic tokens (K per class, per task family)
  rest         background (Zipf-ish, domain-dependent range)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

CLS_ID = 0
FLIP_ID = 1
TOPIC_BASE = 2
TOPIC_K = 30  # topic tokens per class


@dataclasses.dataclass(frozen=True)
class DifficultyMix:
    """Mixture weights over (signal strength, #flips) sample configurations."""

    easy: float      # s=0.60, flips=0
    medium: float    # s=0.30, flips=0
    hard: float      # s=0.15, flips=0
    flip1: float     # s=0.50, flips=1  -> early exits confidently wrong
    flip2: float     # s=0.50, flips=2  -> label restored, mid layers confused

    def as_configs(self) -> List[Tuple[float, float, int]]:
        return [
            (self.easy, 0.40, 0),
            (self.medium, 0.20, 0),
            (self.hard, 0.10, 0),
            (self.flip1, 0.40, 1),
            (self.flip2, 0.40, 2),
        ]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Generative spec of one dataset."""

    name: str
    family: str          # task family: shares topic tokens with its source
    n_classes: int
    n_samples: int
    mix: DifficultyMix
    bg_lo: int           # background token range [bg_lo, bg_hi)
    bg_hi: int
    bg_zipf: float       # Zipf exponent of the background distribution
    seed: int
    role: str            # "source" (fine-tuning) or "eval"
    paper_name: str = "" # the corpus this stands in for


# Task families and their topic-token offsets.  Families re-use the same
# topic ids between source and eval so supervised transfer is possible.
FAMILY_OFFSETS = {"sentiment": 0, "entail2": 1, "entail3": 2, "para": 4}


def topic_tokens(family: str, n_classes: int) -> np.ndarray:
    """Topic token ids for each class of a family: [C, K]."""
    off = TOPIC_BASE + FAMILY_OFFSETS[family] * TOPIC_K * 4
    return np.arange(off, off + n_classes * TOPIC_K).reshape(n_classes, TOPIC_K)


# The nine datasets (paper Table 1, sizes scaled to this testbed; the Yelp /
# SNLI / QQP scale-down is documented in DESIGN.md section 2).
SPECS: Dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        # -- source (fine-tuning) datasets ------------------------------
        # Mixes are kept close to their eval counterparts so the threshold
        # calibrated on source validation data transfers meaningfully (a
        # too-easy source would calibrate alpha ~ 0.5 and disable offloading
        # on the target, which the paper's GLUE pairs do not exhibit).
        DatasetSpec("sst2", "sentiment", 2, 8000,
                    DifficultyMix(.32, .28, .18, .14, .08),
                    600, 800, 1.10, 101, "source", "SST-2"),
        DatasetSpec("rte", "entail2", 2, 2500,
                    DifficultyMix(.20, .25, .34, .14, .07),
                    620, 820, 1.15, 102, "source", "RTE"),
        DatasetSpec("mnli", "entail3", 3, 12000,
                    DifficultyMix(.32, .28, .20, .13, .07),
                    640, 840, 1.05, 103, "source", "MNLI"),
        DatasetSpec("mrpc", "para", 2, 4000,
                    DifficultyMix(.30, .22, .12, .26, .10),
                    660, 860, 1.12, 104, "source", "MRPC"),
        # -- evaluation datasets (shifted background + mixture) ----------
        # Sizes follow the paper's relative ordering (Yelp/SNLI largest)
        # but are scaled to the single-core testbed; see DESIGN.md sec. 2.
        DatasetSpec("imdb", "sentiment", 2, 12000,
                    DifficultyMix(.40, .30, .12, .12, .06),
                    700, 950, 1.30, 201, "eval", "IMDb"),
        DatasetSpec("yelp", "sentiment", 2, 20000,
                    DifficultyMix(.35, .30, .15, .14, .06),
                    720, 1000, 1.40, 202, "eval", "Yelp"),
        DatasetSpec("scitail", "entail2", 2, 12000,
                    DifficultyMix(.18, .25, .37, .13, .07),
                    740, 980, 1.25, 203, "eval", "SciTail"),
        DatasetSpec("snli", "entail3", 3, 20000,
                    DifficultyMix(.35, .30, .18, .11, .06),
                    760, 1010, 1.20, 204, "eval", "SNLI"),
        DatasetSpec("qqp", "para", 2, 16000,
                    DifficultyMix(.28, .20, .10, .32, .10),
                    780, 1020, 1.35, 205, "eval", "QQP"),
    ]
}

# eval dataset -> source dataset used to fine-tune its exits (paper Table 1).
EVAL_TO_SOURCE = {
    "imdb": "sst2",
    "yelp": "sst2",
    "scitail": "rte",
    "snli": "mnli",
    "qqp": "mrpc",
}


def _zipf_probs(lo: int, hi: int, a: float) -> np.ndarray:
    ranks = np.arange(1, hi - lo + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


def generate(spec: DatasetSpec, seq_len: int, vocab: int
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Generate one dataset.

    Returns (tokens i32 [N, T], labels i32 [N], difficulty i32 [N]) where
    difficulty indexes the mixture config (0=easy .. 4=flip2) — exported so
    experiments can slice metrics by difficulty.
    """
    rng = np.random.default_rng(spec.seed)
    N, T, C = spec.n_samples, seq_len, spec.n_classes
    topics = topic_tokens(spec.family, C)
    assert topics.max() < spec.bg_lo <= spec.bg_hi <= vocab, spec.name

    configs = spec.mix.as_configs()
    weights = np.array([c[0] for c in configs])
    assert abs(weights.sum() - 1.0) < 1e-9, f"{spec.name}: mixture must sum to 1"

    cfg_idx = rng.choice(len(configs), size=N, p=weights)
    topic_cls = rng.integers(0, C, size=N)
    bg_probs = _zipf_probs(spec.bg_lo, spec.bg_hi, spec.bg_zipf)

    tokens = np.empty((N, T), dtype=np.int32)
    labels = np.empty((N,), dtype=np.int32)
    for i in range(N):
        _, s, n_flips = configs[cfg_idx[i]]
        c = topic_cls[i]
        seq = spec.bg_lo + rng.choice(spec.bg_hi - spec.bg_lo, size=T, p=bg_probs)
        is_topic = rng.random(T) < s
        n_topic = int(is_topic.sum())
        if n_topic:
            seq[is_topic] = rng.choice(topics[c], size=n_topic)
        if n_flips:
            # flip positions never collide with [CLS] (position 0)
            pos = rng.choice(T - 1, size=n_flips, replace=False) + 1
            seq[pos] = FLIP_ID
        seq[0] = CLS_ID
        tokens[i] = seq
        labels[i] = (c + n_flips) % C
    return tokens, labels, cfg_idx.astype(np.int32)


def generate_all(seq_len: int, vocab: int) -> Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Generate every dataset in SPECS."""
    return {name: generate(spec, seq_len, vocab) for name, spec in SPECS.items()}

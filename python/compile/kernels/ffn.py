"""Pallas kernel: fused pre-LN feed-forward block (LN -> W1 -> GELU -> W2 -> +x).

Same VMEM strategy as the attention kernel: grid over batch rows, one [T, D]
activation tile + both FFN weight matrices resident per grid step (W1/W2 are
64x128 f32 = 32 KiB each).  The two matmuls are MXU-shaped dense `jnp.dot`s;
GELU runs on the VPU between them without an HBM round trip — that fusion is
the point of making this one kernel instead of three XLA ops.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh-approximate GELU, matching jax.nn.gelu(approximate=True).
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def _ffn_kernel(x_ref, ln2_g_ref, ln2_b_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[0]  # [T, D]
    h = _ln(x, ln2_g_ref[...], ln2_b_ref[...])
    h = jnp.dot(h, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = _gelu(h)
    o_ref[0] = x + jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]


def ffn(x: jnp.ndarray, p: Dict[str, jnp.ndarray], interpret: bool = True) -> jnp.ndarray:
    """Fused FFN block over x: [B, T, D].  Residual included."""
    B, T, D = x.shape
    row = pl.BlockSpec((1, T, D), lambda b: (b, 0, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda b: (0,) * a.ndim)
    weights = [p[k] for k in ("ln2_g", "ln2_b", "w1", "b1", "w2", "b2")]
    return pl.pallas_call(
        _ffn_kernel,
        grid=(B,),
        in_specs=[row] + [full(w) for w in weights],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=interpret,
    )(x, *weights)

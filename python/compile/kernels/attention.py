"""Pallas kernel: fused pre-LN multi-head self-attention block.

TPU mapping (DESIGN.md section 8): the grid iterates over batch rows; each grid
step keeps one [T, D] activation tile plus all projection weights resident in
VMEM (at T=32, D=64 the working set is ~70 KiB, far under the ~16 MiB VMEM
budget), so there is a single HBM->VMEM stream per row and every matmul is a
dense MXU-shaped `jnp.dot`.  This replaces the CUDA threadblock/warp schedule
of GPU attention kernels with a BlockSpec-expressed pipeline.

Runtime lowering always uses ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention_kernel(
    x_ref, ln1_g_ref, ln1_b_ref,
    wq_ref, bq_ref, wk_ref, bk_ref, wv_ref, bv_ref, wo_ref, bo_ref,
    o_ref, *, n_heads: int,
):
    """One batch row: o = x + Wo·MHA(LN1(x))."""
    x = x_ref[0]  # [T, D] tile for this grid row
    T, D = x.shape
    dh = D // n_heads

    h = _ln(x, ln1_g_ref[...], ln1_b_ref[...])
    q = jnp.dot(h, wq_ref[...], preferred_element_type=jnp.float32) + bq_ref[...]
    k = jnp.dot(h, wk_ref[...], preferred_element_type=jnp.float32) + bk_ref[...]
    v = jnp.dot(h, wv_ref[...], preferred_element_type=jnp.float32) + bv_ref[...]

    # [T, H, dh] -> [H, T, dh]
    q = q.reshape(T, n_heads, dh).transpose(1, 0, 2)
    k = k.reshape(T, n_heads, dh).transpose(1, 0, 2)
    v = v.reshape(T, n_heads, dh).transpose(1, 0, 2)

    scores = jax.lax.dot_general(
        q, k,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(jnp.float32(dh))  # [H, T, T]
    # Numerically stable softmax over the key axis.
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    o = jax.lax.dot_general(
        w, v,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [H, T, dh]
    o = o.transpose(1, 0, 2).reshape(T, D)
    o_ref[0] = x + jnp.dot(o, wo_ref[...], preferred_element_type=jnp.float32) + bo_ref[...]


def attention(x: jnp.ndarray, p: Dict[str, jnp.ndarray], n_heads: int,
              interpret: bool = True) -> jnp.ndarray:
    """Fused attention block over x: [B, T, D].  Residual included."""
    B, T, D = x.shape
    row = pl.BlockSpec((1, T, D), lambda b: (b, 0, 0))  # stream batch rows
    full = lambda a: pl.BlockSpec(a.shape, lambda b: (0,) * a.ndim)  # resident
    weights = [p[k] for k in ("ln1_g", "ln1_b", "wq", "bq", "wk", "bk",
                              "wv", "bv", "wo", "bo")]
    return pl.pallas_call(
        functools.partial(_attention_kernel, n_heads=n_heads),
        grid=(B,),
        in_specs=[row] + [full(w) for w in weights],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=interpret,
    )(x, *weights)

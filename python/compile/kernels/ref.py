"""Pure-jnp reference (oracle) implementations of every Pallas kernel.

These are the ground truth the Pallas kernels are validated against in
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/seeds and
``assert_allclose``).  They are also used by the high-throughput
``prefix_full`` cache-builder graph, where the interpret-mode Pallas
lowering's sequential grid loop would serialize the batch (see
DESIGN.md section 3 / EXPERIMENTS.md section Perf).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def attention_ref(x: jnp.ndarray, p: Dict[str, jnp.ndarray], n_heads: int) -> jnp.ndarray:
    """Pre-LN multi-head self-attention with residual: ``x + MHA(LN1(x))``.

    x: [B, T, D].  Bidirectional (encoder) attention, no mask.
    """
    B, T, D = x.shape
    dh = D // n_heads
    h = layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = h @ p["wq"] + p["bq"]
    k = h @ p["wk"] + p["bk"]
    v = h @ p["wv"] + p["bv"]
    # [B, T, H, dh] -> [B, H, T, dh]
    q = q.reshape(B, T, n_heads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, n_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, n_heads, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhts,bhsd->bhtd", w, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
    return x + (o @ p["wo"] + p["bo"])


def ffn_ref(x: jnp.ndarray, p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Pre-LN feed-forward with residual: ``x + W2*gelu(W1*LN2(x))``."""
    h = layer_norm(x, p["ln2_g"], p["ln2_b"])
    h = jax.nn.gelu(h @ p["w1"] + p["b1"], approximate=True)
    return x + (h @ p["w2"] + p["b2"])


def block_ref(x: jnp.ndarray, p: Dict[str, jnp.ndarray], n_heads: int) -> jnp.ndarray:
    """One full transformer block (attention then FFN, both residual)."""
    return ffn_ref(attention_ref(x, p, n_heads), p)


def exit_head_ref(
    x: jnp.ndarray, p: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exit head: [CLS] pooling -> LN -> classifier -> softmax.

    Returns (probs [B, C], confidence=max prob [B], entropy [B] in nats).
    Confidence is the paper's C_i; entropy is the DeeBERT-style measure.
    """
    cls = x[:, 0, :]  # [B, D]
    h = layer_norm(cls, p["ln_g"], p["ln_b"])
    logits = h @ p["wc"] + p["bc"]
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    conf = jnp.max(probs, axis=-1)
    ent = -jnp.sum(probs * jnp.log(probs + 1e-12), axis=-1)
    return probs, conf, ent


def embed_ref(tokens: jnp.ndarray, p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Token + positional embedding followed by LayerNorm.  tokens: [B, T] i32."""
    h = p["tok"][tokens] + p["pos"][None, :, :]
    return layer_norm(h, p["ln_g"], p["ln_b"])

"""Pallas kernel: fused exit head ([CLS] pool -> LN -> classifier -> softmax).

Emits three outputs in one kernel so the rust coordinator gets everything a
policy might need from a single PJRT execute:

  * probs [B, C]  — class probabilities,
  * conf  [B]     — max-probability confidence (the paper's C_i, used by
                    SplitEE / SplitEE-S / ElasticBERT-style thresholding),
  * ent   [B]     — prediction entropy in nats (DeeBERT's exit measure).

The whole head is a [D] vector x [D, C] matmul per row — trivially
VMEM-resident; fusing pooling + LN + softmax avoids three HBM round trips per
exit evaluation, which matters because SplitEE-S evaluates every exit head
j <= i_t on the edge device.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _exit_head_kernel(x_ref, ln_g_ref, ln_b_ref, wc_ref, bc_ref,
                      probs_ref, conf_ref, ent_ref):
    cls = x_ref[0, 0]  # [D] — [CLS] token of this batch row
    h = _ln(cls[None, :], ln_g_ref[...], ln_b_ref[...])  # [1, D]
    logits = jnp.dot(h, wc_ref[...], preferred_element_type=jnp.float32) + bc_ref[...]
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)  # [1, C]
    probs_ref[0] = probs[0]
    conf_ref[0] = jnp.max(probs[0])
    ent_ref[0] = -jnp.sum(probs[0] * jnp.log(probs[0] + 1e-12))


def exit_head(
    x: jnp.ndarray, p: Dict[str, jnp.ndarray], interpret: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exit head over hidden states x: [B, T, D] -> (probs, conf, ent)."""
    B, T, D = x.shape
    C = p["wc"].shape[1]
    row = pl.BlockSpec((1, T, D), lambda b: (b, 0, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda b: (0,) * a.ndim)
    weights = [p[k] for k in ("ln_g", "ln_b", "wc", "bc")]
    return pl.pallas_call(
        _exit_head_kernel,
        grid=(B,),
        in_specs=[row] + [full(w) for w in weights],
        out_specs=(
            pl.BlockSpec((1, C), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ),
        interpret=interpret,
    )(x, *weights)

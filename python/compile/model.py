"""Layer-2 JAX model: the multi-exit encoder (ElasticBERT-stand-in).

Composes the Layer-1 Pallas kernels (attention, ffn, exit_head) into the
graphs that ``aot.py`` lowers to HLO text for the rust runtime:

  * ``embed_fn``      tokens [B,T] i32 (+ embed params)  -> h0 [B,T,D]
  * ``block_fn``      h [B,T,D] (+ block params)         -> h' [B,T,D]
  * ``exit_head_fn``  h [B,T,D] (+ head params)          -> (probs, conf, ent)
  * ``prefix_full_fn`` tokens -> per-layer (probs, conf, ent) stacked over L
                       (weights baked as constants; cache-builder graph)

``use_pallas`` switches between the Pallas kernels (interpret=True — the
serving-path artifacts) and the pure-jnp reference (the throughput-oriented
cache builder; numerically identical, verified by pytest).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .common import BLOCK_PARAM_ORDER, HEAD_PARAM_ORDER, ModelConfig
from .kernels import ref
from .kernels.attention import attention
from .kernels.exit_head import exit_head
from .kernels.ffn import ffn


def embed_fn(tokens: jnp.ndarray, tok: jnp.ndarray, pos: jnp.ndarray,
             ln_g: jnp.ndarray, ln_b: jnp.ndarray) -> jnp.ndarray:
    """Embedding graph.  A gather is memory-bound, not MXU work, so this stays
    plain jnp rather than a Pallas kernel (DESIGN.md section 8)."""
    return ref.embed_ref(tokens, {"tok": tok, "pos": pos, "ln_g": ln_g, "ln_b": ln_b})


def block_fn(h: jnp.ndarray, *params: jnp.ndarray, n_heads: int,
             use_pallas: bool = True) -> jnp.ndarray:
    """One transformer block, weights as positional args (BLOCK_PARAM_ORDER)."""
    p = dict(zip(BLOCK_PARAM_ORDER, params))
    if use_pallas:
        return ffn(attention(h, p, n_heads), p)
    return ref.block_ref(h, p, n_heads)


def exit_head_fn(h: jnp.ndarray, *params: jnp.ndarray,
                 use_pallas: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One exit head, weights as positional args (HEAD_PARAM_ORDER)."""
    p = dict(zip(HEAD_PARAM_ORDER, params))
    if use_pallas:
        return exit_head(h, p)
    return ref.exit_head_ref(h, p)


def chain_fn(h: jnp.ndarray, *params: jnp.ndarray, n_blocks: int,
             n_heads: int, use_pallas: bool = True) -> jnp.ndarray:
    """``n_blocks`` consecutive transformer blocks fused into one graph.

    Weights are positional args: BLOCK_PARAM_ORDER per block, blocks in
    ascending layer order — the rust partition subsystem feeds any
    ``blocks[i..j)`` range of length ``n_blocks`` through the same compiled
    module.  Fusing the range into one executable keeps the activation
    device-resident across every internal layer boundary; the per-block
    composition is exactly ``block_fn`` iterated, so outputs are identical
    to the layer-by-layer path (asserted by tests on both sides).
    """
    per = len(BLOCK_PARAM_ORDER)
    assert len(params) == n_blocks * per, (len(params), n_blocks, per)
    for i in range(n_blocks):
        h = block_fn(h, *params[i * per:(i + 1) * per], n_heads=n_heads,
                     use_pallas=use_pallas)
    return h


def forward_all_exits(
    params: Dict, tokens: jnp.ndarray, cfg: ModelConfig, use_pallas: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full forward pass through every layer and every exit head.

    Returns (probs [L,B,C], conf [L,B], ent [L,B]).  This is the graph behind
    the confidence cache and the python-side training/eval utilities.
    """
    h = ref.embed_ref(tokens, params["embed"])
    probs_l: List[jnp.ndarray] = []
    conf_l: List[jnp.ndarray] = []
    ent_l: List[jnp.ndarray] = []
    for blk, head in zip(params["blocks"], params["heads"]):
        if use_pallas:
            h = ffn(attention(h, blk, cfg.n_heads), blk)
            probs, conf, ent = exit_head(h, head)
        else:
            h = ref.block_ref(h, blk, cfg.n_heads)
            probs, conf, ent = ref.exit_head_ref(h, head)
        probs_l.append(probs)
        conf_l.append(conf)
        ent_l.append(ent)
    return jnp.stack(probs_l), jnp.stack(conf_l), jnp.stack(ent_l)


def forward_logits_all_exits(
    params: Dict, tokens: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Per-exit *logits* [L, B, C] (reference path) — used by the trainer."""
    h = ref.embed_ref(tokens, params["embed"])
    logits_l: List[jnp.ndarray] = []
    for blk, head in zip(params["blocks"], params["heads"]):
        h = ref.block_ref(h, blk, cfg.n_heads)
        cls = ref.layer_norm(h[:, 0, :], head["ln_g"], head["ln_b"])
        logits_l.append(cls @ head["wc"] + head["bc"])
    return jnp.stack(logits_l)


def make_prefix_full_fn(params: Dict, cfg: ModelConfig, use_pallas: bool = False):
    """Close over trained weights -> tokens-only graph for the cache builder.

    Baking the weights as HLO constants sidesteps argument-order fragility for
    the one graph with ~400k parameters, and lets XLA constant-fold layouts.
    """

    def fn(tokens: jnp.ndarray):
        return forward_all_exits(params, tokens, cfg, use_pallas=use_pallas)

    return fn

"""Binary + JSON export formats shared with the rust side.

All binary formats are little-endian.  The rust readers live in
``rust/src/model/weights.rs`` and ``rust/src/data/format.rs``; keep the magic
numbers and layouts in sync.

weights.bin::

    u32 magic = 0x53504C57 ("SPLW")      u32 version = 1
    u32 n_tensors
    per tensor:
        u16 name_len, name bytes (utf-8)
        u8 dtype (0 = f32, 1 = i32)
        u8 ndim, u32 dims[ndim]
        raw data (numel * 4 bytes)

data.bin::

    u32 magic = 0x53504C44 ("SPLD")      u32 version = 1
    u32 n_samples, u32 seq_len, u32 n_classes
    i32 tokens[n * seq_len]
    i32 labels[n]
    i32 difficulty[n]                    (mixture config index, see datagen)
"""

from __future__ import annotations

import json
import struct
from pathlib import Path
from typing import Dict, List

import numpy as np

WEIGHTS_MAGIC = 0x53504C57
DATA_MAGIC = 0x53504C44
VERSION = 1

DTYPE_F32 = 0
DTYPE_I32 = 1


def write_weights(path: Path, tensors: List) -> None:
    """Write named tensors.  ``tensors`` is a list of (name, np.ndarray)."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", WEIGHTS_MAGIC, VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr)
            if arr.dtype == np.float32:
                dtype = DTYPE_F32
            elif arr.dtype == np.int32:
                dtype = DTYPE_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dtype, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(np.ascontiguousarray(arr).tobytes())


def flatten_params(params: Dict) -> List:
    """Flatten a model param dict into the canonical (name, array) list.

    Naming scheme (mirrored by the rust loader):
      ``embed.<key>``, ``block<i>.<key>``, ``head<i>.<key>``.
    """
    from .common import BLOCK_PARAM_ORDER, EMBED_PARAM_ORDER, HEAD_PARAM_ORDER

    out = []
    for k in EMBED_PARAM_ORDER:
        out.append((f"embed.{k}", np.asarray(params["embed"][k], np.float32)))
    for i, blk in enumerate(params["blocks"]):
        for k in BLOCK_PARAM_ORDER:
            out.append((f"block{i}.{k}", np.asarray(blk[k], np.float32)))
    for i, head in enumerate(params["heads"]):
        for k in HEAD_PARAM_ORDER:
            out.append((f"head{i}.{k}", np.asarray(head[k], np.float32)))
    return out


def write_dataset(path: Path, tokens: np.ndarray, labels: np.ndarray,
                  difficulty: np.ndarray, n_classes: int) -> None:
    n, t = tokens.shape
    assert labels.shape == (n,) and difficulty.shape == (n,)
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIII", DATA_MAGIC, VERSION, n, t, n_classes))
        f.write(np.ascontiguousarray(tokens, np.int32).tobytes())
        f.write(np.ascontiguousarray(labels, np.int32).tobytes())
        f.write(np.ascontiguousarray(difficulty, np.int32).tobytes())


def write_json(path: Path, obj) -> None:
    path.write_text(json.dumps(obj, indent=1, sort_keys=True))


def fixture_entry(tokens: np.ndarray, labels: np.ndarray, probs: np.ndarray,
                  conf: np.ndarray, ent: np.ndarray) -> Dict:
    """Golden values for the rust integration test: a handful of samples with
    per-layer outputs computed by the python reference model."""
    return {
        "tokens": tokens.astype(int).tolist(),
        "labels": labels.astype(int).tolist(),
        "probs": np.round(probs.astype(float), 6).tolist(),   # [L][B][C]
        "conf": np.round(conf.astype(float), 6).tolist(),     # [L][B]
        "ent": np.round(ent.astype(float), 6).tolist(),       # [L][B]
    }

"""Build-time training of the multi-exit encoder (paper section 5.1 / figure 2).

Two training styles, both from the paper:

  * ``elasticbert`` — joint training: the sum of cross-entropy losses over
    *all* exits updates backbone and heads together (ElasticBERT's recipe,
    which SplitEE uses as its backbone).
  * ``deebert`` — two-stage: (1) train backbone + final head with the final
    loss only (plain BERT fine-tuning); (2) freeze the backbone and final
    head, train the intermediate heads.  DeeBERT's recipe, used for the
    DeeBERT baseline row of Table 2.

Optimisation is hand-rolled Adam (optax is not in the offline image).  The
trainer also calibrates, on a held-out validation split of the *source*
dataset, the exit thresholds the paper treats as given:

  * ``alpha`` — max-probability confidence threshold (SplitEE / ElasticBERT),
  * ``tau``   — entropy threshold (DeeBERT),

each as the loosest threshold whose threshold-cascade accuracy stays within
0.5 points of final-exit accuracy on source validation data.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, init_model_params
from .model import forward_logits_all_exits

VAL_FRACTION = 0.15


# --------------------------------------------------------------------------
# Hand-rolled Adam (no optax in the offline image)
# --------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def _ce(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy.  logits [B, C], labels [B] i32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def joint_loss(params, tokens, labels, cfg: ModelConfig) -> jnp.ndarray:
    """ElasticBERT-style: mean CE over all L exits."""
    logits = forward_logits_all_exits(params, tokens, cfg)  # [L, B, C]
    return jnp.mean(jax.vmap(_ce, in_axes=(0, None))(logits, labels))


def final_loss(params, tokens, labels, cfg: ModelConfig) -> jnp.ndarray:
    """DeeBERT stage 1: CE of the final exit only."""
    logits = forward_logits_all_exits(params, tokens, cfg)
    return _ce(logits[-1], labels)


def heads_loss(heads, frozen, tokens, labels, cfg: ModelConfig) -> jnp.ndarray:
    """DeeBERT stage 2: CE of intermediate exits, backbone + final head frozen."""
    params = {"embed": frozen["embed"], "blocks": frozen["blocks"],
              "heads": list(heads) + [frozen["final_head"]]}
    logits = forward_logits_all_exits(params, tokens, cfg)  # [L, B, C]
    return jnp.mean(jax.vmap(_ce, in_axes=(0, None))(logits[:-1], labels))


# --------------------------------------------------------------------------
# Training loops
# --------------------------------------------------------------------------

def _batches(rng: np.random.Generator, n: int, bs: int, steps: int):
    for _ in range(steps):
        yield rng.integers(0, n, size=bs)


def train_elasticbert(tokens: np.ndarray, labels: np.ndarray, cfg: ModelConfig,
                      n_classes: int, seed: int, steps: int = 700,
                      bs: int = 64, lr: float = 1e-3, log=print) -> Dict:
    """Joint multi-exit training.  Returns trained params."""
    params = init_model_params(seed, cfg, n_classes)
    opt = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(
        functools.partial(joint_loss, cfg=cfg)))
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for step, idx in enumerate(_batches(rng, len(tokens), bs, steps)):
        loss, grads = loss_grad(params, jnp.asarray(tokens[idx]), jnp.asarray(labels[idx]))
        params, opt = adam_update(params, grads, opt, lr)
        if step % 100 == 0 or step == steps - 1:
            log(f"    [elasticbert] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params


def train_deebert(tokens: np.ndarray, labels: np.ndarray, cfg: ModelConfig,
                  n_classes: int, seed: int, steps1: int = 500, steps2: int = 400,
                  bs: int = 64, lr: float = 1e-3, log=print) -> Dict:
    """Two-stage DeeBERT training.  Returns trained params."""
    params = init_model_params(seed + 7, cfg, n_classes)
    # ---- stage 1: backbone + final head, final loss only
    opt = adam_init(params)
    lg1 = jax.jit(jax.value_and_grad(functools.partial(final_loss, cfg=cfg)))
    rng = np.random.default_rng(seed + 7)
    t0 = time.time()
    for step, idx in enumerate(_batches(rng, len(tokens), bs, steps1)):
        loss, grads = lg1(params, jnp.asarray(tokens[idx]), jnp.asarray(labels[idx]))
        params, opt = adam_update(params, grads, opt, lr)
        if step % 100 == 0 or step == steps1 - 1:
            log(f"    [deebert s1]  step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    # ---- stage 2: freeze backbone + final head, train intermediate heads
    frozen = {"embed": params["embed"], "blocks": params["blocks"],
              "final_head": params["heads"][-1]}
    heads = params["heads"][:-1]
    opt2 = adam_init(heads)
    lg2 = jax.jit(jax.value_and_grad(functools.partial(heads_loss, cfg=cfg)),
                  static_argnums=())

    def lg2_wrapped(heads_, tok_, lab_):
        return jax.value_and_grad(heads_loss)(heads_, frozen, tok_, lab_, cfg)

    lg2j = jax.jit(lg2_wrapped)
    for step, idx in enumerate(_batches(rng, len(tokens), bs, steps2)):
        loss, grads = lg2j(heads, jnp.asarray(tokens[idx]), jnp.asarray(labels[idx]))
        heads, opt2 = adam_update(heads, grads, opt2, lr)
        if step % 100 == 0 or step == steps2 - 1:
            log(f"    [deebert s2]  step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    params["heads"] = list(heads) + [frozen["final_head"]]
    return params


# --------------------------------------------------------------------------
# Evaluation + threshold calibration
# --------------------------------------------------------------------------

def eval_all_exits(params: Dict, tokens: np.ndarray, labels: np.ndarray,
                   cfg: ModelConfig, bs: int = 256
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Run the model over a dataset.  Returns (acc [L], conf [L,N], ent [L,N],
    pred [L,N])."""
    fwd = jax.jit(functools.partial(forward_logits_all_exits, cfg=cfg))
    confs, ents, preds = [], [], []
    for i in range(0, len(tokens), bs):
        logits = fwd(params, jnp.asarray(tokens[i:i + bs]))  # [L, B, C]
        logits = logits - jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        confs.append(np.asarray(jnp.max(p, axis=-1)))
        ents.append(np.asarray(-jnp.sum(p * jnp.log(p + 1e-12), axis=-1)))
        preds.append(np.asarray(jnp.argmax(p, axis=-1)))
    conf = np.concatenate(confs, axis=1)
    ent = np.concatenate(ents, axis=1)
    pred = np.concatenate(preds, axis=1)
    acc = (pred == labels[None, :]).mean(axis=1)
    return acc, conf, ent, pred


def calibrate_alpha(conf: np.ndarray, pred: np.ndarray, labels: np.ndarray,
                    tol: float = 0.003) -> float:
    """Smallest confidence threshold whose exit-at-first-confident-layer
    cascade accuracy is within ``tol`` of final-exit accuracy."""
    final_acc = (pred[-1] == labels).mean()
    for alpha in np.arange(0.50, 0.99, 0.02):
        acc = _cascade_acc_conf(conf, pred, labels, alpha)
        if acc >= final_acc - tol:
            return round(float(alpha), 3)
    return 0.98


def calibrate_tau(ent: np.ndarray, pred: np.ndarray, labels: np.ndarray,
                  n_classes: int, tol: float = 0.003) -> float:
    """Largest entropy threshold whose exit-when-entropy-below cascade
    accuracy is within ``tol`` of final-exit accuracy."""
    final_acc = (pred[-1] == labels).mean()
    max_ent = float(np.log(n_classes))
    best = 0.05 * max_ent
    for tau in np.linspace(0.98, 0.02, 49) * max_ent:
        acc = _cascade_acc_ent(ent, pred, labels, tau)
        if acc >= final_acc - tol:
            best = tau
            break
    return round(float(best), 4)


def _cascade_acc_conf(conf, pred, labels, alpha):
    L, N = conf.shape
    exit_layer = np.argmax(conf >= alpha, axis=0)           # first confident
    never = ~(conf >= alpha).any(axis=0)
    exit_layer[never] = L - 1
    chosen = pred[exit_layer, np.arange(N)]
    return (chosen == labels).mean()


def _cascade_acc_ent(ent, pred, labels, tau):
    L, N = ent.shape
    exit_layer = np.argmax(ent <= tau, axis=0)
    never = ~(ent <= tau).any(axis=0)
    exit_layer[never] = L - 1
    chosen = pred[exit_layer, np.arange(N)]
    return (chosen == labels).mean()


def split_train_val(tokens: np.ndarray, labels: np.ndarray, seed: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic train/validation split of a source dataset."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(tokens))
    n_val = int(len(tokens) * VAL_FRACTION)
    val, tr = order[:n_val], order[n_val:]
    return tokens[tr], labels[tr], tokens[val], labels[val]

//! Domain-shift adaptation: the paper's core deployment story.  Exits are
//! calibrated on a *source* dataset (e.g. SST-2) but serve a *target*
//! distribution (e.g. IMDb then Yelp) without labels.  This example streams
//! target datasets through SplitEE back-to-back and shows the bandit
//! re-converging when the distribution changes mid-stream.
//!
//! ```text
//! cargo run --release --example domain_shift -- [--per-phase 3000]
//! ```

use anyhow::Result;
use splitee::config::{Manifest, Settings};
use splitee::cost::CostModel;
use splitee::experiments::ConfidenceCache;
use splitee::policy::{oracle_split, Policy, SampleView, SplitEeSPolicy};
use splitee::runtime::Backend;
use splitee::util::args::Args;
use splitee::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    splitee::util::logging::init(if args.has("quiet") { 0 } else { 1 });
    let settings = Settings::from_args(&args).map_err(anyhow::Error::msg)?;
    let per_phase = args.get_num("per-phase", 3000usize).map_err(anyhow::Error::msg)?;

    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let backend = Backend::from_name(&settings.backend)?;
    let l = manifest.model.n_layers;
    let cm = CostModel::paper(settings.offload_cost, settings.mu, l);

    // Two target domains sharing one fine-tuned model (SST-2 -> IMDb, Yelp).
    let phases = ["imdb", "yelp"];
    let alpha = manifest.source_task("imdb")?.alpha;
    // One long-lived policy across the distribution change — the paper's
    // future-work "adapt to changes in the distribution fast" scenario,
    // using the side-observation variant for fast re-convergence.
    let mut policy = SplitEeSPolicy::new(l, alpha, settings.beta);
    let mut rng = Rng::new(settings.seed);

    for (phase, dataset) in phases.iter().enumerate() {
        let cache = ConfidenceCache::load_or_build(&manifest, &backend, dataset, "elasticbert")?;
        let profiles: Vec<(Vec<f32>, Vec<f32>)> = (0..cache.n_samples)
            .map(|i| (cache.sample_conf(i), cache.sample_ent(i)))
            .collect();
        let (oracle, means) = oracle_split(&profiles, &cm, alpha, true);
        let order = rng.permutation(cache.n_samples);
        let take = per_phase.min(order.len());

        let mut hits = 0usize;
        let mut cost = 0.0;
        let mut window_split = vec![0usize; l + 1];
        for (t, &i) in order[..take].iter().enumerate() {
            let conf = cache.sample_conf(i);
            let ent = cache.sample_ent(i);
            let o = policy.decide(&SampleView { conf: &conf, ent: &ent }, &cm);
            hits += (cache.pred_at(o.infer_layer - 1, i) == cache.labels[i]) as usize;
            cost += o.cost;
            if t >= take.saturating_sub(500) {
                window_split[o.split] += 1; // last-500 split histogram
            }
        }
        let modal = window_split
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "phase {} [{dataset:>7}]: oracle split L{oracle} (E[r] {:+.3}), \
             policy settled on L{modal}; acc {:.1}%, mean cost {:.2} lambda",
            phase + 1,
            means[oracle - 1],
            100.0 * hits as f64 / take as f64,
            cost / take as f64,
        );
    }
    println!(
        "\nThe bandit carries its state across the shift and re-converges on the\n\
         new domain's optimal split within a few hundred samples (SplitEE-S's\n\
         side observations are what make this fast — paper section 5.5)."
    );
    Ok(())
}

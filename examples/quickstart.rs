//! Quickstart: load the AOT artifacts, run one sample through the multi-exit
//! model layer by layer, and let SplitEE decide split + exit-or-offload.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use splitee::config::Manifest;
use splitee::cost::{CostModel, NetworkProfile};
use splitee::data::Dataset;
use splitee::model::MultiExitModel;
use splitee::policy::{Policy, SampleView, SplitEePolicy};
use splitee::runtime::Backend;
use splitee::sim::{CoInferencePipeline, LinkSim};

fn main() -> Result<()> {
    splitee::util::logging::init(1);
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let backend = Backend::auto();
    println!("compute backend: {}", backend.name());

    // 1. Load the fine-tuned multi-exit model for the IMDb task (trained on
    //    the SST-2-like source domain, evaluated cross-domain — the paper's
    //    unsupervised setting).
    let task = manifest.source_task("imdb")?.clone();
    let model = MultiExitModel::load(&manifest, &backend, &task.name, "elasticbert")?;
    println!(
        "model: {} layers, {} classes, exit threshold alpha = {}",
        model.n_layers(),
        model.n_classes(),
        task.alpha
    );

    // 2. Take a handful of real evaluation samples.
    let data = Dataset::load(
        &manifest.root.join(&manifest.dataset("imdb")?.file),
        "imdb",
    )?;

    // 3. Run the paper's Algorithm 1 end to end over a co-inference pipeline
    //    (edge compute -> 3G uplink -> cloud) for 40 samples.
    let cm = CostModel::paper(5.0, 0.1, model.n_layers());
    let link = LinkSim::new(NetworkProfile::three_g(), 7);
    let mut pipeline = CoInferencePipeline::new(&model, link, cm, task.alpha);
    let mut policy = SplitEePolicy::new(model.n_layers(), task.alpha, 1.0);

    let mut correct = 0usize;
    let mut total_cost = 0.0;
    let n = 40.min(data.len());
    for i in 0..n {
        let tokens = data.sample_tokens(i);
        let split = policy.choose_split();
        let trace = pipeline.serve(&tokens, split, false)?;
        policy.record(split, trace.reward);
        if trace.prediction as i32 == data.labels[i] {
            correct += 1;
        }
        total_cost += trace.cost_lambda;
        if i < 8 {
            println!(
                "sample {i:2}: split L{split:<2} -> {} at L{:<2} conf {:.3} \
                 (cost {:.2} lambda, {:.2} ms simulated)",
                if trace.offloaded { "OFFLOAD, infer" } else { "exit" },
                trace.infer_layer,
                trace.confidence,
                trace.cost_lambda,
                trace.latency_ms,
            );
        }
    }
    println!(
        "\n{n} samples: accuracy {:.1}%, mean cost {:.2} lambda \
         (final-exit baseline cost = {:.1})",
        100.0 * correct as f64 / n as f64,
        total_cost / n as f64,
        cm.final_exit_cost()
    );

    // 4. The same decision problem, replayed on cached profiles (how the
    //    experiment harness evaluates 20 repetitions in seconds).
    let mut eval_policy = SplitEePolicy::new(model.n_layers(), task.alpha, 1.0);
    let outs = model.forward_all_exits(&data.range_tokens(0, n))?;
    let mut exits = vec![0usize; model.n_layers() + 1];
    for i in 0..n {
        let conf: Vec<f32> = outs.iter().map(|o| o.conf[i]).collect();
        let ent: Vec<f32> = outs.iter().map(|o| o.ent[i]).collect();
        let o = eval_policy.decide(&SampleView { conf: &conf, ent: &ent }, &cm);
        exits[o.infer_layer] += 1;
    }
    println!("exit-layer histogram over the replay: {exits:?}");
    println!("quickstart OK");
    Ok(())
}

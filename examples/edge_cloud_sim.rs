//! Edge/cloud co-inference study: how the network generation (WiFi/5G/4G/3G)
//! moves the optimal split layer, the offload rate, latency and edge energy —
//! the deployment question figure 1 of the paper poses.
//!
//! Also exercises the failure-injection path: a lossy 3G link with outages
//! forces on-device fallbacks (the LEE/DEE "service outage" scenario).
//!
//! ```text
//! cargo run --release --example edge_cloud_sim -- [--requests 300]
//! ```

use anyhow::Result;
use splitee::config::{Manifest, Settings};
use splitee::cost::{CostModel, NetworkProfile};
use splitee::data::Dataset;
use splitee::model::MultiExitModel;
use splitee::policy::SplitEePolicy;
use splitee::runtime::Backend;
use splitee::sim::{CoInferencePipeline, LinkSim};
use splitee::util::args::Args;
use splitee::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::from_env();
    splitee::util::logging::init(if args.has("quiet") { 0 } else { 1 });
    let settings = Settings::from_args(&args).map_err(anyhow::Error::msg)?;
    let n = args.get_num("requests", 300usize).map_err(anyhow::Error::msg)?;

    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let backend = Backend::from_name(&settings.backend)?;
    let task = manifest.source_task("imdb")?.clone();
    let model = MultiExitModel::load(&manifest, &backend, &task.name, "elasticbert")?;
    let data = Dataset::load(
        &manifest.root.join(&manifest.dataset("imdb")?.file),
        "imdb",
    )?;
    let n = n.min(data.len());

    println!("network   o(L)  best-split  offload%  outage  acc%   p50 ms   p99 ms   energy/req");
    println!("{}", "-".repeat(92));
    for profile in NetworkProfile::all() {
        let cm = CostModel::paper(profile.offload_lambda, settings.mu, model.n_layers());
        let mut link = LinkSim::new(profile, settings.seed);
        if matches!(profile.kind, splitee::cost::network::NetworkKind::ThreeG) {
            // failure injection on the worst link
            link.outage_rate = 0.05;
        }
        let mut pipeline = CoInferencePipeline::new(&model, link, cm, task.alpha);
        let mut policy = SplitEePolicy::new(model.n_layers(), task.alpha, settings.beta);
        let mut latencies = Vec::with_capacity(n);
        let mut offloads = 0usize;
        let mut outages = 0usize;
        let mut correct = 0usize;
        let mut energy = 0.0;
        for i in 0..n {
            let split = policy.choose_split();
            let trace = pipeline.serve(&data.sample_tokens(i), split, false)?;
            policy.record(split, trace.reward);
            latencies.push(trace.latency_ms);
            offloads += trace.offloaded as usize;
            outages += trace.outage_fallback as usize;
            correct += (trace.prediction as i32 == data.labels[i]) as usize;
            energy += trace.energy;
        }
        let s = Summary::of(&latencies);
        let best = policy.ucb().best_empirical() + 1;
        println!(
            "{:<8} {:>4.1}  L{:<9} {:>7.1}%  {:>6} {:>5.1}  {:>7.2}  {:>7.2}  {:>10.2}",
            format!("{:?}", profile.kind),
            profile.offload_lambda,
            best,
            100.0 * offloads as f64 / n as f64,
            outages,
            100.0 * correct as f64 / n as f64,
            s.p50,
            s.p99,
            energy / n as f64,
        );
    }
    println!(
        "\nReading: cheap links (WiFi) offload aggressively from shallow splits;\n\
         expensive links (3G, o = 5 lambda) push the bandit to deeper splits and\n\
         more on-device exits — the mechanism behind paper figures 3-6.\n\
         Outage fallbacks complete on-device at full depth (service-outage path)."
    );
    Ok(())
}

//! E2E serving driver (deliverable (e)): load the trained multi-exit model
//! and serve a stream of batched requests through the full coordinator
//! (router -> dynamic batcher -> SplitEE service -> edge/link/cloud sim),
//! reporting latency percentiles and throughput.
//!
//! ```text
//! cargo run --release --example serve_stream -- \
//!     [--dataset imdb] [--requests 500] [--network 4g] [--rate 200] \
//!     [--backend auto|reference|pjrt] [--speculate on|off|auto] \
//!     [--link static|markov|markov:SEED|trace:PATH] \
//!     [--replicas N] [--dispatch round-robin|least-loaded] \
//!     [--faults kill@B:R|slow@B:RxF|flaky@R:P[,seed=S]] \
//!     [--snapshot PATH] [--snapshot-every N] [--ref-threads N] \
//!     [--policy splitee|splitee-s|contextual|final] [--tcp 127.0.0.1:7878]
//! ```
//!
//! With `--tcp`, the concurrent TCP front-end is exposed instead of the
//! internal replay workload; send comma-separated token lines, optionally
//! preceded by a `hello {"client":NAME,"link":wifi|5g|4g|3g}` identity line
//! (see rust/src/server/).  Replies carry the request line number as `id`.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};
use splitee::config::{Manifest, Settings};
use splitee::coordinator::service::{PolicyKind, SpeculateMode};
use splitee::coordinator::{BatcherConfig, Router, RouterConfig, Service};
use splitee::cost::{CostModel, NetworkProfile};
use splitee::data::{Dataset, SampleStream};
use splitee::model::MultiExitModel;
use splitee::runtime::Backend;
use splitee::sim::{LinkScenario, LinkSim};
use splitee::util::args::Args;
use splitee::util::rng::Rng;
use splitee::util::signals;

fn main() -> Result<()> {
    let args = Args::from_env();
    splitee::util::logging::init(if args.has("quiet") { 0 } else { 1 });
    let settings = Settings::from_args(&args).map_err(anyhow::Error::msg)?;
    // size the reference backend's kernel pool before any model loads
    settings.configure_kernel_pool();

    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let backend = Backend::from_name(&settings.backend)?;
    let dataset_name = args.get_or("dataset", "imdb").to_string();
    let info = manifest.dataset(&dataset_name)?.clone();
    let task = manifest.source_task(&dataset_name)?.clone();
    let n_requests = args.get_num("requests", 500usize).map_err(anyhow::Error::msg)?;
    // mean request arrival rate (requests/s) for the open-loop workload
    let rate = args.get_num("rate", 200.0f64).map_err(anyhow::Error::msg)?;
    let network = NetworkProfile::by_name(args.get_or("network", "4g"))
        .context("--network must be wifi|5g|4g|3g")?;
    let policy = match args.get_or("policy", "splitee") {
        "splitee" => PolicyKind::SplitEe,
        "splitee-s" => PolicyKind::SplitEeS,
        "contextual" => PolicyKind::Contextual,
        "final" => PolicyKind::FinalExit,
        other => anyhow::bail!("unknown policy {other:?}"),
    };

    let model = Arc::new(MultiExitModel::load(
        &manifest, &backend, &task.name, "elasticbert",
    )?);
    let dataset = Dataset::load(&manifest.root.join(&info.file), &dataset_name)?;
    let cm = CostModel::paper(network.offload_lambda, settings.mu, model.n_layers());
    let link = LinkSim::new(network, settings.seed);
    let config = splitee::coordinator::ServiceConfig {
        policy,
        alpha: task.alpha,
        beta: settings.beta,
        batcher: BatcherConfig {
            batch_sizes: manifest.batch_sizes.clone(),
            max_wait: Duration::from_millis(5),
        },
        coalesce: Default::default(),
        speculate: SpeculateMode::from_name(&settings.speculate)?,
        link: LinkScenario::from_name(&settings.link)?,
        replicas: settings.replica_config()?,
    };

    let router = Router::new(RouterConfig { max_inflight: 256 });
    let mut service = Service::new(Arc::clone(&model), cm, link, &config);
    if let Some(snap_cfg) = settings.snapshot_config() {
        if service.restore(&snap_cfg.path) {
            println!(
                "warm restart: restored learned state from {} ({} batches served)",
                snap_cfg.path.display(),
                service.batches_done()
            );
        }
        service.set_snapshot(snap_cfg);
    }
    signals::install();

    if let Some(addr) = args.get("tcp") {
        // TCP front-end mode: compute thread + socket loop.
        let listener = std::net::TcpListener::bind(addr).context("bind")?;
        println!("listening on {addr}; protocol: comma-separated token ids per line");
        let compute = {
            let router = Arc::clone(&router);
            let bc = config.batcher.clone();
            // hand the service back so the final shutdown snapshot can be
            // written after the socket loop ends
            std::thread::spawn(move || {
                let outcome = service.run(router, bc);
                (service, outcome)
            })
        };
        let counters = splitee::server::ServerCounters::new();
        let served = splitee::server::serve_tcp(
            listener,
            Arc::clone(&router),
            model.seq_len(),
            Some(n_requests),
            splitee::server::ServerConfig::default(),
            Arc::clone(&counters),
        )?;
        router.shutdown();
        let (mut service, outcome) = compute.join().expect("compute thread");
        outcome.ok();
        service.write_snapshot();
        println!("{}", counters.snapshot());
        println!("served {served} TCP requests");
        return Ok(());
    }

    // Open-loop replay workload: Poisson arrivals at --rate requests/s.
    let producer = {
        let router = Arc::clone(&router);
        let mut rng = Rng::new(settings.seed);
        let idx: Vec<usize> =
            SampleStream::shuffled(&dataset, &mut rng).take(n_requests).collect();
        let tokens: Vec<_> = idx.iter().map(|&i| dataset.sample_tokens(i)).collect();
        let labels: Vec<i32> = idx.iter().map(|&i| dataset.labels[i]).collect();
        std::thread::spawn(move || -> (usize, usize) {
            let mut arrival_rng = Rng::new(0xA881);
            let (tx, rx) = std::sync::mpsc::channel();
            for t in tokens {
                std::thread::sleep(Duration::from_secs_f64(
                    arrival_rng.exponential(rate).min(0.05),
                ));
                if signals::interrupted() || router.submit(t, tx.clone()).is_none() {
                    break;
                }
            }
            drop(tx);
            let mut got = 0usize;
            let mut correct = 0usize;
            while let Ok(resp) = rx.recv() {
                // responses arrive in service order; match by id index
                if resp.prediction as i32 == labels[resp.id as usize] {
                    correct += 1;
                }
                got += 1;
            }
            router.shutdown();
            (got, correct)
        })
    };

    let bc = config.batcher.clone();
    service.run(Arc::clone(&router), bc)?;
    let (got, correct) = producer.join().expect("producer");
    service.write_snapshot();

    println!(
        "\n=== serve_stream report: {dataset_name}, {:?}, network {} ===",
        args.get_or("policy", "splitee"),
        args.get_or("network", "4g")
    );
    println!("{}", service.metrics.report());
    println!(
        "answered {got}/{n_requests} requests, accuracy {:.1}%",
        100.0 * correct as f64 / got.max(1) as f64
    );
    if let Some((best, _)) = service.bandit_summary() {
        println!("bandit converged toward split layer {best}");
    }
    if signals::interrupted() {
        println!("interrupted: drained {got}/{n_requests} requests before shutdown");
    } else {
        anyhow::ensure!(got == n_requests, "lost {} requests", n_requests - got);
    }
    Ok(())
}

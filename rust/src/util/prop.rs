//! Miniature property-testing driver (the offline cache has no `proptest`).
//!
//! Provides the shape the coordinator invariant tests need: generate many
//! random cases from a seeded [`Rng`], run the property, and on failure
//! report the case index + seed so the exact case replays deterministically.
//! A light "shrink" pass retries the failing case with smaller size
//! parameters when the generator supports it.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Seed is fixed for reproducibility; bump when chasing new cases.
        PropConfig { cases: 128, seed: 0x5EED_CAFE }
    }
}

/// Run `property` on `cases` random inputs produced by `gen`.
///
/// Panics with the case index and seed on the first failure.  `gen` receives
/// an rng plus a monotonically growing `size` hint in `[1, 100]` so early
/// cases are small (cheap shrinking-by-construction).
pub fn check<T, G, P>(config: PropConfig, mut gen: G, mut property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut root = Rng::new(config.seed);
    for case in 0..config.cases {
        let size = 1 + (case * 100) / config.cases.max(1);
        let mut rng = root.fork(case as u64);
        let input = gen(&mut rng, size);
        if let Err(msg) = property(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}, size {size}):\n  {msg}\n  input: {input:?}",
                config.cases, config.seed
            );
        }
    }
}

/// Shorthand with default config.
pub fn quickcheck<T, G, P>(gen: G, property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(PropConfig::default(), gen, property)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quickcheck(
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |v| {
                if v.iter().all(|&x| x < 100) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        quickcheck(
            |rng, _| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn sizes_grow() {
        let mut max_seen = 0usize;
        check(
            PropConfig { cases: 50, seed: 1 },
            |_, size| size,
            |&s| {
                if s >= max_seen {
                    max_seen = s;
                    Ok(())
                } else {
                    Ok(()) // sizes are monotone by construction; just track
                }
            },
        );
        assert!(max_seen >= 90);
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let mut first: Vec<u64> = Vec::new();
        check(
            PropConfig { cases: 10, seed: 42 },
            |rng, _| rng.next_u64(),
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<u64> = Vec::new();
        check(
            PropConfig { cases: 10, seed: 42 },
            |rng, _| rng.next_u64(),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}

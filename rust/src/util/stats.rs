//! Statistics helpers: summaries, confidence intervals, histograms.
//!
//! Used by the experiment harness (figure 7 plots mean cumulative regret with
//! a 95 % confidence interval over 20 repetitions, exactly as the paper does)
//! and by the serving metrics (latency percentiles).

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Half-width of the 95 % normal-approximation confidence interval.
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Summary of a sample: mean, std, 95 % CI, extremes, percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std_dev: std_dev(xs),
            ci95: ci95_half_width(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
        }
    }
}

/// Streaming (Welford) mean/variance accumulator — used on hot paths where
/// storing every observation would allocate (e.g. per-request latency).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for hot paths.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [base * growth^i, base * growth^(i+1))
    base_us: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl LatencyHistogram {
    /// 64 log-spaced buckets from 1 µs up to ~17 s.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            base_us: 1.0,
            growth: 1.3,
            counts: vec![0; 64],
            total: 0,
            sum_us: 0.0,
            max_us: 0.0,
        }
    }

    #[inline]
    pub fn record_us(&mut self, us: f64) {
        let idx = if us <= self.base_us {
            0
        } else {
            ((us / self.base_us).ln() / self.growth.ln()).floor() as usize
        }
        .min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate percentile: upper edge of the bucket holding quantile `q`.
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q / 100.0 * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.base_us * self.growth.powi(i as i32 + 1);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(ci95_half_width(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.5, 2.5, 3.5, 10.0, -1.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_us(50.0);
        let p90 = h.percentile_us(90.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p90 && p90 <= p99);
        // bucket edges are approximate: p50 should be within a growth factor
        assert!(p50 > 300.0 && p50 < 900.0, "p50 {p50}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max_us() >= 1000.0);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let few: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let many: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half_width(&many) < ci95_half_width(&few));
    }
}

//! `log`-crate backend: leveled stderr logger with elapsed-time stamps.
//!
//! Installed once by the binary entrypoints (`main.rs`, examples, benches).
//! Library code only ever uses the `log` macros, so embedders can swap in
//! their own backend.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger.  `verbosity`: 0 = warn, 1 = info, 2 = debug, 3+ = trace.
/// Safe to call more than once (subsequent calls only adjust the level).
pub fn init(verbosity: u8) {
    let level = match verbosity {
        0 => LevelFilter::Warn,
        1 => LevelFilter::Info,
        2 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(1);
        init(2);
        assert_eq!(log::max_level(), LevelFilter::Debug);
        log::info!("logger smoke test");
        init(0);
        assert_eq!(log::max_level(), LevelFilter::Warn);
    }
}

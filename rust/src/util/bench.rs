//! Custom benchmark harness (the offline cache has no `criterion`).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```text
//!     let mut b = BenchSuite::new("policies");
//!     b.bench("splitee_decide", 1_000, 100_000, || { ... });
//!     b.finish();   // prints a table, saves + diffs vs the saved baseline
//! ```
//!
//! Results are written to `results/bench_<suite>.json`; the next run prints
//! the delta against the stored baseline so the perf pass (EXPERIMENTS.md
//! section Perf) can track iteration-by-iteration changes.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::stats;

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// optional throughput annotation (items per iteration)
    pub items_per_iter: Option<f64>,
}

/// A suite of benchmarks with baseline diffing.
pub struct BenchSuite {
    suite: String,
    results: Vec<BenchResult>,
    baseline_path: PathBuf,
    baseline: Option<Json>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> BenchSuite {
        let dir = std::env::var("SPLITEE_RESULTS").unwrap_or_else(|_| "results".into());
        let baseline_path = PathBuf::from(dir).join(format!("bench_{suite}.json"));
        let baseline = std::fs::read_to_string(&baseline_path)
            .ok()
            .and_then(|s| json::parse(&s).ok());
        println!("== bench suite: {suite} ==");
        BenchSuite { suite: suite.to_string(), results: Vec::new(), baseline_path, baseline }
    }

    /// Time `f` over `iters` iterations after `warmup` warmup iterations.
    /// Batched timing (one clock read per iteration) — fine at the >1 µs
    /// granularity of everything we measure.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: u64, iters: u64, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        self.push(name, iters, samples, None);
    }

    /// Like [`bench`], annotating each iteration as processing `items` items
    /// (reports items/s).
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: u64,
        iters: u64,
        items: f64,
        mut f: F,
    ) {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        self.push(name, iters, samples, Some(items));
    }

    fn push(&mut self, name: &str, iters: u64, samples: Vec<f64>, items: Option<f64>) {
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p99_ns: stats::percentile(&samples, 99.0),
            items_per_iter: items,
        };
        let base = self
            .baseline
            .as_ref()
            .and_then(|b| b.opt(name))
            .and_then(|e| e.get("mean_ns").ok().and_then(|v| v.as_f64().ok()));
        let delta = match base {
            Some(b) if b > 0.0 => format!(" ({:+.1}% vs baseline)", 100.0 * (r.mean_ns / b - 1.0)),
            _ => String::new(),
        };
        let thr = items
            .map(|it| format!("  {:>10.0} items/s", it / (r.mean_ns / 1e9)))
            .unwrap_or_default();
        println!(
            "  {:<32} mean {}  p50 {}  p99 {}{thr}{delta}",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
        );
        self.results.push(r);
    }

    /// Print a footer and persist the results as the new baseline.
    pub fn finish(self) {
        let mut obj = std::collections::BTreeMap::new();
        for r in &self.results {
            let mut e = vec![
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p50_ns", Json::Num(r.p50_ns)),
                ("p99_ns", Json::Num(r.p99_ns)),
                ("iters", Json::Num(r.iters as f64)),
            ];
            if let Some(it) = r.items_per_iter {
                e.push(("items_per_iter", Json::Num(it)));
            }
            obj.insert(r.name.clone(), Json::obj(e));
        }
        if let Some(dir) = self.baseline_path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = std::fs::write(&self.baseline_path, Json::Obj(obj).to_string()) {
            eprintln!("warning: could not save baseline: {e}");
        }
        println!(
            "== {} done: {} benchmarks, baseline {} ==",
            self.suite,
            self.results.len(),
            self.baseline_path.display()
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:7.1} ns")
    } else if ns < 1e6 {
        format!("{:7.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:7.2} ms", ns / 1e6)
    } else {
        format!("{:7.2} s ", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_persists() {
        std::env::set_var("SPLITEE_RESULTS", std::env::temp_dir().join("splitee_bench_test").to_str().unwrap());
        let mut suite = BenchSuite::new("selftest");
        let mut x = 0u64;
        suite.bench("noop_loop", 10, 50, || {
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(suite.results().len(), 1);
        assert!(suite.results()[0].mean_ns > 0.0);
        suite.finish();
        // second run sees the baseline
        let suite2 = BenchSuite::new("selftest");
        assert!(suite2.baseline.is_some());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}

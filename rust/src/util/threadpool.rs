//! Fixed-size thread pool with a shared injector queue.
//!
//! The offline cache has no `tokio`; the coordinator's event loop and the
//! experiment fan-out run on this pool instead (DESIGN.md section 2).  The pool
//! is deliberately simple: one `Mutex<VecDeque>` + `Condvar` injector.  On
//! this single-core testbed the queue is never contended enough to justify
//! work-stealing; the abstraction still lets multi-core machines parallelise
//! experiment repetitions.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// A fixed-size worker pool.  Dropping the pool joins all workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (at least 1).
    pub fn new(n: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("splitee-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (parallelism-1, min 1).
    pub fn for_host() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Map `f` over `items` on the pool, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..items.len()).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.submit(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("map results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job dropped"))
            .collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                queue = shared.available.wait(queue).unwrap();
            }
        };
        job();
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = shared.done_lock.lock().unwrap();
            shared.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn reuse_after_wait() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}

//! Fixed-size thread pool with a shared injector queue.
//!
//! The offline cache has no `tokio`; the coordinator's event loop and the
//! experiment fan-out run on this pool instead (DESIGN.md section 2).  The pool
//! is deliberately simple: one `Mutex<VecDeque>` + `Condvar` injector.  On
//! this single-core testbed the queue is never contended enough to justify
//! work-stealing; the abstraction still lets multi-core machines parallelise
//! experiment repetitions.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Identity of the pool this thread is a worker of (the address of its
    /// `Shared` block), or 0 for threads that are not pool workers.  Lets
    /// [`ThreadPool::scope_map`] detect re-entrant calls from its own
    /// workers and fall back to running inline instead of starving itself.
    static WORKER_OF: Cell<usize> = const { Cell::new(0) };
}

/// Process-wide shared pool, sized to the host, created on first use.
/// Experiment fan-out (`run_policy_repeated`) borrows caches and cost models
/// from the caller's stack, so it goes through [`ThreadPool::scope_map`] on
/// this pool instead of spinning up threads per call.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::for_host)
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// A fixed-size worker pool.  Dropping the pool joins all workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (at least 1).
    pub fn new(n: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("splitee-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (parallelism-1, min 1).
    pub fn for_host() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Map `f` over `items` on the pool, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.scope_map(items, f)
    }

    /// Like [`ThreadPool::map`], but the items, results and closure may
    /// borrow from the caller's scope (non-`'static`).  Preserves item
    /// order.  Blocks until every submitted job has finished before
    /// returning — that barrier is what makes lending borrowed data to the
    /// worker threads sound.
    ///
    /// Calling this from a worker thread of the *same* pool is safe: the
    /// call is detected and runs the whole map inline on the caller (a
    /// worker that submitted jobs and then blocked on the barrier would
    /// starve itself — it *is* the thread that was supposed to drain the
    /// queue).  Nesting across different pools parallelizes normally.
    ///
    /// A job that panics is reported here as a "job panicked" panic after
    /// the barrier (the worker survives; see `worker_loop`).
    pub fn scope_map<'env, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Send + Sync + 'env,
    {
        if WORKER_OF.with(|w| w.get()) == Arc::as_ptr(&self.shared) as usize {
            // re-entrant call from one of our own workers: run inline
            return items.into_iter().map(f).collect();
        }
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..items.len()).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
            // SAFETY: lifetime erasure only — the layouts are identical.
            // `wait_idle` below does not return until every job submitted
            // here has been consumed (run to completion or unwound — the
            // worker decrements `in_flight` either way and the job's
            // captures are dropped during unwinding), so nothing captured
            // by `job` outlives this call.  Self-pool re-entrancy (a worker
            // submitting and then blocking on its own barrier) is excluded
            // by the inline fallback above.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
            };
            self.submit(job);
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("scope_map results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job panicked"))
            .collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    WORKER_OF.with(|w| w.set(Arc::as_ptr(&shared) as usize));
    loop {
        let job = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = queue.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                queue = shared.available.wait(queue).unwrap();
            }
        };
        // A panicking job must not wedge the pool: catch the unwind so the
        // worker survives and `in_flight` is still decremented (otherwise
        // every later `wait_idle` on the shared global() pool would hang
        // forever).  map/scope_map surface the failure as a "job panicked"
        // panic from the empty result slot; fire-and-forget submits log it.
        if let Err(panic) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            log::error!("thread-pool job panicked: {msg}");
        }
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = shared.done_lock.lock().unwrap();
            shared.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn reuse_after_wait() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn panicking_job_does_not_wedge_the_pool() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        pool.wait_idle(); // must return, not hang
        // the worker survived and the pool still does work
        let out = pool.scope_map(vec![1u64, 2], |x| x * 2);
        assert_eq!(out, vec![2, 4]);
    }

    #[test]
    #[should_panic(expected = "job panicked")]
    fn scope_map_surfaces_job_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.scope_map(vec![0u64], |_| -> u64 { panic!("inner failure") });
    }

    #[test]
    fn scope_map_from_own_worker_runs_inline_instead_of_deadlocking() {
        // 1 worker makes the old failure mode deterministic: the worker
        // submits jobs only it could run, then blocks on the barrier —
        // forever.  The inline fallback must complete the map instead.
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = std::sync::mpsc::channel();
        let inner_pool = Arc::clone(&pool);
        pool.submit(move || {
            let out = inner_pool.scope_map(vec![1u64, 2, 3], |x| x * 2);
            tx.send(out).unwrap();
        });
        let out = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("re-entrant scope_map must run inline, not deadlock");
        assert_eq!(out, vec![2, 4, 6]);
        pool.wait_idle();
    }

    #[test]
    fn scope_map_across_different_pools_still_parallelizes() {
        // nesting pools (global experiment pool -> kernel pool) is the
        // supported pattern: a worker of pool A fanning out on pool B takes
        // the normal submit path and B's workers do the work
        let a = Arc::new(ThreadPool::new(1));
        let b = Arc::new(ThreadPool::new(2));
        let (tx, rx) = std::sync::mpsc::channel();
        let b2 = Arc::clone(&b);
        a.submit(move || {
            let out = b2.scope_map((0..16u64).collect::<Vec<_>>(), |x| x + 1);
            tx.send(out).unwrap();
        });
        let out = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("cross-pool nesting must complete");
        assert_eq!(out, (1..=16).collect::<Vec<u64>>());
        a.wait_idle();
    }

    #[test]
    fn scope_map_borrows_local_data() {
        // the closure and results borrow stack data — allowed by scope_map's
        // completion barrier
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let slice = &data[..];
        let out = pool.scope_map((0..100usize).collect::<Vec<_>>(), |i| slice[i] * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn global_pool_is_shared_and_usable() {
        let out = global().scope_map(vec![1u64, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        assert!(global().worker_count() >= 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}

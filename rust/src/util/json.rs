//! Minimal JSON parser / writer.
//!
//! The offline crate cache carries no `serde`/`serde_json`, so this module
//! provides the subset of JSON the project needs: the artifact manifest,
//! experiment reports, bench baselines, the TCP wire protocol and test
//! fixtures.  It is a strict recursive-descent parser over UTF-8 with the
//! usual escape handling; fractional/signed numbers are kept as `f64`, while
//! plain non-negative integer literals stay exact u64 ([`Json::UInt`]) so
//! request ids above 2^53 survive a round trip (tensor payloads travel in
//! the binary formats, never JSON).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Exact non-negative integer.  `Num`'s f64 payload silently rounds
    /// integers above 2^53 (request ids are u64), so integer literals that
    /// fit u64 parse into this variant and [`Json::uint`] constructs it —
    /// both sides of a round trip keep all 64 bits.
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// `UInt` and `Num` compare numerically (`UInt(42) == Num(42.0)`): the
/// parser now yields `UInt` for plain integer literals, and callers that
/// built the same value as `Num` must still compare equal.
impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::UInt(a), Json::UInt(b)) => a == b,
            (Json::Num(n), Json::UInt(u)) | (Json::UInt(u), Json::Num(n)) => {
                *n >= 0.0 && n.fract() == 0.0 && *n == *u as f64
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

/// Parse or access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json access error: {0}")]
    Access(String),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            // lossy above 2^53 — exact consumers go through as_u64
            Json::UInt(u) => Ok(*u as f64),
            other => Err(JsonError::Access(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    /// Exact u64 access: `UInt` verbatim, or a `Num` that is a non-negative
    /// integer small enough (< 2^53) for f64 to have represented exactly.
    pub fn as_u64(&self) -> Result<u64, JsonError> {
        match self {
            Json::UInt(u) => Ok(*u),
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9_007_199_254_740_992.0 => {
                Ok(*n as u64)
            }
            other => Err(JsonError::Access(format!("expected exact u64, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let v = self.as_f64()?;
        if v < 0.0 {
            return Err(JsonError::Access(format!("expected usize, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Access(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Access(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Access(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Access(format!("expected object, got {other:?}"))),
        }
    }

    /// Field access on an object: `v.get("key")?`.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key {key:?}")))
    }

    /// Optional field access: `None` if the key is absent or null.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => match o.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_str(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Str(v.to_string())).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::UInt(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

// ---------------- parsing ----------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document from a string.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.pos, msg.to_string())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the sequence verbatim.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        // plain non-negative integer literals keep all 64 bits (request ids
        // above 2^53 would round through f64); everything else stays f64
        if !text.starts_with('-') && !text.contains(['.', 'e', 'E']) {
            if let Ok(u) = text.parse::<u64>() {
                return Ok(Json::UInt(u));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number {text:?}: {e}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ---------------- writing ----------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::UInt(u) => write!(f, "{u}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Write `contents` to `path` atomically: write to `<path>.tmp`, fsync,
/// rename over the destination.  A crash at any byte leaves either the old
/// file intact or a stray `.tmp` — never a truncated destination.  Used by
/// snapshot persistence and the bench JSON emitter.
pub fn write_atomic(path: &std::path::Path, contents: &str) -> std::io::Result<()> {
    use std::io::Write;
    let tmp = {
        let mut os = path.as_os_str().to_owned();
        os.push(".tmp");
        std::path::PathBuf::from(os)
    };
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
    }
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            // don't leave the orphaned tmp behind a failed rename
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"alpha":0.84,"arr":[1,2.5,"s"],"flag":true,"nested":{"x":null}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn display_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn accessors_report_errors() {
        let v = parse("{\"k\": 1}").unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("k").unwrap().as_str().is_err());
        assert!(v.as_arr().is_err());
        assert!(v.opt("missing").is_none());
        assert!(v.opt("k").is_some());
    }

    #[test]
    fn parser_never_panics_on_arbitrary_input() {
        // robustness property: any byte soup either parses or errors cleanly
        crate::util::prop::quickcheck(
            |rng: &mut crate::util::rng::Rng, size| {
                let n = rng.range(0, size * 4 + 2);
                let charset: &[u8] = b"{}[]\",:0123456789.eE+-truefalsnl \n\t\\";
                (0..n)
                    .map(|_| charset[rng.range(0, charset.len())] as char)
                    .collect::<String>()
            },
            |input| {
                let _ = parse(input); // must not panic
                Ok(())
            },
        );
    }

    #[test]
    fn parse_roundtrip_property() {
        // generated values survive to_string -> parse exactly
        crate::util::prop::quickcheck(
            |rng: &mut crate::util::rng::Rng, size| gen_value(rng, size.min(20), 0),
            |v| {
                let back = parse(&v.to_string()).map_err(|e| e.to_string())?;
                if &back != v {
                    return Err(format!("{v} != {back}"));
                }
                Ok(())
            },
        );
    }

    fn gen_value(rng: &mut crate::util::rng::Rng, size: usize, depth: usize) -> Json {
        let choices = if depth > 3 { 4 } else { 6 };
        match rng.below(choices) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.below(1_000_000) as f64) / 4.0),
            3 => Json::Str((0..rng.range(0, 8)).map(|_| {
                let cs = b"ab\"\\\n d";
                cs[rng.range(0, cs.len())] as char
            }).collect()),
            4 => Json::Arr((0..rng.range(0, size.min(4) + 1))
                .map(|_| gen_value(rng, size / 2, depth + 1))
                .collect()),
            _ => {
                let mut obj = std::collections::BTreeMap::new();
                for i in 0..rng.range(0, size.min(4) + 1) {
                    obj.insert(format!("k{i}"), gen_value(rng, size / 2, depth + 1));
                }
                Json::Obj(obj)
            }
        }
    }

    #[test]
    fn integer_display_is_exact() {
        assert_eq!(Json::Num(25000.0).to_string(), "25000");
        assert_eq!(Json::Num(0.1).to_string(), "0.1");
    }

    #[test]
    fn u64_round_trips_exactly_at_the_boundary() {
        // above 2^53 an f64 path silently rounds; UInt must not
        for v in [u64::MAX, u64::MAX - 1, (1u64 << 53) + 1, 0] {
            let line = Json::UInt(v).to_string();
            let back = parse(&line).unwrap();
            assert_eq!(back.as_u64().unwrap(), v, "lost bits in {line}");
        }
        // f64 would have collapsed these two onto the same value
        assert_ne!(
            parse("18446744073709551615").unwrap().as_u64().unwrap(),
            parse("18446744073709551614").unwrap().as_u64().unwrap(),
        );
    }

    #[test]
    fn uint_and_num_compare_numerically() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::UInt(42), Json::Num(42.0));
        assert_ne!(Json::UInt(42), Json::Num(42.5));
        assert_ne!(Json::UInt(1), Json::Num(-1.0));
        // exact accessor rejects values f64 cannot have held exactly
        assert!(Json::Num(9.1e15).as_u64().is_err());
        assert_eq!(Json::Num(42.0).as_u64().unwrap(), 42);
        // lossy widening is still available for stats-style consumers
        assert_eq!(Json::UInt(3).as_f64().unwrap(), 3.0);
        assert_eq!(Json::UInt(7).as_usize().unwrap(), 7);
        // oversized integers with a sign or exponent stay on the f64 path
        assert!(matches!(parse("1e3").unwrap(), Json::Num(_)));
        assert!(matches!(parse("-42").unwrap(), Json::Num(_)));
        // an integer literal too big even for u64 falls back to f64
        assert!(matches!(parse("99999999999999999999999").unwrap(), Json::Num(_)));
    }

    #[test]
    fn write_atomic_replaces_and_cleans_up() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("splitee_json_atomic_{}.json", std::process::id()));
        write_atomic(&path, "{\"v\":1}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"v\":1}");
        write_atomic(&path, "{\"v\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"v\":2}");
        // no temp file survives a successful write
        let mut tmp_os = path.as_os_str().to_owned();
        tmp_os.push(".tmp");
        assert!(!std::path::PathBuf::from(tmp_os).exists());
        std::fs::remove_file(&path).unwrap();
    }
}

//! Deterministic pseudo-random generators (xoshiro256** + SplitMix64).
//!
//! The offline crate cache has no `rand`, so experiments, the workload
//! generators and the property-test driver use this module.  xoshiro256** is
//! the same generator family `rand`'s `SmallRng` uses; SplitMix64 seeds it.
//! Everything is reproducible from a single `u64` seed — experiment reports
//! record the seed so every table/figure can be regenerated bit-identically.

/// SplitMix64: stateless stream used to expand one seed into many.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-repetition / per-worker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// The raw 256-bit generator state — snapshot/restore support.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact saved state.  xoshiro's one illegal
    /// state (all zeros, a fixed point) can only come from a corrupted
    /// snapshot, so it falls back to a freshly-seeded generator.
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s == [0, 0, 0, 0] {
            return Rng::new(0);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // widening multiply keeps the bias below 2^-64 for any n we use
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`) — arrival processes.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy_arm() {
        let mut r = Rng::new(17);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0]);
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(0xD1CE);
        for _ in 0..37 {
            a.next_u64();
        }
        let saved = a.state();
        let tail: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(saved);
        let resumed: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn all_zero_state_falls_back_to_a_working_generator() {
        let mut r = Rng::from_state([0; 4]);
        // the all-zero xoshiro state is a fixed point; the fallback must not be
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn permutation_covers_all() {
        let mut r = Rng::new(23);
        let p = r.permutation(50);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}

//! Infrastructure substrates built in-repo because the offline crate cache
//! only carries the `xla` dependency closure (see DESIGN.md section 2):
//! JSON, PRNG, CLI args, thread pool, statistics, logging, property testing.

pub mod args;
pub mod bench;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod signals;
pub mod stats;
pub mod threadpool;

//! Graceful-shutdown signal latch (SIGINT/SIGTERM), dependency-free.
//!
//! The serving entry points install this once, then poll [`interrupted`]
//! between pipeline drains: on Ctrl-C or a supervisor's TERM the in-flight
//! work finishes, a final durable-state snapshot is written, and the metrics
//! report still prints — instead of the process dying mid-batch with
//! whatever the last periodic snapshot happened to capture.
//!
//! Implementation notes: the handler only stores into a static
//! `AtomicBool` (async-signal-safe); registration goes through the C
//! `signal()` entry point directly because the in-repo dependency policy
//! rules out the `libc`/`signal-hook` crates.  On non-unix targets
//! [`install`] is a no-op and [`interrupted`] stays `false` forever.

use std::sync::atomic::{AtomicBool, Ordering};

static INTERRUPTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    pub(super) const SIGINT: i32 = 2;
    pub(super) const SIGTERM: i32 = 15;

    extern "C" {
        /// POSIX `signal(2)`: the handler slot is pointer-sized, so the
        /// previous disposition comes back as a `usize` we ignore.
        pub(super) fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub(super) extern "C" fn on_signal(_sig: i32) {
        super::INTERRUPTED.store(true, std::sync::atomic::Ordering::SeqCst);
    }
}

/// Install the SIGINT/SIGTERM latch.  Idempotent; later signals of either
/// kind set the same flag.  The latch stays installed for the process
/// lifetime (repeat Ctrl-C does not force-kill; SIGKILL remains the
/// escape hatch), keeping drain semantics predictable.
pub fn install() {
    #[cfg(unix)]
    unsafe {
        imp::signal(imp::SIGINT, imp::on_signal);
        imp::signal(imp::SIGTERM, imp::on_signal);
    }
}

/// True once any installed signal has fired.
pub fn interrupted() -> bool {
    INTERRUPTED.load(Ordering::SeqCst)
}

#[cfg(all(test, unix))]
mod tests {
    extern "C" {
        fn raise(sig: i32) -> i32;
    }

    #[test]
    fn sigint_latches_the_flag() {
        super::install();
        // raise(2) delivers SIGINT to this thread synchronously; with the
        // latch installed the process survives and the flag flips
        unsafe {
            raise(super::imp::SIGINT);
        }
        assert!(super::interrupted());
    }
}

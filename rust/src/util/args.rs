//! Tiny command-line argument parser (the offline cache has no `clap`).
//!
//! Supports the subcommand + flags shape the `splitee` binary uses:
//!
//! ```text
//! splitee <subcommand> [--flag value] [--switch] [positional ...]
//! ```
//!
//! Flags may be `--name value` or `--name=value`.  Unknown flags are
//! collected so each subcommand can validate against its own schema and
//! print a helpful error.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Option<Result<T, String>>
    where
        T::Err: std::fmt::Display,
    {
        self.get(key)
            .map(|s| s.parse::<T>().map_err(|e| format!("--{key} {s:?}: {e}")))
    }

    /// Typed flag with default; malformed values are an error.
    pub fn get_num<T: std::str::FromStr + Copy>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get_parse::<T>(key) {
            None => Ok(default),
            Some(r) => r,
        }
    }

    /// Comma-separated list flag, e.g. `--datasets imdb,yelp`.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table2", "--reps", "20", "--verbose", "--out=results"]);
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.get("reps"), Some("20"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_parsing() {
        let a = parse(&["x", "--mu", "0.1", "--reps", "20"]);
        assert_eq!(a.get_num::<f64>("mu", 0.5).unwrap(), 0.1);
        assert_eq!(a.get_num::<usize>("reps", 1).unwrap(), 20);
        assert_eq!(a.get_num::<usize>("absent", 7).unwrap(), 7);
    }

    #[test]
    fn malformed_number_is_error() {
        let a = parse(&["x", "--mu", "abc"]);
        assert!(a.get_num::<f64>("mu", 0.5).is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse(&["x", "--datasets", "imdb, yelp,qqp"]);
        assert_eq!(a.get_list("datasets").unwrap(), vec!["imdb", "yelp", "qqp"]);
        assert!(a.get_list("absent").is_none());
    }

    #[test]
    fn positionals_follow_subcommand() {
        let a = parse(&["serve", "input.bin", "out.bin", "--port", "9000"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["input.bin", "out.bin"]);
        assert_eq!(a.get("port"), Some("9000"));
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse(&["run", "--fast"]);
        assert!(a.has("fast"));
        assert!(a.flags.is_empty());
    }

    #[test]
    fn empty_args() {
        let a = parse(&[]);
        assert!(a.subcommand.is_none());
        assert!(a.positional.is_empty());
    }
}

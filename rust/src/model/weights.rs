//! Trained-weights loader (SPLW binary format written by
//! `python/compile/export.py` — keep the layout in sync).
//!
//! Format (little-endian):
//!
//! ```text
//!     u32 magic = 0x53504C57 ("SPLW")    u32 version = 1
//!     u32 n_tensors
//!     per tensor:
//!         u16 name_len, name bytes (utf-8)
//!         u8 dtype (0 = f32, 1 = i32)
//!         u8 ndim, u32 dims[ndim]
//!         raw data (numel * 4 bytes)
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt};

use crate::tensor::TensorF32;

pub const WEIGHTS_MAGIC: u32 = 0x53504C57;
pub const FORMAT_VERSION: u32 = 1;

/// Parameter argument order of one transformer block — must match
/// `python/compile/common.py::BLOCK_PARAM_ORDER`.
pub const BLOCK_PARAM_ORDER: [&str; 16] = [
    "ln1_g", "ln1_b",
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b",
    "w1", "b1", "w2", "b2",
];

/// Exit-head argument order — must match `HEAD_PARAM_ORDER`.
pub const HEAD_PARAM_ORDER: [&str; 4] = ["ln_g", "ln_b", "wc", "bc"];

/// Embedding argument order — must match `EMBED_PARAM_ORDER`.
pub const EMBED_PARAM_ORDER: [&str; 4] = ["tok", "pos", "ln_g", "ln_b"];

/// All parameters of one trained multi-exit model, pre-sliced into the
/// argument lists each compiled graph expects.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub n_layers: usize,
    pub n_classes: usize,
    /// embed graph args, canonical order
    pub embed: Vec<TensorF32>,
    /// block graph args per layer, canonical order
    pub blocks: Vec<Vec<TensorF32>>,
    /// head graph args per layer, canonical order
    pub heads: Vec<Vec<TensorF32>>,
}

impl ModelWeights {
    /// Load from a SPLW file.  `n_layers` comes from the manifest.
    pub fn load(path: &Path, n_layers: usize) -> Result<ModelWeights> {
        let raw = read_raw(path)?;
        Self::from_map(raw, n_layers)
    }

    fn from_map(mut raw: BTreeMap<String, TensorF32>, n_layers: usize) -> Result<ModelWeights> {
        let mut take = |name: String| -> Result<TensorF32> {
            raw.remove(&name)
                .with_context(|| format!("weights file missing tensor {name:?}"))
        };
        let embed = EMBED_PARAM_ORDER
            .iter()
            .map(|k| take(format!("embed.{k}")))
            .collect::<Result<Vec<_>>>()?;
        let mut blocks = Vec::with_capacity(n_layers);
        let mut heads = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            blocks.push(
                BLOCK_PARAM_ORDER
                    .iter()
                    .map(|k| take(format!("block{i}.{k}")))
                    .collect::<Result<Vec<_>>>()?,
            );
            heads.push(
                HEAD_PARAM_ORDER
                    .iter()
                    .map(|k| take(format!("head{i}.{k}")))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        if !raw.is_empty() {
            bail!(
                "weights file has {} unexpected tensors (e.g. {:?}) — wrong n_layers?",
                raw.len(),
                raw.keys().next()
            );
        }
        // n_classes from the classifier shape [D, C]
        let wc = &heads[0][2];
        if wc.ndim() != 2 {
            bail!("head0.wc must be 2-D, got shape {:?}", wc.shape());
        }
        let n_classes = wc.shape()[1];
        Ok(ModelWeights { n_layers, n_classes, embed, blocks, heads })
    }

    /// Argument assembly for a fused `chain{n}` block-range graph covering
    /// layers `start..end` (0-based, end exclusive): each layer's parameters
    /// in canonical [`BLOCK_PARAM_ORDER`], layers in ascending order —
    /// exactly the positional order `python/compile/model.py::chain_fn`
    /// lowers with.
    pub fn block_range_args(&self, start: usize, end: usize) -> impl Iterator<Item = &TensorF32> {
        self.blocks[start..end].iter().flat_map(|b| b.iter())
    }

    /// Deterministic synthetic weights: a real (randomly initialized)
    /// multi-exit encoder of the given geometry, for tests and benches that
    /// must run without trained artifacts.  LayerNorm gains start at 1 /
    /// biases at 0 and matrices scale with 1/sqrt(fan_in), so activations
    /// and exit confidences stay in a realistic range at any depth.
    pub fn synthetic(
        n_layers: usize,
        d_model: usize,
        d_ff: usize,
        vocab: usize,
        seq_len: usize,
        n_classes: usize,
        seed: u64,
    ) -> ModelWeights {
        use crate::util::rng::Rng;

        fn mat(rng: &mut Rng, rows: usize, cols: usize) -> TensorF32 {
            let scale = 1.0 / (rows as f32).sqrt();
            let data = (0..rows * cols)
                .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
                .collect();
            TensorF32::new(vec![rows, cols], data).expect("synthetic matrix")
        }
        fn small(rng: &mut Rng, n: usize) -> TensorF32 {
            let data = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * 0.05).collect();
            TensorF32::new(vec![n], data).expect("synthetic bias")
        }
        fn ones(n: usize) -> TensorF32 {
            TensorF32::new(vec![n], vec![1.0; n]).expect("ln gain")
        }

        let mut rng = Rng::new(seed ^ 0x5EED_5157);
        let r = &mut rng;
        let embed = vec![
            mat(r, vocab, d_model),
            mat(r, seq_len, d_model),
            ones(d_model),
            TensorF32::zeros(vec![d_model]),
        ];
        let mut blocks = Vec::with_capacity(n_layers);
        let mut heads = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            blocks.push(vec![
                ones(d_model),             // ln1_g
                TensorF32::zeros(vec![d_model]), // ln1_b
                mat(r, d_model, d_model),  // wq
                small(r, d_model),         // bq
                mat(r, d_model, d_model),  // wk
                small(r, d_model),         // bk
                mat(r, d_model, d_model),  // wv
                small(r, d_model),         // bv
                mat(r, d_model, d_model),  // wo
                small(r, d_model),         // bo
                ones(d_model),             // ln2_g
                TensorF32::zeros(vec![d_model]), // ln2_b
                mat(r, d_model, d_ff),     // w1
                small(r, d_ff),            // b1
                mat(r, d_ff, d_model),     // w2
                small(r, d_model),         // b2
            ]);
            heads.push(vec![
                ones(d_model),              // ln_g
                TensorF32::zeros(vec![d_model]), // ln_b
                mat(r, d_model, n_classes), // wc
                small(r, n_classes),        // bc
            ]);
        }
        ModelWeights { n_layers, n_classes, embed, blocks, heads }
    }

    /// Flat argument list for the `prefix_full` graph: embed params, then all
    /// block params, then all head params (matches the AOT flat order).
    pub fn prefix_full_args(&self) -> Vec<&TensorF32> {
        let mut out: Vec<&TensorF32> = self.embed.iter().collect();
        for b in &self.blocks {
            out.extend(b.iter());
        }
        for h in &self.heads {
            out.extend(h.iter());
        }
        out
    }
}

/// Read the raw name -> tensor map (f32 only; the format also allows i32 but
/// model weights are all f32).
pub fn read_raw(path: &Path) -> Result<BTreeMap<String, TensorF32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading weights {path:?}"))?;
    let mut r = std::io::Cursor::new(&bytes);
    let magic = r.read_u32::<LittleEndian>().context("magic")?;
    if magic != WEIGHTS_MAGIC {
        bail!("{path:?}: bad magic {magic:#x} (expected SPLW)");
    }
    let version = r.read_u32::<LittleEndian>()?;
    if version != FORMAT_VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let n = r.read_u32::<LittleEndian>()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = r.read_u16::<LittleEndian>()? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name utf-8")?;
        let dtype = r.read_u8()?;
        if dtype != 0 {
            bail!("{path:?}: tensor {name:?} has non-f32 dtype {dtype}");
        }
        let ndim = r.read_u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.read_u32::<LittleEndian>()? as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = vec![0f32; numel];
        r.read_f32_into::<LittleEndian>(&mut data)
            .with_context(|| format!("tensor {name:?} data truncated"))?;
        out.insert(
            name,
            TensorF32::new(dims, data).map_err(|e| anyhow::anyhow!(e))?,
        );
    }
    if (r.position() as usize) != bytes.len() {
        bail!("{path:?}: {} trailing bytes", bytes.len() - r.position() as usize);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use byteorder::WriteBytesExt;
    use std::io::Write;

    fn write_tensor(buf: &mut Vec<u8>, name: &str, dims: &[u32], data: &[f32]) {
        buf.write_u16::<LittleEndian>(name.len() as u16).unwrap();
        buf.write_all(name.as_bytes()).unwrap();
        buf.write_u8(0).unwrap();
        buf.write_u8(dims.len() as u8).unwrap();
        for &d in dims {
            buf.write_u32::<LittleEndian>(d).unwrap();
        }
        for &v in data {
            buf.write_f32::<LittleEndian>(v).unwrap();
        }
    }

    fn tiny_weights_file(n_layers: usize, classes: usize) -> Vec<u8> {
        let d = 4usize;
        let f = 8usize;
        let mut body = Vec::new();
        let mut count = 0u32;
        let mut emit = |name: String, dims: Vec<u32>| {
            let numel: usize = dims.iter().map(|&x| x as usize).product();
            write_tensor(&mut body, &name, &dims, &vec![0.5; numel]);
            count += 1;
        };
        emit("embed.tok".into(), vec![16, d as u32]);
        emit("embed.pos".into(), vec![8, d as u32]);
        emit("embed.ln_g".into(), vec![d as u32]);
        emit("embed.ln_b".into(), vec![d as u32]);
        for i in 0..n_layers {
            for k in BLOCK_PARAM_ORDER {
                let dims = match k {
                    "wq" | "wk" | "wv" | "wo" => vec![d as u32, d as u32],
                    "w1" => vec![d as u32, f as u32],
                    "w2" => vec![f as u32, d as u32],
                    "b1" => vec![f as u32],
                    _ => vec![d as u32],
                };
                emit(format!("block{i}.{k}"), dims);
            }
            for k in HEAD_PARAM_ORDER {
                let dims = match k {
                    "wc" => vec![d as u32, classes as u32],
                    "bc" => vec![classes as u32],
                    _ => vec![d as u32],
                };
                emit(format!("head{i}.{k}"), dims);
            }
        }
        let mut file = Vec::new();
        file.write_u32::<LittleEndian>(WEIGHTS_MAGIC).unwrap();
        file.write_u32::<LittleEndian>(FORMAT_VERSION).unwrap();
        file.write_u32::<LittleEndian>(count).unwrap();
        file.extend_from_slice(&body);
        file
    }

    fn temp_file(bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!(
            "splitee_w_{}_{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn load_valid_file() {
        let path = temp_file(&tiny_weights_file(2, 3));
        let w = ModelWeights::load(&path, 2).unwrap();
        assert_eq!(w.n_layers, 2);
        assert_eq!(w.n_classes, 3);
        assert_eq!(w.embed.len(), 4);
        assert_eq!(w.blocks.len(), 2);
        assert_eq!(w.blocks[0].len(), 16);
        assert_eq!(w.heads[1].len(), 4);
        assert_eq!(w.prefix_full_args().len(), 4 + 2 * 16 + 2 * 4);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn block_range_args_cover_layers_in_order() {
        let path = temp_file(&tiny_weights_file(2, 3));
        let w = ModelWeights::load(&path, 2).unwrap();
        let full: Vec<&TensorF32> = w.block_range_args(0, 2).collect();
        assert_eq!(full.len(), 2 * BLOCK_PARAM_ORDER.len());
        // same references, same order, as walking the per-layer tables
        let manual: Vec<&TensorF32> =
            w.blocks.iter().flat_map(|b| b.iter()).collect();
        for (a, b) in full.iter().zip(&manual) {
            assert!(std::ptr::eq(*a, *b));
        }
        let tail: Vec<&TensorF32> = w.block_range_args(1, 2).collect();
        assert_eq!(tail.len(), BLOCK_PARAM_ORDER.len());
        assert!(std::ptr::eq(tail[0], manual[BLOCK_PARAM_ORDER.len()]));
        assert!(w.block_range_args(1, 1).next().is_none());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn synthetic_weights_have_canonical_layout() {
        let w = ModelWeights::synthetic(3, 8, 16, 32, 4, 2, 42);
        assert_eq!(w.n_layers, 3);
        assert_eq!(w.n_classes, 2);
        assert_eq!(w.embed.len(), EMBED_PARAM_ORDER.len());
        assert_eq!(w.embed[0].shape(), &[32, 8]);
        assert_eq!(w.embed[1].shape(), &[4, 8]);
        for b in &w.blocks {
            assert_eq!(b.len(), BLOCK_PARAM_ORDER.len());
            assert_eq!(b[12].shape(), &[8, 16]); // w1
            assert_eq!(b[14].shape(), &[16, 8]); // w2
        }
        assert_eq!(w.heads[2][2].shape(), &[8, 2]); // wc
        // deterministic per seed, distinct across seeds
        let again = ModelWeights::synthetic(3, 8, 16, 32, 4, 2, 42);
        assert_eq!(w.embed[0], again.embed[0]);
        let other = ModelWeights::synthetic(3, 8, 16, 32, 4, 2, 43);
        assert_ne!(w.embed[0], other.embed[0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = tiny_weights_file(1, 2);
        bytes[0] = 0;
        let path = temp_file(&bytes);
        assert!(ModelWeights::load(&path, 1).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_wrong_layer_count() {
        let path = temp_file(&tiny_weights_file(2, 2));
        // asking for more layers than present -> missing tensor error
        assert!(ModelWeights::load(&path, 3).is_err());
        // asking for fewer -> leftover tensor error
        assert!(ModelWeights::load(&path, 1).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_truncation() {
        let mut bytes = tiny_weights_file(1, 2);
        bytes.truncate(bytes.len() - 10);
        let path = temp_file(&bytes);
        assert!(ModelWeights::load(&path, 1).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = tiny_weights_file(1, 2);
        bytes.extend_from_slice(&[0u8; 4]);
        let path = temp_file(&bytes);
        assert!(ModelWeights::load(&path, 1).is_err());
        std::fs::remove_file(path).unwrap();
    }
}

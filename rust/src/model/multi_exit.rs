//! The multi-exit encoder bound to trained weights, executed through a
//! pluggable compute backend as fused **partition ranges**.
//!
//! The serving hot path is partitioned at the split layer: one fused
//! block-range launch covers `blocks[i..j)`, the exit head is one more
//! launch, and the hidden state crosses the host boundary only where the
//! system semantics require it — at the split point (the simulated uplink
//! payload) and at final outputs.  Between launches the activation is
//! carried as an opaque backend-owned [`HiddenState`] (a raw XLA literal
//! under PJRT, a host tensor under the reference backend), never forced
//! through a `TensorF32` round trip by this layer.
//!
//! All backend-specific execution lives behind
//! [`ModelExecutor`](crate::runtime::ModelExecutor); this type owns the
//! model identity (task/style/geometry), validates arguments, plans
//! batches, and derives predictions from head outputs.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::plan_batches;
use super::weights::ModelWeights;
use crate::config::Manifest;
use crate::runtime::{
    Backend, HeadOut, Hidden as HiddenState, ModelExecutor, ModelSpec, SpecCounters, SpecHandle,
    SpecLane,
};
use crate::tensor::{TensorF32, TensorI32};

/// Output of one exit head over a batch.
#[derive(Debug, Clone)]
pub struct ExitOutput {
    /// class probabilities [B, C]
    pub probs: TensorF32,
    /// max-probability confidence per sample (the paper's C_i)
    pub conf: Vec<f32>,
    /// prediction entropy per sample in nats (DeeBERT's measure)
    pub ent: Vec<f32>,
    /// argmax class per sample
    pub pred: Vec<usize>,
}

impl ExitOutput {
    fn from_tensors(probs: TensorF32, conf: TensorF32, ent: TensorF32) -> Result<ExitOutput> {
        let pred = probs.argmax_rows().map_err(|e| anyhow::anyhow!(e))?;
        Ok(ExitOutput {
            pred,
            conf: conf.into_data(),
            ent: ent.into_data(),
            probs,
        })
    }

    /// Backend head output -> exit output (predictions derived here, once,
    /// identically for every backend — resolved speculative launches go
    /// through the same conversion as direct launches).
    pub fn from_head(h: HeadOut) -> Result<ExitOutput> {
        let pred = h.probs.argmax_rows().map_err(|e| anyhow::anyhow!(e))?;
        Ok(ExitOutput { pred, conf: h.conf, ent: h.ent, probs: h.probs })
    }

    /// Keep only the first `n` samples (drop padded rows).
    pub fn truncate(&mut self, n: usize) {
        if self.conf.len() > n {
            self.probs = self.probs.slice_rows(0, n).expect("truncate probs");
            self.conf.truncate(n);
            self.ent.truncate(n);
            self.pred.truncate(n);
        }
    }

    /// Append another batch's outputs in place.  Uses the in-place
    /// `extend_rows` so accumulating K chunks is O(total rows), not the
    /// O(total²) of re-concatenating the prefix on every append.
    pub fn append(&mut self, other: &ExitOutput) {
        self.probs.extend_rows(&other.probs).expect("append probs");
        self.conf.extend_from_slice(&other.conf);
        self.ent.extend_from_slice(&other.ent);
        self.pred.extend_from_slice(&other.pred);
    }

    pub fn len(&self) -> usize {
        self.conf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conf.is_empty()
    }
}

/// One trained multi-exit model, ready to execute partition by partition
/// through whichever [`Backend`] loaded it.
pub struct MultiExitModel {
    pub task: String,
    pub style: String,
    /// shared (not boxed) so speculative launches can execute through the
    /// same executor from the speculation lane's thread
    exec: Arc<dyn ModelExecutor>,
    batch_sizes: Vec<usize>,
    n_layers: usize,
    n_classes: usize,
    seq_len: usize,
}

impl MultiExitModel {
    /// Load a task's trained model (`style` is "elasticbert" or "deebert")
    /// from an artifact manifest, through the given backend.
    pub fn load(manifest: &Manifest, backend: &Backend, task: &str, style: &str) -> Result<Self> {
        let info = manifest.task(task)?;
        let weights = ModelWeights::load(
            &manifest.weights_path(task, style)?,
            manifest.model.n_layers,
        )?;
        if weights.n_classes != info.classes {
            bail!(
                "weights for {task} have {} classes, manifest says {}",
                weights.n_classes,
                info.classes
            );
        }
        let weights = Arc::new(weights);
        let n_classes = weights.n_classes;
        let spec = ModelSpec {
            task,
            style,
            weights,
            n_heads: manifest.model.n_heads,
            seq_len: manifest.model.seq_len,
            batch_sizes: manifest.batch_sizes.clone(),
            cache_batch: manifest.cache_batch,
            manifest: Some(manifest),
        };
        let exec: Arc<dyn ModelExecutor> = Arc::from(backend.load_model(&spec)?);
        Ok(MultiExitModel {
            task: task.to_string(),
            style: style.to_string(),
            exec,
            batch_sizes: manifest.batch_sizes.clone(),
            n_layers: manifest.model.n_layers,
            n_classes,
            seq_len: manifest.model.seq_len,
        })
    }

    /// Build a model directly from in-memory weights, no artifact manifest —
    /// synthetic tests and benches use this with the reference backend so
    /// the full serving stack runs on machines with no artifacts at all.
    /// (Backends that execute compiled artifacts reject manifest-less specs.)
    pub fn from_weights(
        task: &str,
        style: &str,
        weights: ModelWeights,
        n_heads: usize,
        seq_len: usize,
        batch_sizes: Vec<usize>,
        backend: &Backend,
    ) -> Result<Self> {
        if batch_sizes.is_empty() {
            bail!("from_weights needs at least one batch size");
        }
        let n_layers = weights.n_layers;
        let n_classes = weights.n_classes;
        let cache_batch = *batch_sizes.iter().max().expect("non-empty batch sizes");
        let spec = ModelSpec {
            task,
            style,
            weights: Arc::new(weights),
            n_heads,
            seq_len,
            batch_sizes: batch_sizes.clone(),
            cache_batch,
            manifest: None,
        };
        let exec: Arc<dyn ModelExecutor> = Arc::from(backend.load_model(&spec)?);
        Ok(MultiExitModel {
            task: task.to_string(),
            style: style.to_string(),
            exec,
            batch_sizes,
            n_layers,
            n_classes,
            seq_len,
        })
    }

    /// Which compute backend executes this model.
    pub fn backend_name(&self) -> &'static str {
        self.exec.backend_name()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// Largest compiled batch size.  Errors (rather than panicking) on a
    /// manifest with an empty batch-size table.
    pub fn max_batch(&self) -> Result<usize> {
        self.batch_sizes.iter().max().copied().with_context(|| {
            format!(
                "model {}/{} has an empty compiled batch-size table — \
                 artifacts manifest lists no batch_sizes",
                self.task, self.style
            )
        })
    }

    /// True when every multi-block range runs as one fused launch (always
    /// for the reference backend; under PJRT, when the artifact set has
    /// every `chain{n}` graph).
    pub fn has_fused_ranges(&self) -> bool {
        self.exec.has_fused_ranges()
    }

    /// Ensure whatever executes blocks `start..end` at `batch` is compiled
    /// (no-op when unnecessary or length <= 1).  The serving stages call
    /// this *before* their timed regions so a first-use (or post-eviction)
    /// compile is never recorded as simulated compute latency.
    pub fn warm_range(&self, batch: usize, start: usize, end: usize) -> Result<()> {
        if end > start && end - start > 1 {
            self.exec.warm_range(batch, start, end)?;
        }
        Ok(())
    }

    /// Identifiers of the backend's warm compiled units, LRU to MRU
    /// (snapshot persistence; empty for cache-less backends).
    pub fn warm_keys(&self) -> Vec<String> {
        self.exec.warm_keys()
    }

    /// Re-warm a previously exported working set (stale keys are skipped).
    pub fn rewarm(&self, keys: &[String]) -> Result<()> {
        self.exec.rewarm(keys)
    }

    /// Embedding straight to a backend-format hidden state: tokens [B, T] ->
    /// h0 [B, T, D].  Under PJRT, B must be a compiled batch size (callers
    /// batch via [`plan_batches`]).
    pub fn embed_hidden(&self, tokens: &TensorI32) -> Result<HiddenState> {
        self.exec.embed(tokens)
    }

    /// Blocks `start..end` (0-based, end exclusive) as fused partition
    /// launches, hidden state in and out in backend format.
    pub fn blocks_between(
        &self,
        h: &HiddenState,
        start: usize,
        end: usize,
    ) -> Result<HiddenState> {
        self.check_range(start, end)?;
        self.exec.blocks(h, start, end)
    }

    /// Exit head after `layer` (0-based) evaluated from a backend-format
    /// hidden state.
    pub fn exit_head_hidden(&self, h: &HiddenState, layer: usize) -> Result<ExitOutput> {
        self.check_layer(layer)?;
        ExitOutput::from_head(self.exec.exit_head(h, layer)?)
    }

    /// Embedding: tokens [B, T] -> hidden [B, T, D] on the host.
    pub fn embed(&self, tokens: &TensorI32) -> Result<TensorF32> {
        self.embed_hidden(tokens)?.to_tensor()
    }

    /// One transformer block: hidden [B, T, D] -> hidden [B, T, D].
    /// `layer` is 0-based.
    pub fn block(&self, h: &TensorF32, layer: usize) -> Result<TensorF32> {
        self.check_layer(layer)?;
        self.exec.blocks_host(h, layer, layer + 1)?.to_tensor()
    }

    /// Blocks `start..end` (0-based, end exclusive) from a host hidden
    /// state: one fused launch when the backend supports it.  Bit-exact
    /// with iterating [`MultiExitModel::block`] (asserted by the
    /// integration property tests on both backends).
    pub fn forward_range(&self, h: &TensorF32, start: usize, end: usize) -> Result<TensorF32> {
        if start == end {
            return Ok(h.clone());
        }
        self.check_range(start, end)?;
        self.exec.blocks_host(h, start, end)?.to_tensor()
    }

    /// Exit head after `layer` (0-based): hidden -> (probs, conf, ent, pred).
    pub fn exit_head(&self, h: &TensorF32, layer: usize) -> Result<ExitOutput> {
        self.check_layer(layer)?;
        ExitOutput::from_head(self.exec.exit_head_host(h, layer)?)
    }

    /// Run embed + blocks `0..=layer` (0-based).  Returns the hidden state at
    /// the split point.  This is the "edge device" share of the computation:
    /// one embed launch plus one fused block-range launch.
    pub fn forward_to(&self, tokens: &TensorI32, layer: usize) -> Result<TensorF32> {
        let h0 = self.embed_hidden(tokens)?;
        self.blocks_between(&h0, 0, layer + 1)?.to_tensor()
    }

    /// Continue from the hidden state after `from_layer` (0-based, already
    /// executed) through the final block.  This is the "cloud" share after an
    /// offload.  Takes the hidden state by value — the offload call sites
    /// own the gathered chunk, so the continuation never clones it.
    pub fn forward_rest(&self, h: TensorF32, from_layer: usize) -> Result<TensorF32> {
        if from_layer >= self.n_layers {
            bail!("from_layer {from_layer} out of range (L = {})", self.n_layers);
        }
        if from_layer + 1 == self.n_layers {
            return Ok(h);
        }
        self.exec.blocks_host(&h, from_layer + 1, self.n_layers)?.to_tensor()
    }

    /// Cloud continuation fused with the final exit head: blocks
    /// `from_layer+1..L` (one range launch) then head `L-1`, without
    /// materializing the intermediate hidden state on the host.
    pub fn forward_rest_exit(&self, h: &TensorF32, from_layer: usize) -> Result<ExitOutput> {
        if from_layer >= self.n_layers {
            bail!("from_layer {from_layer} out of range (L = {})", self.n_layers);
        }
        let l = self.n_layers;
        if from_layer + 1 == l {
            return ExitOutput::from_head(self.exec.exit_head_host(h, l - 1)?);
        }
        let hid = self.exec.blocks_host(h, from_layer + 1, l)?;
        ExitOutput::from_head(self.exec.exit_head(&hid, l - 1)?)
    }

    /// True when consuming a speculative *full-batch* continuation result
    /// in place of the serial gathered launch is bit-identical (see
    /// `ModelExecutor::speculation_transparent`) — the precondition for the
    /// coordinator to use speculative results at all.
    pub fn speculation_transparent(&self) -> bool {
        self.exec.speculation_transparent()
    }

    /// Issue the cloud continuation (blocks `from_layer+1..L` + the final
    /// exit head — the same operation sequence as
    /// [`MultiExitModel::forward_rest_exit`]) as a cancellable speculative
    /// launch on `lane`, running concurrently with whatever the caller does
    /// next (typically the exit-head verdict).  `h` is the full (padded)
    /// batch hidden state at the split, shared (not copied) with the caller.
    pub fn speculate_rest_exit(
        &self,
        lane: &SpecLane,
        h: Arc<TensorF32>,
        from_layer: usize,
        counters: &Arc<SpecCounters>,
    ) -> Result<SpecHandle> {
        if from_layer >= self.n_layers {
            bail!("from_layer {from_layer} out of range (L = {})", self.n_layers);
        }
        Ok(lane.speculate_rest_exit(
            Arc::clone(&self.exec),
            h,
            from_layer,
            self.n_layers,
            counters,
        ))
    }

    /// Full forward through every exit at once (the cache-builder path —
    /// the fused `prefix_full` graph under PJRT, a direct sweep under the
    /// reference backend).  tokens [B, T] with any B.  Returns per-layer
    /// outputs, outer index = layer.
    pub fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<ExitOutput>> {
        self.exec
            .forward_all_exits(tokens)?
            .into_iter()
            .map(ExitOutput::from_head)
            .collect()
    }

    /// Convenience single-pass serving call used by examples and tests: run
    /// to `split` (0-based), evaluate its exit head, and return both the exit
    /// output and the hidden state (for a possible offload continuation).
    pub fn run_split(
        &self,
        tokens: &TensorI32,
        split: usize,
    ) -> Result<(TensorF32, ExitOutput)> {
        let h0 = self.embed_hidden(tokens)?;
        let h = self.blocks_between(&h0, 0, split + 1)?;
        let out = self.exit_head_hidden(&h, split)?;
        Ok((h.to_tensor()?, out))
    }

    /// Cover `n` rows with compiled batch sizes (see [`plan_batches`]).
    pub fn batch_plan(&self, n: usize) -> Vec<(usize, usize)> {
        plan_batches(n, &self.batch_sizes)
    }

    fn check_range(&self, start: usize, end: usize) -> Result<()> {
        if start >= end || end > self.n_layers {
            bail!(
                "block range [{start}, {end}) out of bounds (L = {})",
                self.n_layers
            );
        }
        Ok(())
    }

    fn check_layer(&self, layer: usize) -> Result<()> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (L = {})", self.n_layers);
        }
        Ok(())
    }
}

impl std::fmt::Debug for MultiExitModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultiExitModel")
            .field("task", &self.task)
            .field("style", &self.style)
            .field("backend", &self.exec.backend_name())
            .field("layers", &self.n_layers)
            .field("classes", &self.n_classes)
            .field("fused_ranges", &self.exec.has_fused_ranges())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_output_truncate_and_append() {
        let probs = TensorF32::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]).unwrap();
        let conf = TensorF32::new(vec![3], vec![0.9, 0.8, 0.6]).unwrap();
        let ent = TensorF32::new(vec![3], vec![0.3, 0.5, 0.67]).unwrap();
        let mut eo = ExitOutput::from_tensors(probs, conf, ent).unwrap();
        assert_eq!(eo.pred, vec![0, 1, 0]);
        eo.truncate(2);
        assert_eq!(eo.len(), 2);
        assert_eq!(eo.pred, vec![0, 1]);

        let other = ExitOutput::from_tensors(
            TensorF32::new(vec![1, 2], vec![0.3, 0.7]).unwrap(),
            TensorF32::new(vec![1], vec![0.7]).unwrap(),
            TensorF32::new(vec![1], vec![0.61]).unwrap(),
        )
        .unwrap();
        eo.append(&other);
        assert_eq!(eo.len(), 3);
        assert_eq!(eo.pred, vec![0, 1, 1]);
        assert_eq!(eo.probs.shape(), &[3, 2]);
    }

    #[test]
    fn append_accumulation_is_linear_and_correct() {
        // accumulate many single-row chunks; the result must match one big
        // construction (this is the pattern forward_all_exits preallocates)
        let mut acc = ExitOutput {
            probs: TensorF32::new(vec![1, 2], vec![0.9, 0.1]).unwrap(),
            conf: vec![0.9],
            ent: vec![0.3],
            pred: vec![0],
        };
        for i in 1..20 {
            let p = if i % 2 == 0 { vec![0.8, 0.2] } else { vec![0.2, 0.8] };
            let other = ExitOutput {
                probs: TensorF32::new(vec![1, 2], p.clone()).unwrap(),
                conf: vec![p[0].max(p[1])],
                ent: vec![0.5],
                pred: vec![if p[1] > p[0] { 1 } else { 0 }],
            };
            acc.append(&other);
        }
        assert_eq!(acc.len(), 20);
        assert_eq!(acc.probs.shape(), &[20, 2]);
        assert_eq!(acc.pred, acc.probs.argmax_rows().unwrap());
    }

    fn tiny_reference_model() -> MultiExitModel {
        let weights = ModelWeights::synthetic(4, 16, 32, 64, 8, 2, 0xC0DE);
        MultiExitModel::from_weights(
            "synthetic",
            "reference",
            weights,
            2,
            8,
            vec![1, 4],
            &Backend::reference(),
        )
        .expect("reference model")
    }

    fn tokens(b: usize, seed: i32) -> TensorI32 {
        TensorI32::new(
            vec![b, 8],
            (0..b as i32 * 8).map(|i| (i * 7 + seed) % 64).collect(),
        )
        .unwrap()
    }

    #[test]
    fn reference_model_runs_end_to_end() {
        let model = tiny_reference_model();
        assert_eq!(model.backend_name(), "reference");
        assert!(model.has_fused_ranges());
        let t = tokens(1, 3);
        let (h, out) = model.run_split(&t, 2).unwrap();
        assert_eq!(h.shape(), &[1, 8, 16]);
        assert_eq!(out.probs.shape(), &[1, 2]);
        let p: f32 = out.probs.data().iter().sum();
        assert!((p - 1.0).abs() < 1e-4, "probs sum {p}");
        // full-depth sweep agrees with the layered path at the final layer
        let all = model.forward_all_exits(&t).unwrap();
        assert_eq!(all.len(), 4);
        let (_h, fin) = model.run_split(&t, 3).unwrap();
        assert!((all[3].conf[0] - fin.conf[0]).abs() < 1e-4);
        assert_eq!(all[3].pred[0], fin.pred[0]);
    }

    #[test]
    fn reference_batched_execution_matches_single() {
        let model = tiny_reference_model();
        let batch = tokens(4, 11);
        let (_h, out_batch) = model.run_split(&batch, 1).unwrap();
        for i in 0..4 {
            let single = TensorI32::new(
                vec![1, 8],
                batch.data()[i * 8..(i + 1) * 8].to_vec(),
            )
            .unwrap();
            let (_h1, out1) = model.run_split(&single, 1).unwrap();
            assert_eq!(
                out1.conf[0].to_bits(),
                out_batch.conf[i].to_bits(),
                "row {i}: reference batching must be bit-exact"
            );
            assert_eq!(out1.pred[0], out_batch.pred[i], "row {i}");
        }
    }

    #[test]
    fn reference_forward_rest_continues_the_layered_path() {
        let model = tiny_reference_model();
        let t = tokens(2, 5);
        let split = 1usize; // 0-based split layer
        let (h, _out) = model.run_split(&t, split).unwrap();
        let full = model.forward_rest(h.clone(), split).unwrap();
        let direct = model.forward_to(&t, model.n_layers() - 1).unwrap();
        for (a, b) in full.data().iter().zip(direct.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // fused continuation + head agrees with the two-step version
        let eo = model.forward_rest_exit(&h, split).unwrap();
        let eo2 = model.exit_head(&full, model.n_layers() - 1).unwrap();
        assert_eq!(eo.pred, eo2.pred);
        assert_eq!(eo.conf[0].to_bits(), eo2.conf[0].to_bits());
    }

    #[test]
    fn model_rejects_out_of_range_layers() {
        let model = tiny_reference_model();
        let t = tokens(1, 1);
        let h = model.embed(&t).unwrap();
        assert!(model.exit_head(&h, 4).is_err());
        assert!(model.forward_range(&h, 2, 9).is_err());
        assert!(model.forward_rest(h, 9).is_err());
    }
}

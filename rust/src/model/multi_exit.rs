//! The multi-exit encoder bound to trained weights, executing compiled
//! PJRT graphs as fused **partition ranges**.
//!
//! The serving hot path is partitioned at the split layer: one fused
//! `chain{n}` executable covers `blocks[i..j)` in a single launch (the
//! activation stays device-resident inside the module), the exit head is one
//! more launch, and the hidden state crosses the host boundary only where
//! the system semantics require it — at the split point (the simulated
//! uplink payload) and at final outputs.  Between launches the activation is
//! carried as a [`HiddenState`] (a raw XLA literal), never a `TensorF32`.
//! When an artifact set predates the chain graphs the model falls back to
//! per-block launches with the same literal passthrough, so outputs are
//! identical either way.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::plan_batches;
use super::weights::ModelWeights;
use crate::config::Manifest;
use crate::runtime::executable::Arg;
use crate::runtime::literal::{literal_f32, tensor_f32};
use crate::runtime::{Executable, Runtime};
use crate::tensor::{TensorF32, TensorI32};

/// Output of one exit head over a batch.
#[derive(Debug, Clone)]
pub struct ExitOutput {
    /// class probabilities [B, C]
    pub probs: TensorF32,
    /// max-probability confidence per sample (the paper's C_i)
    pub conf: Vec<f32>,
    /// prediction entropy per sample in nats (DeeBERT's measure)
    pub ent: Vec<f32>,
    /// argmax class per sample
    pub pred: Vec<usize>,
}

impl ExitOutput {
    fn from_tensors(probs: TensorF32, conf: TensorF32, ent: TensorF32) -> Result<ExitOutput> {
        let pred = probs.argmax_rows().map_err(|e| anyhow::anyhow!(e))?;
        Ok(ExitOutput {
            pred,
            conf: conf.data().to_vec(),
            ent: ent.data().to_vec(),
            probs,
        })
    }

    /// Keep only the first `n` samples (drop padded rows).
    pub fn truncate(&mut self, n: usize) {
        if self.conf.len() > n {
            self.probs = self.probs.slice_rows(0, n).expect("truncate probs");
            self.conf.truncate(n);
            self.ent.truncate(n);
            self.pred.truncate(n);
        }
    }

    /// Append another batch's outputs in place.  Uses the in-place
    /// `extend_rows` so accumulating K chunks is O(total rows), not the
    /// O(total²) of re-concatenating the prefix on every append.
    pub fn append(&mut self, other: &ExitOutput) {
        self.probs.extend_rows(&other.probs).expect("append probs");
        self.conf.extend_from_slice(&other.conf);
        self.ent.extend_from_slice(&other.ent);
        self.pred.extend_from_slice(&other.pred);
    }

    pub fn len(&self) -> usize {
        self.conf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conf.is_empty()
    }
}

/// A hidden state held in XLA-literal form between partition launches.
///
/// The buffer is handed straight back as the next launch's argument
/// (`Arg::Lit`), skipping the host `TensorF32` materialization the per-block
/// path used to pay at every layer boundary.  Call [`HiddenState::to_tensor`]
/// only where the host genuinely needs the values — the split boundary and
/// final outputs.
pub struct HiddenState {
    lit: xla::Literal,
    batch: usize,
}

impl HiddenState {
    /// Batch dimension (a compiled batch size).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Host transfer: literal -> `TensorF32` (the split-boundary copy).
    pub fn to_tensor(&self) -> Result<TensorF32> {
        tensor_f32(&self.lit)
    }
}

impl std::fmt::Debug for HiddenState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HiddenState").field("batch", &self.batch).finish()
    }
}

/// One trained multi-exit model, ready to execute partition by partition.
///
/// The fused `chain{n}` executables are weight-parameterized like `block`,
/// so one compiled module serves *every* range of length `n`; they are
/// compiled lazily per `(length, batch)` through the runtime's bounded LRU
/// cache rather than eagerly at load.
pub struct MultiExitModel {
    pub task: String,
    pub style: String,
    weights: Arc<ModelWeights>,
    runtime: Runtime,
    embed: BTreeMap<usize, Arc<Executable>>,
    block: BTreeMap<usize, Arc<Executable>>,
    head: BTreeMap<usize, Arc<Executable>>,
    prefix_full: Option<(usize, Arc<Executable>)>,
    /// fused block-range artifacts: (range length, batch) -> HLO path,
    /// loaded lazily through the runtime's LRU cache
    chain: BTreeMap<(usize, usize), PathBuf>,
    /// Weight tensors pre-converted to XLA literals — skips the host copy on
    /// every layer execution (L3 perf pass; disable for A/B measurement with
    /// SPLITEE_NO_LITERAL_CACHE=1).
    lits: Option<LitCache>,
    batch_sizes: Vec<usize>,
    n_layers: usize,
    seq_len: usize,
}

struct LitCache {
    embed: Vec<xla::Literal>,
    blocks: Vec<Vec<xla::Literal>>,
    heads: Vec<Vec<xla::Literal>>,
    prefix: Vec<xla::Literal>,
}

// SAFETY: the literal cache is immutable after construction and literals are
// plain host buffers; the PJRT CPU executables are internally synchronized.
// The runtime handle is only used for lazy chain compiles, which are
// serialized under the runtime's dedicated compile lock
// (`RuntimeInner::compile_lock` — cache-hit probes never compile), so the
// thread-affine client never compiles from two threads at once.  The model
// is only ever used behind `Arc` with `&self` access.
unsafe impl Send for MultiExitModel {}
unsafe impl Sync for MultiExitModel {}

fn build_lit_cache(weights: &ModelWeights) -> anyhow::Result<LitCache> {
    let conv = |ts: &[crate::tensor::TensorF32]| -> anyhow::Result<Vec<xla::Literal>> {
        ts.iter().map(literal_f32).collect()
    };
    Ok(LitCache {
        embed: conv(&weights.embed)?,
        blocks: weights.blocks.iter().map(|b| conv(b)).collect::<anyhow::Result<_>>()?,
        heads: weights.heads.iter().map(|h| conv(h)).collect::<anyhow::Result<_>>()?,
        prefix: {
            let mut all = conv(&weights.embed)?;
            for b in &weights.blocks {
                all.extend(conv(b)?);
            }
            for h in &weights.heads {
                all.extend(conv(h)?);
            }
            all
        },
    })
}

impl MultiExitModel {
    /// Load a task's trained model (`style` is "elasticbert" or "deebert").
    pub fn load(manifest: &Manifest, runtime: &Runtime, task: &str, style: &str) -> Result<Self> {
        let info = manifest.task(task)?;
        let weights = ModelWeights::load(
            &manifest.weights_path(task, style)?,
            manifest.model.n_layers,
        )?;
        if weights.n_classes != info.classes {
            bail!(
                "weights for {task} have {} classes, manifest says {}",
                weights.n_classes,
                info.classes
            );
        }
        let head_graph = format!("head_c{}", info.classes);
        let mut embed = BTreeMap::new();
        let mut block = BTreeMap::new();
        let mut head = BTreeMap::new();
        for &b in &manifest.batch_sizes {
            embed.insert(b, runtime.load(&manifest.hlo_path("embed", b)?)?);
            block.insert(b, runtime.load(&manifest.hlo_path("block", b)?)?);
            head.insert(b, runtime.load(&manifest.hlo_path(&head_graph, b)?)?);
        }
        let prefix_graph = format!("prefix_full_c{}", info.classes);
        let prefix_full = match manifest.hlo_path(&prefix_graph, manifest.cache_batch) {
            Ok(path) => Some((manifest.cache_batch, runtime.load(&path)?)),
            Err(_) => None,
        };
        // Fused block-range graphs (chain2..chainL): record paths only; the
        // runtime compiles each lazily on first use behind its LRU cache.
        // Length-1 ranges reuse the plain `block` executable.
        let mut chain = BTreeMap::new();
        for len in 2..=manifest.model.n_layers {
            let graph = format!("chain{len}");
            for &b in &manifest.batch_sizes {
                if let Ok(path) = manifest.hlo_path(&graph, b) {
                    chain.insert((len, b), path);
                }
            }
        }
        let weights = Arc::new(weights);
        let lits = if std::env::var("SPLITEE_NO_LITERAL_CACHE").is_ok() {
            None
        } else {
            Some(build_lit_cache(&weights)?)
        };
        Ok(MultiExitModel {
            task: task.to_string(),
            style: style.to_string(),
            weights,
            runtime: runtime.clone(),
            embed,
            block,
            head,
            prefix_full,
            chain,
            lits,
            batch_sizes: manifest.batch_sizes.clone(),
            n_layers: manifest.model.n_layers,
            seq_len: manifest.model.seq_len,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_classes(&self) -> usize {
        self.weights.n_classes
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// Largest compiled batch size.  Errors (rather than panicking) on a
    /// manifest with an empty batch-size table.
    pub fn max_batch(&self) -> Result<usize> {
        self.batch_sizes.iter().max().copied().with_context(|| {
            format!(
                "model {}/{} has an empty compiled batch-size table — \
                 artifacts manifest lists no batch_sizes",
                self.task, self.style
            )
        })
    }

    /// True when every multi-block range has a fused artifact (all lengths
    /// 2..=L at every compiled batch size), i.e. the serving path runs one
    /// block-range launch per partition.
    pub fn has_fused_ranges(&self) -> bool {
        self.batch_sizes
            .iter()
            .all(|&b| (2..=self.n_layers).all(|len| self.chain.contains_key(&(len, b))))
    }

    fn pick_exec<'a>(
        table: &'a BTreeMap<usize, Arc<Executable>>,
        batch: usize,
    ) -> Result<&'a Arc<Executable>> {
        table
            .get(&batch)
            .with_context(|| format!("no executable compiled for batch {batch}"))
    }

    fn push_block_args<'a>(&'a self, args: &mut Vec<Arg<'a>>, layer: usize) {
        match &self.lits {
            Some(l) => args.extend(l.blocks[layer].iter().map(Arg::Lit)),
            None => args.extend(self.weights.blocks[layer].iter().map(Arg::F32)),
        }
    }

    /// Run blocks `start..end` (0-based, end exclusive) from a hidden-state
    /// argument, returning the raw output literal.  One fused launch when
    /// the `chain{end-start}` artifact exists; otherwise per-block launches
    /// with literal passthrough (no host materialization either way).
    fn run_blocks_arg(
        &self,
        h: Arg<'_>,
        batch: usize,
        start: usize,
        end: usize,
    ) -> Result<xla::Literal> {
        if start >= end || end > self.n_layers {
            bail!(
                "block range [{start}, {end}) out of bounds (L = {})",
                self.n_layers
            );
        }
        let len = end - start;
        if len > 1 {
            if let Some(path) = self.chain.get(&(len, batch)) {
                let exe = self
                    .runtime
                    .load(path)
                    .with_context(|| format!("loading fused range chain{len} (batch {batch})"))?;
                let mut args: Vec<Arg<'_>> = Vec::with_capacity(1 + 16 * len);
                args.push(h);
                match &self.lits {
                    Some(l) => {
                        for blk in &l.blocks[start..end] {
                            args.extend(blk.iter().map(Arg::Lit));
                        }
                    }
                    None => {
                        args.extend(self.weights.block_range_args(start, end).map(Arg::F32))
                    }
                }
                let mut out = exe.run(&args)?;
                if out.is_empty() {
                    bail!("chain{len} returned no outputs");
                }
                return Ok(out.remove(0));
            }
        }
        // fallback: per-block launches, activation carried as a literal
        let exe = Self::pick_exec(&self.block, batch)?;
        let mut cur = {
            let mut args: Vec<Arg<'_>> = Vec::with_capacity(17);
            args.push(h);
            self.push_block_args(&mut args, start);
            let mut out = exe.run(&args)?;
            if out.is_empty() {
                bail!("block returned no outputs");
            }
            out.remove(0)
        };
        for layer in (start + 1)..end {
            let mut out = {
                let mut args: Vec<Arg<'_>> = Vec::with_capacity(17);
                args.push(Arg::Lit(&cur));
                self.push_block_args(&mut args, layer);
                exe.run(&args)?
            };
            if out.is_empty() {
                bail!("block returned no outputs");
            }
            cur = out.remove(0);
        }
        Ok(cur)
    }

    fn exit_head_arg(&self, h: Arg<'_>, batch: usize, layer: usize) -> Result<ExitOutput> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (L = {})", self.n_layers);
        }
        let exe = Self::pick_exec(&self.head, batch)?;
        let mut args = vec![h];
        match &self.lits {
            Some(l) => args.extend(l.heads[layer].iter().map(Arg::Lit)),
            None => args.extend(self.weights.heads[layer].iter().map(Arg::F32)),
        }
        let out = exe.run(&args)?;
        if out.len() != 3 {
            bail!("exit head returned {} outputs, expected 3", out.len());
        }
        let probs = tensor_f32(&out[0])?;
        let conf = tensor_f32(&out[1])?;
        let ent = tensor_f32(&out[2])?;
        ExitOutput::from_tensors(probs, conf, ent)
    }

    /// Ensure the fused range executable for blocks `start..end` at `batch`
    /// is compiled (no-op when absent or length 1).  The serving stages call
    /// this *before* their timed regions so a first-use (or post-eviction)
    /// chain compile is never recorded as simulated compute latency.
    pub fn warm_range(&self, batch: usize, start: usize, end: usize) -> Result<()> {
        if end > start && end - start > 1 {
            if let Some(path) = self.chain.get(&(end - start, batch)) {
                self.runtime.load(path).with_context(|| {
                    format!("pre-warming fused range chain{} (batch {batch})", end - start)
                })?;
            }
        }
        Ok(())
    }

    /// Embedding straight to a device-format hidden state: tokens [B, T] ->
    /// h0 [B, T, D] as a literal.  B must be a compiled batch size (callers
    /// batch via [`plan_batches`]).
    pub fn embed_hidden(&self, tokens: &TensorI32) -> Result<HiddenState> {
        let b = tokens.shape()[0];
        let exe = Self::pick_exec(&self.embed, b)?;
        let mut args = vec![Arg::I32(tokens)];
        match &self.lits {
            Some(l) => args.extend(l.embed.iter().map(Arg::Lit)),
            None => args.extend(self.weights.embed.iter().map(Arg::F32)),
        }
        let mut out = exe.run(&args)?;
        if out.is_empty() {
            bail!("embed returned no outputs");
        }
        Ok(HiddenState { lit: out.remove(0), batch: b })
    }

    /// Blocks `start..end` (0-based, end exclusive) as fused partition
    /// launches, hidden state in and out in device format.
    pub fn blocks_between(
        &self,
        h: &HiddenState,
        start: usize,
        end: usize,
    ) -> Result<HiddenState> {
        let lit = self.run_blocks_arg(Arg::Lit(&h.lit), h.batch, start, end)?;
        Ok(HiddenState { lit, batch: h.batch })
    }

    /// Exit head after `layer` (0-based) evaluated from a device-format
    /// hidden state.
    pub fn exit_head_hidden(&self, h: &HiddenState, layer: usize) -> Result<ExitOutput> {
        self.exit_head_arg(Arg::Lit(&h.lit), h.batch, layer)
    }

    /// Embedding: tokens [B, T] -> hidden [B, T, D] on the host.
    pub fn embed(&self, tokens: &TensorI32) -> Result<TensorF32> {
        self.embed_hidden(tokens)?.to_tensor()
    }

    /// One transformer block: hidden [B, T, D] -> hidden [B, T, D].
    /// `layer` is 0-based.
    pub fn block(&self, h: &TensorF32, layer: usize) -> Result<TensorF32> {
        let b = h.shape()[0];
        let lit = self.run_blocks_arg(Arg::F32(h), b, layer, layer + 1)?;
        tensor_f32(&lit)
    }

    /// Blocks `start..end` (0-based, end exclusive) from a host hidden
    /// state: one fused launch when the range artifact exists.  Bit-exact
    /// with iterating [`MultiExitModel::block`] (asserted by the
    /// integration property test).
    pub fn forward_range(&self, h: &TensorF32, start: usize, end: usize) -> Result<TensorF32> {
        if start == end {
            return Ok(h.clone());
        }
        let b = h.shape()[0];
        let lit = self.run_blocks_arg(Arg::F32(h), b, start, end)?;
        tensor_f32(&lit)
    }

    /// Exit head after `layer` (0-based): hidden -> (probs, conf, ent, pred).
    pub fn exit_head(&self, h: &TensorF32, layer: usize) -> Result<ExitOutput> {
        self.exit_head_arg(Arg::F32(h), h.shape()[0], layer)
    }

    /// Run embed + blocks `0..=layer` (0-based).  Returns the hidden state at
    /// the split point.  This is the "edge device" share of the computation:
    /// one embed launch plus one fused block-range launch.
    pub fn forward_to(&self, tokens: &TensorI32, layer: usize) -> Result<TensorF32> {
        let h0 = self.embed_hidden(tokens)?;
        self.blocks_between(&h0, 0, layer + 1)?.to_tensor()
    }

    /// Continue from the hidden state after `from_layer` (0-based, already
    /// executed) through the final block.  This is the "cloud" share after an
    /// offload.  Takes the hidden state by value — the offload call sites
    /// own the gathered chunk, so the continuation never clones it.
    pub fn forward_rest(&self, h: TensorF32, from_layer: usize) -> Result<TensorF32> {
        if from_layer >= self.n_layers {
            bail!("from_layer {from_layer} out of range (L = {})", self.n_layers);
        }
        if from_layer + 1 == self.n_layers {
            return Ok(h);
        }
        let b = h.shape()[0];
        let lit = self.run_blocks_arg(Arg::F32(&h), b, from_layer + 1, self.n_layers)?;
        tensor_f32(&lit)
    }

    /// Cloud continuation fused with the final exit head: blocks
    /// `from_layer+1..L` (one range launch) then head `L-1`, without
    /// materializing the intermediate hidden state on the host.
    pub fn forward_rest_exit(&self, h: &TensorF32, from_layer: usize) -> Result<ExitOutput> {
        if from_layer >= self.n_layers {
            bail!("from_layer {from_layer} out of range (L = {})", self.n_layers);
        }
        let l = self.n_layers;
        let b = h.shape()[0];
        if from_layer + 1 == l {
            return self.exit_head_arg(Arg::F32(h), b, l - 1);
        }
        let lit = self.run_blocks_arg(Arg::F32(h), b, from_layer + 1, l)?;
        self.exit_head_arg(Arg::Lit(&lit), b, l - 1)
    }

    /// Full forward through every exit at once via the fused `prefix_full`
    /// graph.  tokens [B, T] with any B — batching/padding handled here.
    /// Returns per-layer outputs, outer index = layer.
    ///
    /// Accumulators are preallocated from the batch plan (`n` rows, `C`
    /// classes known up front), so covering a large cache is one exact-size
    /// allocation per layer instead of a re-concatenation per chunk.
    pub fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<ExitOutput>> {
        let (cache_b, exe) = self
            .prefix_full
            .as_ref()
            .context("prefix_full graph not in manifest")?;
        let n = tokens.shape()[0];
        let c = self.weights.n_classes;
        let layers = self.n_layers;
        let mut probs_acc: Vec<Vec<f32>> =
            (0..layers).map(|_| Vec::with_capacity(n * c)).collect();
        let mut conf_acc: Vec<Vec<f32>> = (0..layers).map(|_| Vec::with_capacity(n)).collect();
        let mut ent_acc: Vec<Vec<f32>> = (0..layers).map(|_| Vec::with_capacity(n)).collect();
        let mut done = 0usize;
        while done < n {
            let real = (*cache_b).min(n - done);
            let chunk = tokens
                .slice_rows(done, done + real)
                .map_err(|e| anyhow::anyhow!(e))?
                .pad_rows_to(*cache_b)
                .map_err(|e| anyhow::anyhow!(e))?;
            let mut args = vec![Arg::I32(&chunk)];
            let flat;
            match &self.lits {
                Some(l) => args.extend(l.prefix.iter().map(Arg::Lit)),
                None => {
                    flat = self.weights.prefix_full_args();
                    args.extend(flat.iter().map(|t| Arg::F32(t)));
                }
            }
            let out = exe.run_f32(&args)?;
            // output layout: (probs [L,B,C], conf [L,B], ent [L,B])
            if out.len() != 3 {
                bail!("prefix_full returned {} outputs, expected 3", out.len());
            }
            let (probs, conf, ent) = (&out[0], &out[1], &out[2]);
            let b = probs.shape()[1];
            if probs.shape()[2] != c {
                bail!("prefix_full emitted {} classes, weights have {c}", probs.shape()[2]);
            }
            // copy the `real` unpadded rows of each stacked layer straight
            // into the preallocated accumulators
            for l in 0..layers {
                probs_acc[l].extend_from_slice(&probs.data()[l * b * c..l * b * c + real * c]);
                conf_acc[l].extend_from_slice(&conf.data()[l * b..l * b + real]);
                ent_acc[l].extend_from_slice(&ent.data()[l * b..l * b + real]);
            }
            done += real;
        }
        probs_acc
            .into_iter()
            .zip(conf_acc)
            .zip(ent_acc)
            .map(|((p, cf), en)| {
                let probs = TensorF32::new(vec![n, c], p).map_err(|e| anyhow::anyhow!(e))?;
                let pred = probs.argmax_rows().map_err(|e| anyhow::anyhow!(e))?;
                Ok(ExitOutput { probs, conf: cf, ent: en, pred })
            })
            .collect()
    }

    /// Convenience single-pass serving call used by examples and tests: run
    /// to `split` (0-based), evaluate its exit head, and return both the exit
    /// output and the hidden state (for a possible offload continuation).
    pub fn run_split(
        &self,
        tokens: &TensorI32,
        split: usize,
    ) -> Result<(TensorF32, ExitOutput)> {
        let h0 = self.embed_hidden(tokens)?;
        let h = self.blocks_between(&h0, 0, split + 1)?;
        let out = self.exit_head_hidden(&h, split)?;
        Ok((h.to_tensor()?, out))
    }

    /// Cover `n` rows with compiled batch sizes (see [`plan_batches`]).
    pub fn batch_plan(&self, n: usize) -> Vec<(usize, usize)> {
        plan_batches(n, &self.batch_sizes)
    }
}

impl std::fmt::Debug for MultiExitModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultiExitModel")
            .field("task", &self.task)
            .field("style", &self.style)
            .field("layers", &self.n_layers)
            .field("classes", &self.weights.n_classes)
            .field("fused_ranges", &self.chain.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_output_truncate_and_append() {
        let probs = TensorF32::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]).unwrap();
        let conf = TensorF32::new(vec![3], vec![0.9, 0.8, 0.6]).unwrap();
        let ent = TensorF32::new(vec![3], vec![0.3, 0.5, 0.67]).unwrap();
        let mut eo = ExitOutput::from_tensors(probs, conf, ent).unwrap();
        assert_eq!(eo.pred, vec![0, 1, 0]);
        eo.truncate(2);
        assert_eq!(eo.len(), 2);
        assert_eq!(eo.pred, vec![0, 1]);

        let other = ExitOutput::from_tensors(
            TensorF32::new(vec![1, 2], vec![0.3, 0.7]).unwrap(),
            TensorF32::new(vec![1], vec![0.7]).unwrap(),
            TensorF32::new(vec![1], vec![0.61]).unwrap(),
        )
        .unwrap();
        eo.append(&other);
        assert_eq!(eo.len(), 3);
        assert_eq!(eo.pred, vec![0, 1, 1]);
        assert_eq!(eo.probs.shape(), &[3, 2]);
    }

    #[test]
    fn append_accumulation_is_linear_and_correct() {
        // accumulate many single-row chunks; the result must match one big
        // construction (this is the pattern forward_all_exits preallocates)
        let mut acc = ExitOutput {
            probs: TensorF32::new(vec![1, 2], vec![0.9, 0.1]).unwrap(),
            conf: vec![0.9],
            ent: vec![0.3],
            pred: vec![0],
        };
        for i in 1..20 {
            let p = if i % 2 == 0 { vec![0.8, 0.2] } else { vec![0.2, 0.8] };
            let other = ExitOutput {
                probs: TensorF32::new(vec![1, 2], p.clone()).unwrap(),
                conf: vec![p[0].max(p[1])],
                ent: vec![0.5],
                pred: vec![if p[1] > p[0] { 1 } else { 0 }],
            };
            acc.append(&other);
        }
        assert_eq!(acc.len(), 20);
        assert_eq!(acc.probs.shape(), &[20, 2]);
        assert_eq!(acc.pred, acc.probs.argmax_rows().unwrap());
    }
}

//! Multi-exit model execution on top of the pluggable compute backends.
//!
//! [`MultiExitModel`] binds one trained task's weights to a backend-loaded
//! executor (compiled PJRT graphs, or the pure-Rust reference math) and
//! exposes the layer-by-layer operations the coordinator needs for true
//! early-exit serving: run blocks up to the split layer on the "edge",
//! evaluate the exit head there, and — if offloading — continue through the
//! remaining blocks on the "cloud".  [`HiddenState`] is the backend-owned
//! activation handle that travels between those partition launches.

pub mod multi_exit;
pub mod weights;

pub use multi_exit::{ExitOutput, MultiExitModel};
pub use weights::ModelWeights;

pub use crate::runtime::Hidden as HiddenState;

/// Plan how to cover `n` samples with the compiled batch sizes.
///
/// Greedy: use the largest compiled batch that fits the remainder; when the
/// remainder is smaller than every compiled batch, use the smallest compiled
/// batch and pad.  Returns (batch size, real rows) pairs.
pub fn plan_batches(n: usize, sizes: &[usize]) -> Vec<(usize, usize)> {
    assert!(!sizes.is_empty(), "no compiled batch sizes");
    let mut sorted = sizes.to_vec();
    sorted.sort_unstable();
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        let fit = sorted.iter().rev().find(|&&b| b <= left);
        match fit {
            Some(&b) => {
                out.push((b, b));
                left -= b;
            }
            None => {
                out.push((sorted[0], left));
                left = 0;
            }
        }
    }
    out
}

/// Like [`plan_batches`], but minimizes *launches* instead of padded rows:
/// full largest-size chunks while the remainder exceeds every compiled
/// size, then one padded launch with the smallest compiled size that fits
/// the tail.  The cloud stage's coalesced offload groups use this — one
/// fused `forward_rest` launch per group beats the per-row padding FLOPs at
/// the batch sizes we compile.
pub fn plan_batches_fused(n: usize, sizes: &[usize]) -> Vec<(usize, usize)> {
    assert!(!sizes.is_empty(), "no compiled batch sizes");
    let max = *sizes.iter().max().expect("non-empty sizes");
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        if left >= max {
            out.push((max, max));
            left -= max;
        } else {
            let fit = sizes
                .iter()
                .copied()
                .filter(|&b| b >= left)
                .min()
                .expect("some compiled size >= remainder < max");
            out.push((fit, left));
            left = 0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_exact_fit() {
        assert_eq!(plan_batches(16, &[1, 8]), vec![(8, 8), (8, 8)]);
    }

    #[test]
    fn plan_with_padding_tail() {
        assert_eq!(plan_batches(10, &[1, 8]), vec![(8, 8), (1, 1), (1, 1)]);
        assert_eq!(plan_batches(3, &[8]), vec![(8, 3)]);
    }

    #[test]
    fn plan_zero() {
        assert!(plan_batches(0, &[1, 8]).is_empty());
    }

    #[test]
    fn plan_fused_prefers_one_padded_launch() {
        assert_eq!(plan_batches_fused(3, &[1, 8]), vec![(8, 3)]);
        assert_eq!(plan_batches_fused(8, &[1, 8]), vec![(8, 8)]);
        assert_eq!(plan_batches_fused(1, &[1, 8]), vec![(1, 1)]);
        // overflow: full max-size chunks, then one fused tail
        assert_eq!(plan_batches_fused(10, &[1, 8]), vec![(8, 8), (8, 2)]);
        assert_eq!(plan_batches_fused(17, &[1, 8]), vec![(8, 8), (8, 8), (1, 1)]);
        assert!(plan_batches_fused(0, &[1, 8]).is_empty());
    }

    #[test]
    fn plan_fused_covers_all_rows_with_fewer_or_equal_launches() {
        for n in 0..50 {
            for sizes in [&[1usize, 8][..], &[8][..], &[1][..], &[4, 32][..]] {
                let fused = plan_batches_fused(n, sizes);
                let total: usize = fused.iter().map(|(_, real)| real).sum();
                assert_eq!(total, n, "n={n} sizes={sizes:?}");
                for (b, real) in &fused {
                    assert!(real <= b);
                    assert!(sizes.contains(b));
                }
                assert!(
                    fused.len() <= plan_batches(n, sizes).len(),
                    "n={n} sizes={sizes:?}: fused plan must not add launches"
                );
            }
        }
    }

    #[test]
    fn plan_covers_all_rows() {
        for n in 0..50 {
            for sizes in [&[1usize, 8][..], &[8][..], &[1][..], &[4, 32][..]] {
                let plan = plan_batches(n, sizes);
                let total: usize = plan.iter().map(|(_, real)| real).sum();
                assert_eq!(total, n, "n={n} sizes={sizes:?}");
                for (b, real) in plan {
                    assert!(real <= b);
                    assert!(sizes.contains(&b));
                }
            }
        }
    }
}

//! Network profiles: map communication technology to the paper's offloading
//! cost `o` and to simulated link behaviour for the serving-path simulator.
//!
//! The paper treats `o` as user-defined, bounded by ~5x the per-layer
//! computational cost across 3G/4G/5G/Wi-Fi (section 5.2, citing Kuang et
//! al. for the cost calculus).  The simulator additionally needs latency and
//! bandwidth figures; these are representative uplink numbers for each
//! generation, used only for wall-clock serving metrics — the paper's
//! tables/figures are all in lambda units and do not depend on them.

/// A communication technology between edge and cloud.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetworkKind {
    WiFi,
    FiveG,
    FourG,
    ThreeG,
}

impl NetworkKind {
    /// Canonical lowercase name, the one [`NetworkProfile::by_name`] parses
    /// and the per-cohort metrics key on.
    pub fn name(&self) -> &'static str {
        match self {
            NetworkKind::WiFi => "wifi",
            NetworkKind::FiveG => "5g",
            NetworkKind::FourG => "4g",
            NetworkKind::ThreeG => "3g",
        }
    }
}

/// Link model: paper-cost plus simulator latency/bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    pub kind: NetworkKind,
    /// offloading cost in lambda units (paper's o)
    pub offload_lambda: f64,
    /// one-way base latency, milliseconds
    pub base_latency_ms: f64,
    /// uplink bandwidth, megabits/s
    pub uplink_mbps: f64,
    /// probability a transfer needs a retransmission (failure injection)
    pub loss_rate: f64,
}

impl NetworkProfile {
    pub fn wifi() -> NetworkProfile {
        NetworkProfile {
            kind: NetworkKind::WiFi,
            offload_lambda: 1.0,
            base_latency_ms: 2.0,
            uplink_mbps: 100.0,
            loss_rate: 0.001,
        }
    }

    pub fn five_g() -> NetworkProfile {
        NetworkProfile {
            kind: NetworkKind::FiveG,
            offload_lambda: 2.0,
            base_latency_ms: 10.0,
            uplink_mbps: 50.0,
            loss_rate: 0.005,
        }
    }

    pub fn four_g() -> NetworkProfile {
        NetworkProfile {
            kind: NetworkKind::FourG,
            offload_lambda: 3.5,
            base_latency_ms: 35.0,
            uplink_mbps: 10.0,
            loss_rate: 0.01,
        }
    }

    pub fn three_g() -> NetworkProfile {
        NetworkProfile {
            kind: NetworkKind::ThreeG,
            offload_lambda: 5.0,
            base_latency_ms: 100.0,
            uplink_mbps: 1.5,
            loss_rate: 0.03,
        }
    }

    pub fn by_name(name: &str) -> Option<NetworkProfile> {
        match name.to_ascii_lowercase().as_str() {
            "wifi" => Some(Self::wifi()),
            "5g" | "fiveg" => Some(Self::five_g()),
            "4g" | "fourg" => Some(Self::four_g()),
            "3g" | "threeg" => Some(Self::three_g()),
            _ => None,
        }
    }

    /// All profiles, best to worst.
    pub fn all() -> Vec<NetworkProfile> {
        vec![Self::wifi(), Self::five_g(), Self::four_g(), Self::three_g()]
    }

    /// Simulated one-way transfer time for a payload, in milliseconds.
    pub fn transfer_ms(&self, payload_bytes: usize) -> f64 {
        self.base_latency_ms + (payload_bytes as f64 * 8.0 / 1e6) / self.uplink_mbps * 1e3
    }

    /// A modulated copy of this profile: bandwidth and latency scaled by the
    /// instantaneous link condition, with the offloading cost re-derived from
    /// the *effective* bandwidth via [`offload_lambda_for_uplink`].  This is
    /// how the dynamic-link scenarios ([`crate::sim::link::LinkScenario`])
    /// turn a base profile into a time-varying one.
    pub fn scaled(&self, bandwidth_scale: f64, latency_scale: f64) -> NetworkProfile {
        let uplink_mbps = (self.uplink_mbps * bandwidth_scale).max(1e-6);
        NetworkProfile {
            kind: self.kind,
            offload_lambda: offload_lambda_for_uplink(uplink_mbps),
            base_latency_ms: self.base_latency_ms * latency_scale,
            uplink_mbps,
            loss_rate: self.loss_rate,
        }
    }
}

/// Map an instantaneous uplink bandwidth to the paper's offloading cost `o`
/// (lambda units, clamped to the paper's `1..=5` range).
///
/// The interpolation is logarithmic, anchored at the two extremes the paper
/// tabulates — Wi-Fi (100 Mbit/s, `o = 1`) and 3G (1.5 Mbit/s, `o = 5`) —
/// so the static profiles land close to their hand-assigned costs (4G:
/// ~3.2 vs 3.5, 5G: ~1.7 vs 2.0) while a *time-varying* link gets a
/// continuous, monotone cost the dynamic scenarios can sample per batch.
pub fn offload_lambda_for_uplink(uplink_mbps: f64) -> f64 {
    const HI_MBPS: f64 = 100.0; // Wi-Fi anchor, o = 1
    const LO_MBPS: f64 = 1.5; // 3G anchor,  o = 5
    if uplink_mbps <= 0.0 {
        return 5.0;
    }
    let t = (HI_MBPS.ln() - uplink_mbps.ln()) / (HI_MBPS.ln() - LO_MBPS.ln());
    (1.0 + 4.0 * t).clamp(1.0, 5.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_costs_span_paper_range() {
        // paper: o in {lambda .. 5 lambda}
        for p in NetworkProfile::all() {
            assert!((1.0..=5.0).contains(&p.offload_lambda), "{:?}", p.kind);
        }
        assert_eq!(NetworkProfile::three_g().offload_lambda, 5.0);
        assert_eq!(NetworkProfile::wifi().offload_lambda, 1.0);
    }

    #[test]
    fn worse_generation_means_higher_cost_and_latency() {
        let all = NetworkProfile::all();
        for w in all.windows(2) {
            assert!(w[0].offload_lambda <= w[1].offload_lambda);
            assert!(w[0].base_latency_ms < w[1].base_latency_ms);
            assert!(w[0].uplink_mbps > w[1].uplink_mbps);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(NetworkProfile::by_name("wifi").unwrap().kind, NetworkKind::WiFi);
        assert_eq!(NetworkProfile::by_name("5G").unwrap().kind, NetworkKind::FiveG);
        assert_eq!(NetworkProfile::by_name("4g").unwrap().kind, NetworkKind::FourG);
        assert_eq!(NetworkProfile::by_name("3g").unwrap().kind, NetworkKind::ThreeG);
        assert!(NetworkProfile::by_name("2g").is_none());
    }

    #[test]
    fn kind_name_roundtrips_through_by_name() {
        for p in NetworkProfile::all() {
            let named = NetworkProfile::by_name(p.kind.name()).unwrap();
            assert_eq!(named.kind, p.kind);
        }
    }

    #[test]
    fn offload_lambda_interpolation_hits_anchors_and_is_monotone() {
        assert!((offload_lambda_for_uplink(100.0) - 1.0).abs() < 1e-9);
        assert!((offload_lambda_for_uplink(1.5) - 5.0).abs() < 1e-9);
        // clamped outside the anchored range, worst case for a dead link
        assert_eq!(offload_lambda_for_uplink(1000.0), 1.0);
        assert_eq!(offload_lambda_for_uplink(0.01), 5.0);
        assert_eq!(offload_lambda_for_uplink(0.0), 5.0);
        let mut prev = offload_lambda_for_uplink(0.5);
        for mbps in [1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 200.0] {
            let o = offload_lambda_for_uplink(mbps);
            assert!(o <= prev, "o must fall as bandwidth rises ({mbps} Mbps)");
            prev = o;
        }
        // the static profiles' hand-assigned costs are near the curve
        for p in NetworkProfile::all() {
            let derived = offload_lambda_for_uplink(p.uplink_mbps);
            assert!(
                (derived - p.offload_lambda).abs() < 0.6,
                "{:?}: derived {derived:.2} vs assigned {}",
                p.kind,
                p.offload_lambda
            );
        }
    }

    #[test]
    fn scaled_profile_modulates_bandwidth_latency_and_cost() {
        let base = NetworkProfile::wifi();
        let degraded = base.scaled(0.015, 4.0);
        assert_eq!(degraded.kind, base.kind);
        assert!((degraded.uplink_mbps - 1.5).abs() < 1e-9);
        assert!((degraded.base_latency_ms - 8.0).abs() < 1e-9);
        assert!((degraded.offload_lambda - 5.0).abs() < 1e-9, "1.5 Mbps is the o=5 anchor");
        // identity scaling re-derives only the offload cost
        let same = base.scaled(1.0, 1.0);
        assert_eq!(same.uplink_mbps, base.uplink_mbps);
        assert_eq!(same.base_latency_ms, base.base_latency_ms);
        assert!((same.offload_lambda - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_scales_with_payload() {
        let p = NetworkProfile::four_g();
        let small = p.transfer_ms(1_000);
        let large = p.transfer_ms(1_000_000);
        assert!(large > small);
        assert!(small >= p.base_latency_ms);
    }
}

//! Cost model (paper section 3) and network profiles.
//!
//! All costs are expressed in **lambda units**, the paper's abstract per-layer
//! computational cost.  `lambda = lambda1 + lambda2` splits into processing
//! (`lambda1`) and exit-head inference (`lambda2 = lambda1 / 6` — the paper
//! counts 5 matmuls to process a layer and 1 to infer).  Offloading costs
//! `o ∈ {1..5} * lambda` depending on the network generation.
//!
//! Under a dynamic link (`--link markov|trace:<path>`) the offloading cost
//! is no longer a constant: [`offload_lambda_for_uplink`] maps the
//! instantaneous uplink bandwidth into the paper's `1..=5` range and
//! [`CostModel::with_offload`] charges one batch's rewards at that
//! instantaneous cost, leaving every other knob untouched (see
//! [`crate::sim::link`]).

pub mod network;

pub use network::{offload_lambda_for_uplink, NetworkProfile};

/// The paper's cost/reward model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// per-layer total cost lambda (paper sets 1.0 wlog)
    pub lambda: f64,
    /// per-layer processing share (5/6 lambda)
    pub lambda1: f64,
    /// per-exit inference share (1/6 lambda)
    pub lambda2: f64,
    /// offloading cost o, in the same units
    pub offload: f64,
    /// confidence<->cost conversion factor mu (paper: 0.1)
    pub mu: f64,
    /// number of layers L
    pub n_layers: usize,
}

impl CostModel {
    /// Paper configuration: `lambda = 1`, `lambda2 = lambda1 / 6`.
    pub fn paper(offload_lambda: f64, mu: f64, n_layers: usize) -> CostModel {
        let lambda = 1.0;
        let lambda1 = lambda * 6.0 / 7.0;
        let lambda2 = lambda / 7.0;
        CostModel { lambda, lambda1, lambda2, offload: offload_lambda * lambda, mu, n_layers }
    }

    /// Computation cost of processing up to layer `i` (1-based) and running
    /// a *single* exit head there — the SplitEE variant's cost
    /// (`lambda1 * i + lambda2`).
    pub fn compute_cost_splitee(&self, layer_1based: usize) -> f64 {
        self.lambda1 * layer_1based as f64 + self.lambda2
    }

    /// Computation cost of processing up to layer `i` (1-based) evaluating
    /// *every* exit head on the way — the SplitEE-S variant and the
    /// DeeBERT/ElasticBERT threshold cascades (`lambda * i`).
    pub fn compute_cost_cascade(&self, layer_1based: usize) -> f64 {
        self.lambda * layer_1based as f64
    }

    /// Reward (paper eq. 1) when the sample **exits** at split layer `i`
    /// (1-based) with confidence `conf_i`.  `side_info` selects the cascade
    /// cost (SplitEE-S) vs the single-head cost (SplitEE).
    pub fn reward_exit(&self, layer_1based: usize, conf_i: f64, side_info: bool) -> f64 {
        conf_i - self.mu * self.gamma(layer_1based, side_info)
    }

    /// Reward (paper eq. 1) when the sample is **offloaded** from split layer
    /// `i` and infers at the final layer with confidence `conf_l`.
    pub fn reward_offload(&self, layer_1based: usize, conf_l: f64, side_info: bool) -> f64 {
        conf_l - self.mu * (self.gamma(layer_1based, side_info) + self.offload)
    }

    /// gamma_i: computation cost charged at split layer `i` (1-based).
    pub fn gamma(&self, layer_1based: usize, side_info: bool) -> f64 {
        if side_info {
            self.compute_cost_cascade(layer_1based)
        } else {
            self.compute_cost_splitee(layer_1based)
        }
    }

    /// Cost actually *accumulated* for a sample: computation at the split +
    /// offload cost if it was offloaded.  This is what Table 2 / Figures 4, 6
    /// total (in lambda units).
    pub fn total_cost(&self, layer_1based: usize, offloaded: bool, side_info: bool) -> f64 {
        self.gamma(layer_1based, side_info) + if offloaded { self.offload } else { 0.0 }
    }

    /// Cost of the final-exit baseline: every sample through all L layers.
    pub fn final_exit_cost(&self) -> f64 {
        self.lambda * self.n_layers as f64
    }

    /// A copy of this model with the offloading cost replaced — how the
    /// dynamic-link scenarios charge the *instantaneous* communication cost
    /// (`o` re-derived from the sampled link state) without touching any
    /// other knob.  `offload_lambda` is in lambda units, like
    /// [`CostModel::paper`]'s first argument.
    pub fn with_offload(mut self, offload_lambda: f64) -> CostModel {
        self.offload = offload_lambda * self.lambda;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    #[test]
    fn lambda_split_matches_paper_ratio() {
        let c = cm();
        assert!((c.lambda1 + c.lambda2 - c.lambda).abs() < 1e-12);
        assert!((c.lambda2 - c.lambda1 / 6.0).abs() < 1e-12, "lambda2 = lambda1/6");
    }

    #[test]
    fn splitee_cost_cheaper_than_cascade() {
        let c = cm();
        for i in 2..=12 {
            assert!(c.compute_cost_splitee(i) < c.compute_cost_cascade(i));
        }
        // at layer 1 both run exactly one head: identical cost
        assert!((c.compute_cost_splitee(1) - c.compute_cost_cascade(1)).abs() < 1e-12);
    }

    #[test]
    fn reward_exit_matches_eq1() {
        let c = cm();
        // r(i) = C_i - mu * gamma_i
        let r = c.reward_exit(4, 0.9, false);
        let expected = 0.9 - 0.1 * (c.lambda1 * 4.0 + c.lambda2);
        assert!((r - expected).abs() < 1e-12);
    }

    #[test]
    fn reward_offload_matches_eq1() {
        let c = cm();
        // r(i) = C_L - mu * (gamma_i + o)
        let r = c.reward_offload(4, 0.95, false);
        let expected = 0.95 - 0.1 * (c.lambda1 * 4.0 + c.lambda2 + 5.0);
        assert!((r - expected).abs() < 1e-12);
    }

    #[test]
    fn offload_is_charged_in_total_cost() {
        let c = cm();
        let exit = c.total_cost(3, false, false);
        let off = c.total_cost(3, true, false);
        assert!((off - exit - 5.0).abs() < 1e-12);
    }

    #[test]
    fn final_exit_cost_is_lambda_l() {
        assert!((cm().final_exit_cost() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn deeper_exit_costs_more() {
        let c = cm();
        for i in 1..12 {
            assert!(c.compute_cost_splitee(i) < c.compute_cost_splitee(i + 1));
            assert!(c.compute_cost_cascade(i) < c.compute_cost_cascade(i + 1));
        }
    }

    #[test]
    fn with_offload_replaces_only_the_offload_cost() {
        let c = cm();
        let cheap = c.with_offload(1.0);
        assert!((cheap.offload - 1.0).abs() < 1e-12);
        assert_eq!(cheap.lambda1, c.lambda1);
        assert_eq!(cheap.mu, c.mu);
        // exit rewards are untouched; offload rewards shift by mu * delta_o
        assert_eq!(cheap.reward_exit(3, 0.9, false), c.reward_exit(3, 0.9, false));
        let shift = c.reward_offload(3, 0.9, false) - cheap.reward_offload(3, 0.9, false);
        assert!((shift - c.mu * 4.0).abs() < 1e-12);
    }

    #[test]
    fn paper_observation_layer6_crossover() {
        // Section 5.4: processing beyond layer 6 costs more than the
        // worst-case offload (o = 5 lambda).
        let c = cm();
        assert!(c.compute_cost_cascade(6) > c.offload);
        assert!(c.compute_cost_cascade(5) <= c.offload);
    }
}

//! Edge–cloud co-inference simulator.
//!
//! The paper's deployment (figure 1) runs layers `1..=i` on a mobile device,
//! ships the split-layer activations over a mobile network, and finishes on
//! a GPU cloud.  This module reproduces that *timing and energy* behaviour
//! around the real PJRT computation: the compute happens for real (CPU), and
//! the simulator scales edge compute time, adds link latency from the
//! [`NetworkProfile`], and accounts energy/cost per the paper's lambda model.

pub mod device;
pub mod link;
pub mod pipeline;

pub use device::{CloudSim, EdgeSim};
pub use link::LinkSim;
pub use pipeline::{CoInferencePipeline, SampleTrace};

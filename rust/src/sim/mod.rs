//! Edge–cloud co-inference simulator.
//!
//! The paper's deployment (figure 1) runs layers `1..=i` on a mobile device,
//! ships the split-layer activations over a mobile network, and finishes on
//! a GPU cloud.  This module reproduces that *timing and energy* behaviour
//! around the real computation: the compute happens for real (on whatever
//! backend is selected), and the simulator scales edge compute time, adds
//! link latency from the [`NetworkProfile`], and accounts energy/cost per
//! the paper's lambda model.
//!
//! The [`link`] module additionally hosts the **dynamic-link scenario
//! engine** ([`LinkScenario`] / [`LinkState`]): a time-varying uplink
//! (seeded Markov modulation or trace replay, `--link
//! static|markov|trace:<path>`) sampled once per served batch, which the
//! serving coordinator threads through the uplink simulation, the
//! instantaneous offloading cost and the context-aware split policy.
//!
//! The [`faults`] module hosts the **deterministic replica fault schedule**
//! ([`FaultSchedule`], `--faults kill@…|slow@…|flaky@…`): scripted
//! kill/slow/flaky events keyed on the replica pool's dispatch sequence,
//! which the fault-tolerant cloud tier
//! ([`crate::coordinator::replicas`]) replays bit-identically from a seed.
//!
//! [`NetworkProfile`]: crate::cost::NetworkProfile

//! The [`loadgen`] module hosts the **open-loop fleet load generator**
//! ([`LoadgenConfig`] / [`LoadReport`], `splitee loadgen`): seeded Pareto
//! arrivals with diurnal/surge phases, driven over pipelined TCP
//! connections against the network front end ([`crate::server`]).

pub mod device;
pub mod faults;
pub mod link;
pub mod loadgen;
pub mod pipeline;

pub use device::{CloudSim, EdgeSim};
pub use faults::{FaultEvent, FaultSchedule, FaultState, FaultVerdict};
pub use link::{LinkScenario, LinkSim, LinkState, LinkTrace, MarkovLink};
pub use loadgen::{LoadReport, LoadgenConfig};
pub use pipeline::{CoInferencePipeline, SampleTrace};

//! Deterministic replica fault schedules (`--faults`).
//!
//! The multi-replica cloud tier ([`crate::coordinator::replicas`]) is only
//! testable if its failures are reproducible, so faults are not drawn from
//! wall clock or thread timing: every event is keyed on the pool's
//! **dispatch sequence number** (one tick per dispatch attempt), and the
//! only randomness — flaky-failure draws — comes from per-replica streams
//! expanded from one schedule seed.  The same `(seed, schedule)` pair
//! therefore replays the identical kill/slow/flaky trajectory on every run,
//! which is the foundation of the weaker determinism contract documented in
//! ARCHITECTURE.md.
//!
//! Grammar (events joined by `|`, optional trailing `,seed=<u64>`):
//!
//! - `kill@<batch>:<replica>` — the replica dies at dispatch sequence
//!   `batch` and stays dead (its lane thread exits; later dispatches fail
//!   fast).
//! - `slow@<batch>:<replica>x<factor>` — from dispatch sequence `batch` on,
//!   the replica's host compute time is multiplied by `factor` (a large
//!   factor forces offload-deadline timeouts).
//! - `flaky@<replica>:<p>` — every dispatch to the replica fails with
//!   probability `p`, drawn from that replica's seeded stream.
//!
//! `kill@2:0|flaky@1:0.25,seed=7` kills replica 0 at its first dispatch at
//! or after sequence 2 and makes replica 1 drop about a quarter of its
//! dispatches, reproducibly under seed 7.  `SPLITEE_FAULTS` carries the
//! same grammar into the test suite and CI fault matrix.

use anyhow::{anyhow, bail, Result};

use crate::util::rng::Rng;

/// Seed used when a schedule does not carry an explicit `,seed=` trailer.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA17;

/// One scheduled fault.  `batch` counts the pool's dispatch attempts — the
/// deterministic clock every event is keyed on (with coalescing off and no
/// retries it equals the served batch index).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// the replica dies at dispatch sequence `batch` and never recovers
    Kill {
        /// first dispatch sequence at which the replica is dead
        batch: u64,
        /// target replica id
        replica: usize,
    },
    /// host compute is `factor`x slower from dispatch sequence `batch` on
    Slow {
        /// first dispatch sequence at which the slowdown applies
        batch: u64,
        /// target replica id
        replica: usize,
        /// multiplicative host-time factor (> 0; overlapping events compose)
        factor: f64,
    },
    /// every dispatch to the replica fails with probability `p`
    Flaky {
        /// target replica id
        replica: usize,
        /// per-dispatch failure probability in `[0, 1]`
        p: f64,
    },
}

impl FaultEvent {
    /// The replica this event targets.
    pub fn replica(&self) -> usize {
        match *self {
            FaultEvent::Kill { replica, .. }
            | FaultEvent::Slow { replica, .. }
            | FaultEvent::Flaky { replica, .. } => replica,
        }
    }

    fn render(&self) -> String {
        match *self {
            FaultEvent::Kill { batch, replica } => format!("kill@{batch}:{replica}"),
            FaultEvent::Slow { batch, replica, factor } => {
                format!("slow@{batch}:{replica}x{factor}")
            }
            FaultEvent::Flaky { replica, p } => format!("flaky@{replica}:{p}"),
        }
    }
}

/// A parsed, immutable fault schedule.  The empty schedule (the `Default`)
/// injects nothing — a pool running under it behaves exactly like the
/// single-worker cloud stage it replaced.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
    seed: u64,
}

impl Default for FaultSchedule {
    fn default() -> Self {
        FaultSchedule::none()
    }
}

impl FaultSchedule {
    /// The empty schedule: no faults ever fire.
    pub fn none() -> FaultSchedule {
        FaultSchedule { events: Vec::new(), seed: DEFAULT_FAULT_SEED }
    }

    /// True when the schedule carries no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The scheduled events, in declaration order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Seed of the per-replica flaky streams.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Canonical spelling; `from_name(name())` round-trips.
    pub fn name(&self) -> String {
        if self.events.is_empty() {
            return "none".to_string();
        }
        let events: Vec<String> = self.events.iter().map(FaultEvent::render).collect();
        format!("{},seed={}", events.join("|"), self.seed)
    }

    /// Parse a `--faults` spec.  `""` and `"none"` are the empty schedule;
    /// anything else must match the grammar in the module docs.  This is
    /// the single source of truth for accepted values — `config.rs`
    /// validates CLI input by calling it eagerly.
    pub fn from_name(spec: &str) -> Result<FaultSchedule> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultSchedule::none());
        }
        let mut parts = spec.splitn(2, ',');
        let events_str = parts.next().unwrap_or("");
        let seed = match parts.next() {
            Some(trailer) => {
                let value = trailer
                    .strip_prefix("seed=")
                    .ok_or_else(|| anyhow!("fault trailer {trailer:?} is not seed=<u64>"))?;
                value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("fault seed {value:?} is not a u64"))?
            }
            None => DEFAULT_FAULT_SEED,
        };
        let mut events = Vec::new();
        for event in events_str.split('|') {
            events.push(parse_event(event)?);
        }
        Ok(FaultSchedule { events, seed })
    }

    /// Schedule from the `SPLITEE_FAULTS` environment hook (unset/empty =
    /// no faults).  Panics on an invalid value, naming the variable — a
    /// mistyped schedule must not silently serve fault-free.
    pub fn from_env() -> FaultSchedule {
        match std::env::var("SPLITEE_FAULTS") {
            Ok(v) => match FaultSchedule::from_name(&v) {
                Ok(schedule) => schedule,
                Err(e) => panic!("SPLITEE_FAULTS={v:?} is invalid: {e:#}"),
            },
            Err(_) => FaultSchedule::none(),
        }
    }
}

fn bad_shape(event: &str, shape: &str) -> anyhow::Error {
    anyhow!("fault event {event:?} must be {shape}")
}

fn num<T: std::str::FromStr>(event: &str, field: &str) -> Result<T> {
    field
        .trim()
        .parse()
        .map_err(|_| anyhow!("number {field:?} in fault event {event:?} does not parse"))
}

fn parse_event(event: &str) -> Result<FaultEvent> {
    let event = event.trim();
    let (kind, rest) = event
        .split_once('@')
        .ok_or_else(|| anyhow!("fault event {event:?} is not kill@… | slow@… | flaky@…"))?;
    match kind {
        "kill" => {
            let (batch, replica) = rest
                .split_once(':')
                .ok_or_else(|| bad_shape(event, "kill@<batch>:<replica>"))?;
            Ok(FaultEvent::Kill { batch: num(event, batch)?, replica: num(event, replica)? })
        }
        "slow" => {
            let shape = "slow@<batch>:<replica>x<factor>";
            let (batch, rest) = rest.split_once(':').ok_or_else(|| bad_shape(event, shape))?;
            let (replica, factor) = rest.split_once('x').ok_or_else(|| bad_shape(event, shape))?;
            let factor: f64 = num(event, factor)?;
            if !(factor > 0.0 && factor.is_finite()) {
                bail!("slow factor in {event:?} must be a positive finite number");
            }
            Ok(FaultEvent::Slow { batch: num(event, batch)?, replica: num(event, replica)?, factor })
        }
        "flaky" => {
            let (replica, p) = rest
                .split_once(':')
                .ok_or_else(|| bad_shape(event, "flaky@<replica>:<p>"))?;
            let p: f64 = num(event, p)?;
            if !(0.0..=1.0).contains(&p) {
                bail!("flaky probability in {event:?} must be in [0, 1]");
            }
            Ok(FaultEvent::Flaky { replica: num(event, replica)?, p })
        }
        other => bail!("unknown fault kind {other:?} in {event:?} (kill | slow | flaky)"),
    }
}

/// Verdict for one dispatch attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultVerdict {
    /// no event applies: the dispatch proceeds normally
    Healthy,
    /// the replica is dead (a kill event at or before this sequence)
    Killed,
    /// a flaky draw failed this dispatch
    Failed,
    /// compute proceeds with host time multiplied by the factor
    Slowed(f64),
}

/// Mutable replay state: the schedule plus one seeded stream per replica.
/// Flaky draws are consumed in dispatch order on live replicas only, so the
/// stream position — and with it the whole trajectory — is a pure function
/// of `(seed, schedule, dispatch sequence)`.
#[derive(Debug, Clone)]
pub struct FaultState {
    schedule: FaultSchedule,
    rngs: Vec<Rng>,
}

impl FaultState {
    /// State for a pool of `n_replicas` lanes.
    pub fn new(schedule: FaultSchedule, n_replicas: usize) -> FaultState {
        let mut base = Rng::new(schedule.seed);
        let rngs = (0..n_replicas as u64).map(|i| base.fork(i)).collect();
        FaultState { schedule, rngs }
    }

    /// Evaluate the schedule for dispatch `seq` targeting `replica`.
    /// Precedence: killed > flaky-failed > slowed.  A killed replica never
    /// consumes a flaky draw (it is dead before the draw would happen).
    pub fn verdict(&mut self, seq: u64, replica: usize) -> FaultVerdict {
        let mut slow = 1.0f64;
        for event in self.schedule.events.iter() {
            match *event {
                FaultEvent::Kill { batch, replica: r } if r == replica && seq >= batch => {
                    return FaultVerdict::Killed;
                }
                FaultEvent::Slow { batch, replica: r, factor } if r == replica && seq >= batch => {
                    slow *= factor;
                }
                _ => {}
            }
        }
        for event in self.schedule.events.iter() {
            if let FaultEvent::Flaky { replica: r, p } = *event {
                if r == replica && replica < self.rngs.len() && self.rngs[replica].chance(p) {
                    return FaultVerdict::Failed;
                }
            }
        }
        if slow != 1.0 {
            FaultVerdict::Slowed(slow)
        } else {
            FaultVerdict::Healthy
        }
    }

    /// Replayable state for snapshot persistence: the per-replica flaky
    /// stream positions.  The schedule itself is configuration (part of the
    /// snapshot fingerprint), so only the rng cursors are exported.
    pub fn export_state(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![(
            "rngs",
            crate::util::json::Json::Arr(
                self.rngs.iter().map(crate::persist::rng_to_json).collect(),
            ),
        )])
    }

    /// Restore state exported by [`FaultState::export_state`].  The stream
    /// count must match the pool size this state was built for.
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> Result<()> {
        let arr = v.get("rngs")?.as_arr()?;
        if arr.len() != self.rngs.len() {
            bail!("fault snapshot has {} rng streams, this pool has {}", arr.len(), self.rngs.len());
        }
        let rngs =
            arr.iter().map(crate::persist::rng_from_json).collect::<Result<Vec<_>>>()?;
        self.rngs = rngs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_none_parse_to_the_empty_schedule() {
        assert!(FaultSchedule::from_name("").unwrap().is_empty());
        assert!(FaultSchedule::from_name("none").unwrap().is_empty());
        assert_eq!(FaultSchedule::default().name(), "none");
    }

    #[test]
    fn full_grammar_round_trips() {
        let spec = "kill@2:0|slow@5:1x8|flaky@2:0.25,seed=7";
        let schedule = FaultSchedule::from_name(spec).unwrap();
        assert_eq!(schedule.events().len(), 3);
        assert_eq!(schedule.seed(), 7);
        assert_eq!(schedule.events()[0], FaultEvent::Kill { batch: 2, replica: 0 });
        assert_eq!(schedule.events()[1], FaultEvent::Slow { batch: 5, replica: 1, factor: 8.0 });
        assert_eq!(schedule.events()[2], FaultEvent::Flaky { replica: 2, p: 0.25 });
        let round = FaultSchedule::from_name(&schedule.name()).unwrap();
        assert_eq!(round, schedule);
    }

    #[test]
    fn seed_defaults_when_omitted() {
        let schedule = FaultSchedule::from_name("flaky@0:0.5").unwrap();
        assert_eq!(schedule.seed(), DEFAULT_FAULT_SEED);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_grammar_named() {
        for spec in [
            "kaboom@1:0",
            "kill@1",
            "kill@x:0",
            "slow@1:0",
            "slow@1:0x-3",
            "slow@1:0xinf",
            "flaky@0:1.5",
            "flaky@0:0.5,sneed=9",
            "flaky@0:0.5,seed=banana",
        ] {
            assert!(FaultSchedule::from_name(spec).is_err(), "{spec:?} should not parse");
        }
    }

    #[test]
    fn kill_applies_from_its_batch_on() {
        let schedule = FaultSchedule::from_name("kill@3:1").unwrap();
        let mut state = FaultState::new(schedule, 2);
        assert_eq!(state.verdict(2, 1), FaultVerdict::Healthy);
        assert_eq!(state.verdict(3, 1), FaultVerdict::Killed);
        assert_eq!(state.verdict(100, 1), FaultVerdict::Killed);
        assert_eq!(state.verdict(100, 0), FaultVerdict::Healthy);
    }

    #[test]
    fn overlapping_slow_events_compose_multiplicatively() {
        let schedule = FaultSchedule::from_name("slow@0:0x2|slow@4:0x3").unwrap();
        let mut state = FaultState::new(schedule, 1);
        assert_eq!(state.verdict(0, 0), FaultVerdict::Slowed(2.0));
        assert_eq!(state.verdict(4, 0), FaultVerdict::Slowed(6.0));
    }

    #[test]
    fn kill_precedes_slow_and_flaky() {
        let schedule = FaultSchedule::from_name("kill@0:0|slow@0:0x9|flaky@0:1").unwrap();
        let mut state = FaultState::new(schedule, 1);
        assert_eq!(state.verdict(0, 0), FaultVerdict::Killed);
    }

    #[test]
    fn flaky_trajectory_replays_bit_identically() {
        let schedule = FaultSchedule::from_name("flaky@0:0.4|flaky@1:0.6,seed=99").unwrap();
        let mut a = FaultState::new(schedule.clone(), 2);
        let mut b = FaultState::new(schedule, 2);
        let trace = |state: &mut FaultState| -> Vec<FaultVerdict> {
            (0..64).map(|seq| state.verdict(seq, (seq % 2) as usize)).collect()
        };
        let ta = trace(&mut a);
        assert_eq!(ta, trace(&mut b));
        // p in (0, 1) on both replicas: both outcomes must occur
        assert!(ta.contains(&FaultVerdict::Failed));
        assert!(ta.contains(&FaultVerdict::Healthy));
    }

    #[test]
    fn fault_state_round_trip_resumes_the_flaky_streams() {
        let schedule = FaultSchedule::from_name("flaky@0:0.4|flaky@1:0.6,seed=99").unwrap();
        let mut a = FaultState::new(schedule.clone(), 2);
        for seq in 0..31 {
            a.verdict(seq, (seq % 2) as usize);
        }
        let state = a.export_state();
        let mut b = FaultState::new(schedule.clone(), 2);
        b.import_state(&state).unwrap();
        for seq in 31..95 {
            let r = (seq % 2) as usize;
            assert_eq!(a.verdict(seq, r), b.verdict(seq, r), "seq {seq}");
        }
        // stream-count mismatch (different pool size) is rejected
        let mut wrong = FaultState::new(schedule, 3);
        assert!(wrong.import_state(&state).is_err());
    }

    #[test]
    fn flaky_extremes_are_certain() {
        let schedule = FaultSchedule::from_name("flaky@0:1|flaky@1:0").unwrap();
        let mut state = FaultState::new(schedule, 2);
        for seq in 0..16 {
            assert_eq!(state.verdict(seq, 0), FaultVerdict::Failed);
            assert_eq!(state.verdict(seq, 1), FaultVerdict::Healthy);
        }
    }
}

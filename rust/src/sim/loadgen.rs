//! Open-loop fleet load generator for the TCP front end.
//!
//! Simulates a fleet of edge clients firing at the serving plane the way
//! deployed traffic does: a seeded **heavy-tailed (Pareto) arrival process**
//! (bursts and lulls, not Poisson smoothness), **diurnal/surge phases** that
//! scale the offered rate across the run, and **per-client request mixes**
//! (each client has a Pareto-distributed activity weight and its own token
//! template).  Clients are multiplexed over a bounded set of pipelined TCP
//! connections, each registering a per-connection identity + link profile
//! via the `hello` line, so the server's per-cohort metrics light up.
//!
//! Open-loop means send times come from the schedule, not from replies — an
//! overloaded server sees the full offered rate and must shed, which is
//! exactly the behaviour the admission-control tests and the `loadgen`
//! bench leg measure.  The schedule is generated up front from the seed
//! ([`schedule`]), so two runs with the same config offer identical
//! traffic.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json;
use crate::util::rng::Rng;
use crate::util::stats::LatencyHistogram;

/// One workload phase: `fraction` of the request volume offered at
/// `rate_mul` times the base rate.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub fraction: f64,
    pub rate_mul: f64,
}

/// Load-generator parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// simulated client identities (heavy-tailed activity mix)
    pub clients: usize,
    /// TCP connections the clients multiplex over
    pub conns: usize,
    /// total requests to offer
    pub requests: usize,
    /// tokens per request line (must match the served model)
    pub seq_len: usize,
    /// token id range for the synthetic request mixes
    pub vocab: usize,
    pub seed: u64,
    /// base offered rate, requests/s (phases scale it)
    pub mean_rps: f64,
    /// Pareto shape for inter-arrivals and client weights (>1 for a finite
    /// mean; smaller = heavier tail)
    pub pareto_alpha: f64,
    /// diurnal/surge phases, in order; fractions should sum to ~1
    pub phases: Vec<Phase>,
    /// extra connections that send a request burst and then never read —
    /// the stalled-client stressor
    pub stall_conns: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 64,
            conns: 32,
            requests: 2000,
            seq_len: 8,
            vocab: 64,
            seed: 0x10AD,
            mean_rps: 2000.0,
            pareto_alpha: 1.5,
            phases: vec![
                Phase { name: "night", fraction: 0.2, rate_mul: 0.3 },
                Phase { name: "day", fraction: 0.5, rate_mul: 1.0 },
                Phase { name: "surge", fraction: 0.2, rate_mul: 4.0 },
                Phase { name: "cooldown", fraction: 0.1, rate_mul: 1.0 },
            ],
            stall_conns: 0,
        }
    }
}

/// One scheduled request: offset from the run start, and the client firing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub at: Duration,
    pub client: usize,
}

/// Pareto sample with scale `x_m` and shape `alpha` (inverse transform:
/// `x_m * u^(-1/alpha)`, support `[x_m, inf)`).
fn pareto(rng: &mut Rng, x_m: f64, alpha: f64) -> f64 {
    let u = rng.next_f64().max(1e-12);
    x_m * u.powf(-1.0 / alpha)
}

/// Generate the full arrival schedule deterministically from the seed:
/// Pareto inter-arrivals with mean `1/mean_rps`, compressed/stretched by
/// the phase rate multipliers, each event assigned to a client by its
/// heavy-tailed activity weight.
pub fn schedule(cfg: &LoadgenConfig) -> Vec<Event> {
    assert!(cfg.pareto_alpha > 1.0, "need a finite-mean Pareto shape");
    assert!(cfg.clients > 0 && cfg.mean_rps > 0.0);
    let mut rng = Rng::new(cfg.seed);
    // per-client activity weights: a few clients dominate the mix
    let weights: Vec<f64> =
        (0..cfg.clients).map(|_| pareto(&mut rng, 1.0, cfg.pareto_alpha)).collect();
    // scale so the Pareto mean x_m * a/(a-1) equals the target gap
    let x_m = (cfg.pareto_alpha - 1.0) / (cfg.pareto_alpha * cfg.mean_rps);
    let mut out = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for i in 0..cfg.requests {
        let gap = pareto(&mut rng, x_m, cfg.pareto_alpha);
        t += gap / phase_rate_mul(&cfg.phases, i, cfg.requests);
        out.push(Event {
            at: Duration::from_secs_f64(t),
            client: rng.weighted(&weights),
        });
    }
    out
}

/// The rate multiplier in effect for request `i` of `n`: phases partition
/// the request volume by their fractions.
fn phase_rate_mul(phases: &[Phase], i: usize, n: usize) -> f64 {
    if phases.is_empty() || n == 0 {
        return 1.0;
    }
    let progress = i as f64 / n as f64;
    let total: f64 = phases.iter().map(|p| p.fraction).sum();
    let mut acc = 0.0;
    for p in phases {
        acc += p.fraction / total.max(1e-12);
        if progress < acc {
            return p.rate_mul.max(1e-6);
        }
    }
    phases.last().map(|p| p.rate_mul).unwrap_or(1.0).max(1e-6)
}

/// The deterministic token line client `client` sends (its "request mix").
fn token_line(client: usize, seq_len: usize, vocab: usize) -> String {
    let mut s = String::with_capacity(seq_len * 4);
    for j in 0..seq_len {
        if j > 0 {
            s.push(',');
        }
        s.push_str(&((client.wrapping_mul(131).wrapping_add(j * 17)) % vocab.max(1)).to_string());
    }
    s.push('\n');
    s
}

/// Aggregated outcome of a load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub wall_s: f64,
    /// request lines written to sockets (excludes the stalled burst)
    pub sent: u64,
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    /// requests written by stalled connections (never read back)
    pub stalled_sent: u64,
    pub latency: LatencyHistogram,
    /// sent requests per link profile
    pub per_link: BTreeMap<String, u64>,
}

impl LoadReport {
    pub fn achieved_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.sent as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn served_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.served as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.shed as f64 / self.sent as f64
        }
    }

    /// Every sent request came back exactly once (served, shed or
    /// rejected).  Only meaningful after the server drained — the run
    /// waits for every reader, so it holds unless replies were lost.
    pub fn balanced(&self) -> bool {
        self.sent == self.served + self.shed + self.rejected
    }
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "offered {} requests in {:.2}s ({:.1} rps offered, {:.1} rps served)",
            self.sent,
            self.wall_s,
            self.achieved_rps(),
            self.served_rps(),
        )?;
        writeln!(
            f,
            "served {}   shed {} ({:.1}%)   rejected {}   stalled-sent {}",
            self.served,
            self.shed,
            100.0 * self.shed_rate(),
            self.rejected,
            self.stalled_sent,
        )?;
        writeln!(
            f,
            "latency  p50 {:.2} ms   p99 {:.2} ms   mean {:.2} ms   max {:.2} ms",
            self.latency.percentile_us(50.0) / 1e3,
            self.latency.percentile_us(99.0) / 1e3,
            self.latency.mean_us() / 1e3,
            self.latency.max_us() / 1e3,
        )?;
        let links: Vec<String> =
            self.per_link.iter().map(|(l, n)| format!("{l}:{n}")).collect();
        write!(f, "links    {}", links.join("  "))
    }
}

/// Per-connection tally, merged into the [`LoadReport`].
#[derive(Debug, Default)]
struct ConnResult {
    sent: u64,
    served: u64,
    shed: u64,
    rejected: u64,
    latency: LatencyHistogram,
}

const LINKS: [&str; 4] = ["wifi", "5g", "4g", "3g"];

/// Drive the fleet against a serving plane at `addr` and collect the
/// report.  Blocks until every (non-stalled) connection has sent its
/// schedule and read back a reply for every request; stalled connections
/// are then released.  The server must keep serving for the duration —
/// shut its router down only after this returns.
pub fn run(addr: &str, cfg: &LoadgenConfig) -> Result<LoadReport> {
    let events = schedule(cfg);
    let conns = cfg.conns.max(1);
    let mut per_conn: Vec<Vec<Event>> = (0..conns).map(|_| Vec::new()).collect();
    for e in &events {
        per_conn[e.client % conns].push(*e);
    }

    let stop = Arc::new(AtomicBool::new(false));
    // shared start line so per-connection pacing stays aligned
    let start = Instant::now() + Duration::from_millis(50);

    // stalled stressors first, so they hold their connections during the run
    let mut stall_handles = Vec::new();
    for si in 0..cfg.stall_conns {
        let addr = addr.to_string();
        let stop = Arc::clone(&stop);
        let seq_len = cfg.seq_len;
        let vocab = cfg.vocab;
        stall_handles.push(thread::spawn(move || -> Result<u64> {
            let mut w = TcpStream::connect(&addr).context("stalled connect")?;
            w.write_all(
                format!("hello {{\"client\":\"stalled-{si:02}\",\"link\":\"3g\"}}\n").as_bytes(),
            )?;
            // a burst it never reads replies for: the server's reply path
            // must absorb this without blocking anyone else
            let mut sent = 0u64;
            for _ in 0..64 {
                w.write_all(token_line(usize::MAX - si, seq_len, vocab).as_bytes())?;
                sent += 1;
            }
            while !stop.load(Ordering::Relaxed) {
                thread::sleep(Duration::from_millis(10));
            }
            Ok(sent)
        }));
    }

    let mut handles = Vec::new();
    for (ci, evs) in per_conn.into_iter().enumerate() {
        let addr = addr.to_string();
        let link = LINKS[Rng::new(cfg.seed ^ 0xC0 ^ ci as u64).below(4) as usize].to_string();
        let seq_len = cfg.seq_len;
        let vocab = cfg.vocab;
        handles.push((
            link.clone(),
            thread::spawn(move || conn_worker(&addr, ci, &link, evs, seq_len, vocab, start)),
        ));
    }

    let mut report = LoadReport {
        wall_s: 0.0,
        sent: 0,
        served: 0,
        shed: 0,
        rejected: 0,
        stalled_sent: 0,
        latency: LatencyHistogram::new(),
        per_link: BTreeMap::new(),
    };
    for (link, h) in handles {
        let r = h.join().map_err(|_| anyhow::anyhow!("loadgen connection panicked"))??;
        report.sent += r.sent;
        report.served += r.served;
        report.shed += r.shed;
        report.rejected += r.rejected;
        report.latency.merge(&r.latency);
        *report.per_link.entry(link).or_insert(0) += r.sent;
    }
    report.wall_s = start.elapsed().as_secs_f64().max(0.0);
    stop.store(true, Ordering::Relaxed);
    for h in stall_handles {
        if let Ok(Ok(sent)) = h.join() {
            report.stalled_sent += sent;
        }
    }
    Ok(report)
}

/// One pipelined connection: a sender paced by the schedule and a reader
/// that correlates replies back to send times by id.
fn conn_worker(
    addr: &str,
    ci: usize,
    link: &str,
    events: Vec<Event>,
    seq_len: usize,
    vocab: usize,
    start: Instant,
) -> Result<ConnResult> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let mut w = stream.try_clone().context("clone stream")?;
    w.write_all(format!("hello {{\"client\":\"fleet-{ci:04}\",\"link\":\"{link}\"}}\n").as_bytes())
        .context("hello")?;

    let send_times: Arc<Mutex<Vec<Instant>>> = Arc::new(Mutex::new(Vec::new()));
    let reader = {
        let send_times = Arc::clone(&send_times);
        thread::spawn(move || {
            let mut served = 0u64;
            let mut shed = 0u64;
            let mut rejected = 0u64;
            let mut latency = LatencyHistogram::new();
            for line in BufReader::new(stream).lines() {
                let Ok(line) = line else { break };
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let Ok(v) = json::parse(trimmed) else { continue };
                // the hello ack has no id: not a request reply
                let Some(id) = v.opt("id").and_then(|x| x.as_u64().ok()) else { continue };
                match v.opt("error").and_then(|e| e.as_str().ok()) {
                    None => {
                        served += 1;
                        let sent = {
                            let times =
                                send_times.lock().unwrap_or_else(PoisonError::into_inner);
                            times.get(id as usize).copied()
                        };
                        if let Some(sent) = sent {
                            latency.record_us(sent.elapsed().as_secs_f64() * 1e6);
                        }
                    }
                    Some("shed") => shed += 1,
                    Some(_) => rejected += 1,
                }
            }
            (served, shed, rejected, latency)
        })
    };

    let mut sent = 0u64;
    for e in &events {
        let target = start + e.at;
        let now = Instant::now();
        if target > now {
            thread::sleep(target - now);
        }
        {
            let mut times = send_times.lock().unwrap_or_else(PoisonError::into_inner);
            times.push(Instant::now());
        }
        w.write_all(token_line(e.client, seq_len, vocab).as_bytes())
            .context("send request")?;
        sent += 1;
    }
    // quit closes the server side once every pending reply has drained;
    // the reader then sees EOF
    w.write_all(b"quit\n").context("send quit")?;
    let (served, shed, rejected, latency) =
        reader.join().map_err(|_| anyhow::anyhow!("reader panicked"))?;
    Ok(ConnResult { sent, served, shed, rejected, latency })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> LoadgenConfig {
        LoadgenConfig { requests: 500, clients: 16, conns: 8, ..LoadgenConfig::default() }
    }

    #[test]
    fn schedule_is_deterministic_and_monotone() {
        let cfg = small_cfg();
        let a = schedule(&cfg);
        let b = schedule(&cfg);
        assert_eq!(a, b, "same seed, same schedule");
        assert_eq!(a.len(), cfg.requests);
        for w in a.windows(2) {
            assert!(w[1].at >= w[0].at, "arrival times must be non-decreasing");
        }
        assert!(a.iter().all(|e| e.client < cfg.clients));
        let c = schedule(&LoadgenConfig { seed: 0xDEAD, ..small_cfg() });
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn schedule_hits_the_target_rate_roughly() {
        // with ~uniform phases the mean gap is 1/mean_rps; Pareto tails are
        // noisy, so only pin the order of magnitude
        let cfg = LoadgenConfig {
            requests: 4000,
            mean_rps: 1000.0,
            phases: vec![Phase { name: "flat", fraction: 1.0, rate_mul: 1.0 }],
            ..small_cfg()
        };
        let s = schedule(&cfg);
        let span = s.last().unwrap().at.as_secs_f64();
        let rps = cfg.requests as f64 / span;
        assert!(
            rps > cfg.mean_rps * 0.3 && rps < cfg.mean_rps * 3.0,
            "offered {rps:.0} rps vs target {} rps",
            cfg.mean_rps
        );
    }

    #[test]
    fn surge_phase_compresses_inter_arrivals() {
        let cfg = LoadgenConfig {
            requests: 2000,
            phases: vec![
                Phase { name: "calm", fraction: 0.5, rate_mul: 1.0 },
                Phase { name: "surge", fraction: 0.5, rate_mul: 8.0 },
            ],
            ..small_cfg()
        };
        let s = schedule(&cfg);
        let half = cfg.requests / 2;
        let calm_span = s[half - 1].at.as_secs_f64() - s[0].at.as_secs_f64();
        let surge_span = s.last().unwrap().at.as_secs_f64() - s[half].at.as_secs_f64();
        // same request count in each half; the surged half should be much
        // shorter (8x rate, generous 2x slack for tail noise)
        assert!(
            surge_span < calm_span / 2.0,
            "surge span {surge_span:.3}s vs calm span {calm_span:.3}s"
        );
    }

    #[test]
    fn client_mix_is_heavy_tailed() {
        let cfg = LoadgenConfig { requests: 4000, clients: 32, ..small_cfg() };
        let s = schedule(&cfg);
        let mut counts = vec![0u64; cfg.clients];
        for e in &s {
            counts[e.client] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let uniform = (cfg.requests / cfg.clients) as u64;
        assert!(
            max > uniform * 2,
            "heaviest client sent {max}, uniform share {uniform} — not heavy-tailed"
        );
    }

    #[test]
    fn pareto_respects_scale_and_phase_lookup_covers_edges() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            assert!(pareto(&mut rng, 0.25, 1.5) >= 0.25);
        }
        let phases = vec![
            Phase { name: "a", fraction: 0.5, rate_mul: 2.0 },
            Phase { name: "b", fraction: 0.5, rate_mul: 0.5 },
        ];
        assert_eq!(phase_rate_mul(&phases, 0, 100), 2.0);
        assert_eq!(phase_rate_mul(&phases, 99, 100), 0.5);
        assert_eq!(phase_rate_mul(&[], 5, 100), 1.0);
    }

    #[test]
    fn token_lines_parse_back_and_differ_per_client() {
        let a = token_line(3, 8, 64);
        let b = token_line(4, 8, 64);
        assert_ne!(a, b, "per-client request mixes must differ");
        let toks: Vec<i32> = a
            .trim()
            .split(',')
            .map(|t| t.parse().expect("integer token"))
            .collect();
        assert_eq!(toks.len(), 8);
        assert!(toks.iter().all(|&t| t >= 0 && t < 64));
    }

    #[test]
    fn empty_report_does_not_divide_by_zero() {
        let r = LoadReport {
            wall_s: 0.0,
            sent: 0,
            served: 0,
            shed: 0,
            rejected: 0,
            stalled_sent: 0,
            latency: LatencyHistogram::new(),
            per_link: BTreeMap::new(),
        };
        assert!(r.balanced());
        assert_eq!(r.achieved_rps(), 0.0);
        assert_eq!(r.shed_rate(), 0.0);
        let _ = r.to_string();
    }
}

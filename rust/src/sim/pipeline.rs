//! The full edge->link->cloud co-inference pipeline over the *real* model.
//!
//! This is the serving-path counterpart of the cached experiment harness:
//! every block/head execution goes through PJRT, the split decision comes
//! from a live policy, and the simulator layers edge/cloud timing and link
//! behaviour on top.  Used by `splitee serve`, the examples and the E2E
//! bench.

use std::time::Instant;

use anyhow::Result;

use super::device::{CloudSim, EdgeSim};
use super::link::{LinkSim, TransferResult};
use crate::cost::CostModel;
use crate::model::MultiExitModel;
use crate::tensor::TensorI32;

/// Everything that happened to one request.
#[derive(Debug, Clone)]
pub struct SampleTrace {
    /// 1-based split layer chosen by the policy
    pub split: usize,
    /// 1-based layer whose prediction was served
    pub infer_layer: usize,
    pub offloaded: bool,
    /// the link failed and the sample fell back to full on-device inference
    pub outage_fallback: bool,
    pub prediction: usize,
    pub confidence: f32,
    /// simulated end-to-end latency (edge + link + cloud), ms
    pub latency_ms: f64,
    /// real host compute time spent in PJRT, ms
    pub host_compute_ms: f64,
    /// cost in lambda units (the paper's accounting)
    pub cost_lambda: f64,
    /// edge energy units consumed
    pub energy: f64,
    /// paper reward realised for the split decision
    pub reward: f64,
}

/// Live co-inference executor for one model.
pub struct CoInferencePipeline<'m> {
    pub model: &'m MultiExitModel,
    pub edge: EdgeSim,
    pub cloud: CloudSim,
    pub link: LinkSim,
    pub cost: CostModel,
    /// exit threshold alpha
    pub alpha: f64,
}

impl<'m> CoInferencePipeline<'m> {
    pub fn new(
        model: &'m MultiExitModel,
        link: LinkSim,
        cost: CostModel,
        alpha: f64,
    ) -> CoInferencePipeline<'m> {
        CoInferencePipeline {
            model,
            edge: EdgeSim::default(),
            cloud: CloudSim::default(),
            link,
            cost,
            alpha,
        }
    }

    /// Serve one request (tokens [1, T] or [B, T] with a compiled B) at a
    /// given split layer.  The exit-or-offload rule runs exactly as the
    /// paper describes; `side_info` selects SplitEE-S-style per-layer head
    /// evaluation on the way up.
    pub fn serve(
        &mut self,
        tokens: &TensorI32,
        split_1based: usize,
        side_info: bool,
    ) -> Result<SampleTrace> {
        let l = self.model.n_layers();
        let split = split_1based.clamp(1, l);

        // ---- edge share: embed + blocks 0..split-1 (+ heads if side info)
        let t0 = Instant::now();
        let mut h = self.model.embed(tokens)?;
        let mut prefix_conf: Vec<f32> = Vec::with_capacity(split);
        for layer in 0..split {
            h = self.model.block(&h, layer)?;
            if side_info && layer + 1 < split {
                let eo = self.model.exit_head(&h, layer)?;
                prefix_conf.push(eo.conf[0]);
            }
        }
        let exit_out = self.model.exit_head(&h, split - 1)?;
        prefix_conf.push(exit_out.conf[0]);
        let edge_host_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut host_compute_ms = edge_host_ms;
        let mut latency_ms = self.edge.simulated_ms(edge_host_ms);

        let conf_i = exit_out.conf[0] as f64;
        let exited = conf_i >= self.alpha || split == l;

        if exited {
            let gamma = self.cost.gamma(split, side_info);
            return Ok(SampleTrace {
                split,
                infer_layer: split,
                offloaded: false,
                outage_fallback: false,
                prediction: exit_out.pred[0],
                confidence: exit_out.conf[0],
                latency_ms,
                host_compute_ms,
                cost_lambda: self.cost.total_cost(split, false, side_info),
                energy: self.edge.energy(gamma, false),
                reward: self.cost.reward_exit(split, conf_i, side_info),
            });
        }

        // ---- offload: ship the split-layer activation over the link
        let payload = LinkSim::activation_payload(self.model.seq_len(), h.shape()[2]);
        match self.link.transfer(payload) {
            TransferResult::Delivered { ms, .. } => {
                latency_ms += ms;
                let t1 = Instant::now();
                let h_final = self.model.forward_rest(h, split - 1)?;
                let final_out = self.model.exit_head(&h_final, l - 1)?;
                let cloud_host_ms = t1.elapsed().as_secs_f64() * 1e3;
                host_compute_ms += cloud_host_ms;
                latency_ms += self.cloud.simulated_ms(cloud_host_ms);
                let gamma = self.cost.gamma(split, side_info);
                Ok(SampleTrace {
                    split,
                    infer_layer: l,
                    offloaded: true,
                    outage_fallback: false,
                    prediction: final_out.pred[0],
                    confidence: final_out.conf[0],
                    latency_ms,
                    host_compute_ms,
                    cost_lambda: self.cost.total_cost(split, true, side_info),
                    energy: self.edge.energy(gamma, true),
                    reward: self
                        .cost
                        .reward_offload(split, final_out.conf[0] as f64, side_info),
                })
            }
            TransferResult::Outage => {
                // Service outage (LEE/DEE scenario): degrade to full
                // on-device inference — finish the remaining layers locally.
                let t1 = Instant::now();
                let h_final = self.model.forward_rest(h, split - 1)?;
                let final_out = self.model.exit_head(&h_final, l - 1)?;
                let local_ms = t1.elapsed().as_secs_f64() * 1e3;
                host_compute_ms += local_ms;
                latency_ms += self.edge.simulated_ms(local_ms);
                // cost: the full on-device depth, no offload charge
                let gamma = self.cost.compute_cost_cascade(l);
                Ok(SampleTrace {
                    split,
                    infer_layer: l,
                    offloaded: false,
                    outage_fallback: true,
                    prediction: final_out.pred[0],
                    confidence: final_out.conf[0],
                    latency_ms,
                    host_compute_ms,
                    cost_lambda: gamma,
                    energy: self.edge.energy(gamma, false),
                    reward: self.cost.reward_exit(l, final_out.conf[0] as f64, side_info),
                })
            }
        }
    }
}

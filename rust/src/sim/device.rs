//! Edge-device and cloud compute models.
//!
//! Both wrap the *same* real PJRT computation; they differ in the simulated
//! wall-clock scale factor (an edge NPU is slower than a cloud GPU) and in
//! the energy accounting.  The scale factors only affect reported serving
//! latency — all paper tables/figures are in lambda units and come from the
//! cost model, not from here.

/// Compute-speed and energy model of the edge device.
#[derive(Debug, Clone, Copy)]
pub struct EdgeSim {
    /// simulated slowdown relative to the host CPU executing the block
    pub compute_scale: f64,
    /// energy per lambda unit of on-device computation (abstract joules)
    pub energy_per_lambda: f64,
    /// energy per offloaded payload transmission (abstract joules)
    pub energy_per_offload: f64,
}

impl Default for EdgeSim {
    fn default() -> Self {
        // A mobile NPU runs this tiny encoder slower than a server CPU core;
        // 4x is a representative gap for int8-less f32 inference.
        EdgeSim { compute_scale: 4.0, energy_per_lambda: 1.0, energy_per_offload: 2.5 }
    }
}

impl EdgeSim {
    /// Simulated on-device latency for a real measured host duration.
    pub fn simulated_ms(&self, real_host_ms: f64) -> f64 {
        real_host_ms * self.compute_scale
    }

    /// Battery drain of processing `gamma` lambda units + optional offload.
    pub fn energy(&self, gamma: f64, offloaded: bool) -> f64 {
        gamma * self.energy_per_lambda
            + if offloaded { self.energy_per_offload } else { 0.0 }
    }
}

/// Compute-speed model of the cloud worker.
#[derive(Debug, Clone, Copy)]
pub struct CloudSim {
    /// simulated speedup relative to the host CPU (a GPU runs the remaining
    /// layers much faster)
    pub compute_scale: f64,
    /// fixed service overhead per offloaded request (queueing, batching), ms
    pub service_overhead_ms: f64,
}

impl Default for CloudSim {
    fn default() -> Self {
        CloudSim { compute_scale: 0.25, service_overhead_ms: 1.0 }
    }
}

impl CloudSim {
    /// Simulated cloud latency for a real measured host duration.  With
    /// speculative edge continuation this is fed the *speculative* launch's
    /// measured compute when its result is used (the same rule as the
    /// launch it replaced), and never sees killed speculative work — so
    /// speculation changes no reward or cost accounting, only when the
    /// compute happened (see coordinator::service module docs).
    pub fn simulated_ms(&self, real_host_ms: f64) -> f64 {
        real_host_ms * self.compute_scale + self.service_overhead_ms
    }

    /// A copy with `factor`-scaled compute speed (service overhead
    /// unchanged).  The replica pool derives per-lane profiles from one
    /// base profile this way: `scaled(1.0)` is the homogeneous pool, and a
    /// `slow@` fault is just a large transient factor.
    pub fn scaled(&self, factor: f64) -> CloudSim {
        CloudSim { compute_scale: self.compute_scale * factor, ..*self }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_slower_than_host_cloud_faster() {
        let e = EdgeSim::default();
        let c = CloudSim::default();
        assert!(e.simulated_ms(10.0) > 10.0);
        assert!(c.simulated_ms(10.0) < 10.0 + c.service_overhead_ms + 10.0);
        assert!(c.simulated_ms(10.0) >= c.service_overhead_ms);
    }

    #[test]
    fn scaled_multiplies_compute_not_overhead() {
        let c = CloudSim::default();
        let slow = c.scaled(8.0);
        assert!((slow.compute_scale - 8.0 * c.compute_scale).abs() < 1e-12);
        assert!((slow.service_overhead_ms - c.service_overhead_ms).abs() < 1e-12);
        let base = c.simulated_ms(10.0) - c.service_overhead_ms;
        let scaled = slow.simulated_ms(10.0) - slow.service_overhead_ms;
        assert!((scaled - 8.0 * base).abs() < 1e-9);
    }

    #[test]
    fn energy_charges_offload() {
        let e = EdgeSim::default();
        let stay = e.energy(3.0, false);
        let off = e.energy(3.0, true);
        assert!((off - stay - e.energy_per_offload).abs() < 1e-12);
    }

    #[test]
    fn energy_proportional_to_gamma() {
        let e = EdgeSim::default();
        assert!((e.energy(6.0, false) - 2.0 * e.energy(3.0, false)).abs() < 1e-12);
    }
}

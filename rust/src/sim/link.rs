//! Edge->cloud uplink simulator: latency, jitter, retransmissions, outages.
//!
//! Wraps a [`NetworkProfile`] with stochastic behaviour for the serving
//! simulator and for failure-injection tests (the paper's related work — LEE
//! / DEE — motivates exactly the service-outage scenario; SplitEE degrades
//! to on-device final exit when the link reports an outage).

use crate::cost::NetworkProfile;
use crate::util::rng::Rng;

/// Outcome of one simulated transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransferResult {
    /// delivered after `ms` (including any retransmissions)
    Delivered { ms: f64, retries: u32 },
    /// the link is in outage; the caller must fall back to on-device inference
    Outage,
}

/// Stochastic uplink.
#[derive(Debug)]
pub struct LinkSim {
    pub profile: NetworkProfile,
    /// multiplicative jitter spread (0.1 -> +-10%)
    pub jitter: f64,
    /// probability the link is in outage for a given transfer
    pub outage_rate: f64,
    /// maximum retransmissions before declaring an outage
    pub max_retries: u32,
    rng: Rng,
}

impl LinkSim {
    pub fn new(profile: NetworkProfile, seed: u64) -> LinkSim {
        LinkSim { profile, jitter: 0.1, outage_rate: 0.0, max_retries: 3, rng: Rng::new(seed) }
    }

    /// Simulate transferring `payload_bytes` to the cloud.
    pub fn transfer(&mut self, payload_bytes: usize) -> TransferResult {
        if self.outage_rate > 0.0 && self.rng.chance(self.outage_rate) {
            return TransferResult::Outage;
        }
        let base = self.profile.transfer_ms(payload_bytes);
        let mut total = 0.0;
        let mut retries = 0;
        loop {
            let jitter = 1.0 + self.jitter * (2.0 * self.rng.next_f64() - 1.0);
            total += base * jitter.max(0.1);
            if !self.rng.chance(self.profile.loss_rate) {
                return TransferResult::Delivered { ms: total, retries };
            }
            retries += 1;
            if retries > self.max_retries {
                return TransferResult::Outage;
            }
        }
    }

    /// Payload size of offloading split-layer activations: [T, D] f32 plus a
    /// small header.  (The paper notes `o` depends on the *input* size and
    /// the network; we ship the hidden state like SPINN-style splits.)
    pub fn activation_payload(seq_len: usize, d_model: usize) -> usize {
        seq_len * d_model * 4 + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivery_time_near_profile() {
        let mut link = LinkSim::new(NetworkProfile::wifi(), 1);
        let payload = LinkSim::activation_payload(32, 64);
        let base = link.profile.transfer_ms(payload);
        for _ in 0..100 {
            match link.transfer(payload) {
                TransferResult::Delivered { ms, .. } => {
                    assert!(ms > base * 0.85 && ms < base * 4.0, "ms {ms} base {base}");
                }
                TransferResult::Outage => panic!("wifi should not outage here"),
            }
        }
    }

    #[test]
    fn outage_rate_one_always_fails() {
        let mut link = LinkSim::new(NetworkProfile::wifi(), 2);
        link.outage_rate = 1.0;
        assert_eq!(link.transfer(100), TransferResult::Outage);
    }

    #[test]
    fn lossy_link_retries() {
        let mut link = LinkSim::new(NetworkProfile::three_g(), 3);
        link.profile.loss_rate = 0.5;
        let mut saw_retry = false;
        for _ in 0..200 {
            if let TransferResult::Delivered { retries, .. } = link.transfer(1000) {
                if retries > 0 {
                    saw_retry = true;
                }
            }
        }
        assert!(saw_retry, "expected at least one retransmission");
    }

    #[test]
    fn hopeless_link_becomes_outage() {
        let mut link = LinkSim::new(NetworkProfile::three_g(), 4);
        link.profile.loss_rate = 1.0;
        assert_eq!(link.transfer(1000), TransferResult::Outage);
    }

    #[test]
    fn payload_accounts_activation_size() {
        assert_eq!(LinkSim::activation_payload(32, 64), 32 * 64 * 4 + 64);
    }
}

//! Edge->cloud uplink simulator: latency, jitter, retransmissions, outages —
//! and the **dynamic-link scenario engine** that makes the uplink
//! time-varying.
//!
//! Two layers live here:
//!
//! * [`LinkSim`] wraps a [`NetworkProfile`] with stochastic per-transfer
//!   behaviour (jitter, loss/retransmission, outage) for the serving
//!   simulator and for failure-injection tests (the paper's related work —
//!   LEE / DEE — motivates exactly the service-outage scenario; SplitEE
//!   degrades to on-device final exit when the link reports an outage).
//! * [`LinkScenario`] produces the *instantaneous* link condition, one
//!   [`LinkState`] per served batch: `static` (the fixed profile, the
//!   paper's setting), `markov` (a seeded Markov-modulated good / degraded /
//!   outage chain, the I-SplitEE-style time-varying setting), or
//!   `trace:<path>` (replay of a recorded [`LinkTrace`] file).  The sampled
//!   state carries an effective [`NetworkProfile`] plus the instantaneous
//!   offloading cost, and discretizes into a small **context** id the
//!   context-aware split policy
//!   ([`crate::policy::ContextualSplitPolicy`]) keys its per-context arm
//!   statistics by.
//!
//! Scenario selection is plumbed through `--link static|markov|trace:<path>`
//! on the binary and `examples/serve_stream.rs` (see
//! [`LinkScenario::from_name`]), and through `SPLITEE_LINK` for the test
//! suites ([`LinkScenario::from_env`]).  Everything is deterministic from
//! the scenario's seed / trace, which is what keeps pipelined serving
//! decision-identical to serial replay under a time-varying link.

use std::path::Path;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context as _, Result};

use crate::cost::{offload_lambda_for_uplink, CostModel, NetworkProfile};
use crate::util::rng::Rng;

/// Outcome of one simulated transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransferResult {
    /// delivered after `ms` (including any retransmissions)
    Delivered { ms: f64, retries: u32 },
    /// the link is in outage; the caller must fall back to on-device inference
    Outage,
}

/// Stochastic uplink.
#[derive(Debug, Clone)]
pub struct LinkSim {
    pub profile: NetworkProfile,
    /// multiplicative jitter spread (0.1 -> +-10%)
    pub jitter: f64,
    /// probability the link is in outage for a given transfer
    pub outage_rate: f64,
    /// maximum retransmissions before declaring an outage
    pub max_retries: u32,
    rng: Rng,
}

impl LinkSim {
    pub fn new(profile: NetworkProfile, seed: u64) -> LinkSim {
        LinkSim { profile, jitter: 0.1, outage_rate: 0.0, max_retries: 3, rng: Rng::new(seed) }
    }

    /// Simulate transferring `payload_bytes` to the cloud.
    pub fn transfer(&mut self, payload_bytes: usize) -> TransferResult {
        if self.outage_rate > 0.0 && self.rng.chance(self.outage_rate) {
            return TransferResult::Outage;
        }
        let base = self.profile.transfer_ms(payload_bytes);
        let mut total = 0.0;
        let mut retries = 0;
        loop {
            let jitter = 1.0 + self.jitter * (2.0 * self.rng.next_f64() - 1.0);
            total += base * jitter.max(0.1);
            if !self.rng.chance(self.profile.loss_rate) {
                return TransferResult::Delivered { ms: total, retries };
            }
            retries += 1;
            if retries > self.max_retries {
                return TransferResult::Outage;
            }
        }
    }

    /// Payload size of offloading split-layer activations: `T * D` f32 plus
    /// a small header.  (The paper notes `o` depends on the *input* size and
    /// the network; we ship the hidden state like SPINN-style splits.)
    pub fn activation_payload(seq_len: usize, d_model: usize) -> usize {
        seq_len * d_model * 4 + 64
    }

    /// Replayable state for snapshot persistence: the rng position.  Jitter,
    /// loss and outage draws consume this stream, so a warm restart that
    /// skipped it would diverge from the uninterrupted run at the first
    /// transfer.
    pub fn export_state(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![("rng", crate::persist::rng_to_json(&self.rng))])
    }

    /// Restore state exported by [`LinkSim::export_state`].
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        self.rng = crate::persist::rng_from_json(v.get("rng")?)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Dynamic-link scenario engine
// ---------------------------------------------------------------------------

/// The instantaneous uplink condition for one served batch, sampled from a
/// [`LinkScenario`] at offload time.
///
/// The reply stage threads this through the whole batch: the effective
/// `profile` drives the uplink simulation, `offload_lambda` (when present)
/// replaces the cost model's communication cost `o` for this batch's
/// rewards, and `context` keys the contextual split policy's arm statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkState {
    /// effective instantaneous profile (bandwidth / latency / loss)
    pub profile: NetworkProfile,
    /// the link is in total outage: every offload falls back on-device
    pub outage: bool,
    /// discretized context id, `< LinkScenario::n_contexts()`
    pub context: usize,
    /// human-readable state label (metrics / bench keys); shared, so the
    /// per-batch state sample never allocates
    pub label: Arc<str>,
    /// instantaneous offloading cost in lambda units; `None` means "use the
    /// configured cost" (the static scenario — bit-compatible with a fixed
    /// link)
    pub offload_lambda: Option<f64>,
}

impl LinkState {
    /// The static scenario's state: the base profile, untouched cost.
    fn fixed(base: &NetworkProfile) -> LinkState {
        static LABEL: OnceLock<Arc<str>> = OnceLock::new();
        LinkState {
            profile: *base,
            outage: false,
            context: 0,
            label: LABEL.get_or_init(|| Arc::from("static")).clone(),
            offload_lambda: None,
        }
    }

    /// The cost model this batch's rewards are computed under: the base
    /// model with the offloading cost replaced by the instantaneous one
    /// (identity for the static scenario, so static replay is bit-exact).
    pub fn effective_cost(&self, base: &CostModel) -> CostModel {
        match self.offload_lambda {
            Some(o) => base.with_offload(o),
            None => *base,
        }
    }
}

/// One state of the Markov-modulated link.
#[derive(Debug, Clone, PartialEq)]
pub struct MarkovState {
    /// shared label: cloning the per-batch [`LinkState`] costs a refcount,
    /// not an allocation
    pub label: Arc<str>,
    /// multiplier on the base profile's uplink bandwidth
    pub bandwidth_scale: f64,
    /// multiplier on the base profile's one-way latency
    pub latency_scale: f64,
    /// total outage: transfers fail deterministically in this state
    pub outage: bool,
}

/// A seeded Markov-modulated link model: a chain over [`MarkovState`]s,
/// stepped once per served batch.
///
/// The state sequence is a pure function of the seed (xoshiro256**), so two
/// services built from the same scenario replay identical conditions — the
/// property the serial-vs-pipelined decision-equivalence tests rely on.
#[derive(Debug, Clone)]
pub struct MarkovLink {
    states: Vec<MarkovState>,
    /// row-stochastic transition matrix, `transition[from][to]`
    transition: Vec<Vec<f64>>,
    cur: usize,
    rng: Rng,
}

impl MarkovLink {
    /// Build a chain from explicit states and a row-stochastic transition
    /// matrix, starting in state `start`.
    pub fn new(
        states: Vec<MarkovState>,
        transition: Vec<Vec<f64>>,
        start: usize,
        seed: u64,
    ) -> Result<MarkovLink> {
        if states.is_empty() {
            bail!("markov link needs at least one state");
        }
        if start >= states.len() {
            bail!("markov start state {start} out of range ({} states)", states.len());
        }
        if transition.len() != states.len() {
            bail!(
                "markov transition matrix has {} rows for {} states",
                transition.len(),
                states.len()
            );
        }
        for (i, row) in transition.iter().enumerate() {
            if row.len() != states.len() {
                bail!("markov transition row {i} has {} entries, want {}", row.len(), states.len());
            }
            if row.iter().any(|&p| !p.is_finite() || p < 0.0) {
                bail!("markov transition row {i} has a negative or non-finite probability");
            }
            let sum: f64 = row.iter().sum();
            if (sum - 1.0).abs() > 1e-6 {
                bail!("markov transition row {i} sums to {sum}, want 1");
            }
        }
        Ok(MarkovLink { states, transition, cur: start, rng: Rng::new(seed) })
    }

    /// The canonical three-state scenario the `--link markov` CLI value
    /// selects: a sticky *good* link (the base profile as-is), a sticky
    /// *degraded* link (~8% bandwidth, 4x latency — a congested cell), and a
    /// rare short *outage*.
    pub fn default_scenario(seed: u64) -> MarkovLink {
        let states = vec![
            MarkovState {
                label: "good".into(),
                bandwidth_scale: 1.0,
                latency_scale: 1.0,
                outage: false,
            },
            MarkovState {
                label: "degraded".into(),
                bandwidth_scale: 0.08,
                latency_scale: 4.0,
                outage: false,
            },
            MarkovState {
                label: "outage".into(),
                bandwidth_scale: 0.0,
                latency_scale: 1.0,
                outage: true,
            },
        ];
        let transition = vec![
            vec![0.90, 0.09, 0.01],
            vec![0.15, 0.80, 0.05],
            vec![0.30, 0.30, 0.40],
        ];
        MarkovLink::new(states, transition, 0, seed).expect("canonical scenario is valid")
    }

    /// Advance one batch: sample the next state from the current row.
    /// Returns the new state index.
    pub fn step(&mut self) -> usize {
        self.cur = self.rng.weighted(&self.transition[self.cur]);
        self.cur
    }

    pub fn states(&self) -> &[MarkovState] {
        &self.states
    }

    /// Replayable chain position (current state + rng) for snapshot
    /// persistence.  The state/transition tables are configuration and live
    /// in the snapshot's fingerprint instead.
    pub fn export_state(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("cur", crate::persist::u64_hex(self.cur as u64)),
            ("rng", crate::persist::rng_to_json(&self.rng)),
        ])
    }

    /// Restore a position exported by [`MarkovLink::export_state`].
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        let cur = crate::persist::u64_from_hex(v.get("cur")?)? as usize;
        if cur >= self.states.len() {
            bail!("markov snapshot state {cur} out of range ({} states)", self.states.len());
        }
        let rng = crate::persist::rng_from_json(v.get("rng")?)?;
        self.cur = cur;
        self.rng = rng;
        Ok(())
    }
}

/// One segment of a recorded link trace: hold the given condition for
/// `batches` served batches.  `uplink_mbps == 0` records an outage.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSegment {
    pub batches: u64,
    pub uplink_mbps: f64,
    pub latency_ms: f64,
    pub loss_rate: f64,
}

/// A recorded link trace, replayable (looping) through
/// [`LinkScenario::Trace`].
///
/// The on-disk format is line-oriented text: `#` comments and blank lines
/// are ignored; every other line is four whitespace-separated fields,
/// `batches uplink_mbps latency_ms loss_rate`.  [`LinkTrace::to_text`] and
/// [`LinkTrace::parse`] round-trip exactly (Rust's float `Display` is
/// shortest-round-trip).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkTrace {
    pub segments: Vec<TraceSegment>,
}

impl LinkTrace {
    /// Parse the text format.  Errors name the offending line.
    pub fn parse(text: &str) -> Result<LinkTrace> {
        let mut segments = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 4 {
                bail!(
                    "link trace line {}: want 4 fields `batches uplink_mbps latency_ms \
                     loss_rate`, got {} in {line:?}",
                    lineno + 1,
                    fields.len()
                );
            }
            let batches: u64 = fields[0].parse().with_context(|| {
                format!("link trace line {}: batches {:?}", lineno + 1, fields[0])
            })?;
            if batches == 0 {
                bail!("link trace line {}: a segment must span at least one batch", lineno + 1);
            }
            let num = |i: usize, name: &str| -> Result<f64> {
                fields[i].parse::<f64>().with_context(|| {
                    format!("link trace line {}: {name} {:?}", lineno + 1, fields[i])
                })
            };
            let seg = TraceSegment {
                batches,
                uplink_mbps: num(1, "uplink_mbps")?,
                latency_ms: num(2, "latency_ms")?,
                loss_rate: num(3, "loss_rate")?,
            };
            if !seg.uplink_mbps.is_finite()
                || !seg.latency_ms.is_finite()
                || seg.uplink_mbps < 0.0
                || seg.latency_ms < 0.0
            {
                bail!(
                    "link trace line {}: bandwidth/latency must be finite and non-negative",
                    lineno + 1
                );
            }
            if !(0.0..=1.0).contains(&seg.loss_rate) {
                // NaN fails the range test too — rejected here, not downstream
                bail!("link trace line {}: loss_rate must be in [0, 1]", lineno + 1);
            }
            segments.push(seg);
        }
        if segments.is_empty() {
            bail!("link trace has no segments");
        }
        Ok(LinkTrace { segments })
    }

    /// Serialize back to the text format parsed by [`LinkTrace::parse`].
    pub fn to_text(&self) -> String {
        let mut out = String::from(
            "# splitee-link-trace v1\n# batches uplink_mbps latency_ms loss_rate\n",
        );
        for s in &self.segments {
            out.push_str(&format!(
                "{} {} {} {}\n",
                s.batches, s.uplink_mbps, s.latency_ms, s.loss_rate
            ));
        }
        out
    }

    pub fn load(path: &Path) -> Result<LinkTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading link trace {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parsing link trace {path:?}"))
    }
}

/// Discretize an instantaneous uplink into the trace scenario's context
/// buckets (the contextual policy's arms are kept per bucket).
fn quality_bucket(uplink_mbps: f64, outage: bool) -> usize {
    if outage || uplink_mbps <= 0.0 {
        3
    } else if uplink_mbps >= 25.0 {
        0
    } else if uplink_mbps >= 5.0 {
        1
    } else {
        2
    }
}

/// Shared bucket labels, so trace replay's per-batch state sample never
/// allocates.
fn bucket_label(context: usize) -> Arc<str> {
    static LABELS: OnceLock<[Arc<str>; 4]> = OnceLock::new();
    LABELS.get_or_init(|| ["good".into(), "fair".into(), "poor".into(), "outage".into()])
        [context]
        .clone()
}

/// Seed `--link markov` resolves to when none is given (`markov:<seed>`
/// overrides it).
pub const DEFAULT_MARKOV_SEED: u64 = 0x11A5;

/// A time-varying uplink scenario, stepped once per served batch.
///
/// Cloning a scenario clones its *replay position and seed state*, so every
/// service built from one configured scenario observes the identical
/// condition sequence — serial and pipelined runs of the same arrival order
/// therefore make bit-identical decisions (asserted by
/// `tests/integration.rs::pipelined_matches_serial_decisions`).
#[derive(Debug, Clone, Default)]
pub enum LinkScenario {
    /// the fixed base profile — exactly the pre-scenario behaviour, bit for
    /// bit (no extra randomness is drawn, the cost model is untouched)
    #[default]
    Static,
    /// seeded Markov-modulated link
    Markov(MarkovLink),
    /// looping replay of a recorded [`LinkTrace`]
    Trace {
        trace: LinkTrace,
        /// current segment index
        seg: usize,
        /// batches left in the current segment
        left: u64,
    },
}

impl LinkScenario {
    /// Parse a `--link` value: `static`, `markov`, `markov:<seed>` or
    /// `trace:<path>` (the trace file is read eagerly so a bad path fails at
    /// configuration time, not mid-serve).
    pub fn from_name(name: &str) -> Result<LinkScenario> {
        if name == "static" {
            return Ok(LinkScenario::Static);
        }
        if name == "markov" {
            return Ok(LinkScenario::Markov(MarkovLink::default_scenario(DEFAULT_MARKOV_SEED)));
        }
        if let Some(seed) = name.strip_prefix("markov:") {
            let seed: u64 = seed
                .parse()
                .with_context(|| format!("markov seed {seed:?} is not a u64"))?;
            return Ok(LinkScenario::Markov(MarkovLink::default_scenario(seed)));
        }
        if let Some(path) = name.strip_prefix("trace:") {
            let trace = LinkTrace::load(Path::new(path))?;
            let left = trace.segments[0].batches;
            return Ok(LinkScenario::Trace { trace, seg: 0, left });
        }
        bail!(
            "unknown link scenario {name:?} — accepted values: static, markov, \
             markov:<seed>, trace:<path>"
        )
    }

    /// Test-matrix hook: `SPLITEE_LINK=static|markov|markov:<seed>|
    /// trace:<path>` (default `Static` when unset).  An invalid value
    /// panics with the variable name and the accepted values rather than
    /// silently testing the static path under a dynamic-link job label.
    pub fn from_env() -> LinkScenario {
        match std::env::var("SPLITEE_LINK") {
            Ok(v) => match LinkScenario::from_name(&v) {
                Ok(s) => s,
                Err(e) => panic!("SPLITEE_LINK={v:?} is invalid: {e:#}"),
            },
            Err(_) => LinkScenario::Static,
        }
    }

    /// Scenario family name (reports / bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            LinkScenario::Static => "static",
            LinkScenario::Markov(_) => "markov",
            LinkScenario::Trace { .. } => "trace",
        }
    }

    /// Number of distinct context ids [`LinkState::context`] can take — the
    /// contextual split policy sizes its per-context bandits with this.
    pub fn n_contexts(&self) -> usize {
        match self {
            LinkScenario::Static => 1,
            LinkScenario::Markov(m) => m.states.len(),
            LinkScenario::Trace { .. } => 4, // good / fair / poor / outage
        }
    }

    /// Advance one batch and return the instantaneous link condition, as a
    /// modulation of the configured base profile.
    pub fn next_state(&mut self, base: &NetworkProfile) -> LinkState {
        match self {
            LinkScenario::Static => LinkState::fixed(base),
            LinkScenario::Markov(m) => {
                let idx = m.step();
                let s = &m.states[idx];
                let profile = base.scaled(s.bandwidth_scale.max(1e-6), s.latency_scale);
                LinkState {
                    offload_lambda: Some(profile.offload_lambda),
                    profile,
                    outage: s.outage,
                    context: idx,
                    label: s.label.clone(),
                }
            }
            LinkScenario::Trace { trace, seg, left } => {
                let s = trace.segments[*seg].clone();
                *left -= 1;
                if *left == 0 {
                    *seg = (*seg + 1) % trace.segments.len();
                    *left = trace.segments[*seg].batches;
                }
                let outage = s.uplink_mbps <= 0.0;
                let context = quality_bucket(s.uplink_mbps, outage);
                let profile = NetworkProfile {
                    kind: base.kind,
                    offload_lambda: offload_lambda_for_uplink(s.uplink_mbps),
                    base_latency_ms: s.latency_ms,
                    uplink_mbps: s.uplink_mbps.max(1e-6),
                    loss_rate: s.loss_rate,
                };
                LinkState {
                    offload_lambda: Some(profile.offload_lambda),
                    profile,
                    outage,
                    context,
                    label: bucket_label(context),
                }
            }
        }
    }

    /// Replay position for snapshot persistence, tagged by scenario kind so
    /// a restore into a differently-configured scenario is detected.  The
    /// scenario definition itself (states, trace contents, seed) is
    /// configuration — only the cursor is state.
    pub fn export_state(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            LinkScenario::Static => Json::obj(vec![("kind", Json::Str("static".into()))]),
            LinkScenario::Markov(m) => Json::obj(vec![
                ("kind", Json::Str("markov".into())),
                ("markov", m.export_state()),
            ]),
            LinkScenario::Trace { seg, left, .. } => Json::obj(vec![
                ("kind", Json::Str("trace".into())),
                ("seg", crate::persist::u64_hex(*seg as u64)),
                ("left", crate::persist::u64_hex(*left)),
            ]),
        }
    }

    /// Restore a position exported by [`LinkScenario::export_state`].  The
    /// snapshot's kind must match this scenario's variant, and trace cursors
    /// must point inside the configured trace.
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        let kind = v.get("kind")?.as_str()?;
        if kind != self.name() {
            bail!("snapshot is for a {kind:?} link scenario, this service runs {:?}", self.name());
        }
        match self {
            LinkScenario::Static => Ok(()),
            LinkScenario::Markov(m) => m.import_state(v.get("markov")?),
            LinkScenario::Trace { trace, seg, left } => {
                let new_seg = crate::persist::u64_from_hex(v.get("seg")?)? as usize;
                let new_left = crate::persist::u64_from_hex(v.get("left")?)?;
                if new_seg >= trace.segments.len() {
                    bail!(
                        "trace snapshot segment {new_seg} out of range ({} segments)",
                        trace.segments.len()
                    );
                }
                if new_left == 0 || new_left > trace.segments[new_seg].batches {
                    bail!(
                        "trace snapshot has {new_left} batches left in a {}-batch segment",
                        trace.segments[new_seg].batches
                    );
                }
                *seg = new_seg;
                *left = new_left;
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivery_time_near_profile() {
        let mut link = LinkSim::new(NetworkProfile::wifi(), 1);
        let payload = LinkSim::activation_payload(32, 64);
        let base = link.profile.transfer_ms(payload);
        for _ in 0..100 {
            match link.transfer(payload) {
                TransferResult::Delivered { ms, .. } => {
                    assert!(ms > base * 0.85 && ms < base * 4.0, "ms {ms} base {base}");
                }
                TransferResult::Outage => panic!("wifi should not outage here"),
            }
        }
    }

    #[test]
    fn outage_rate_one_always_fails() {
        let mut link = LinkSim::new(NetworkProfile::wifi(), 2);
        link.outage_rate = 1.0;
        assert_eq!(link.transfer(100), TransferResult::Outage);
    }

    #[test]
    fn lossy_link_retries() {
        let mut link = LinkSim::new(NetworkProfile::three_g(), 3);
        link.profile.loss_rate = 0.5;
        let mut saw_retry = false;
        for _ in 0..200 {
            if let TransferResult::Delivered { retries, .. } = link.transfer(1000) {
                if retries > 0 {
                    saw_retry = true;
                }
            }
        }
        assert!(saw_retry, "expected at least one retransmission");
    }

    #[test]
    fn hopeless_link_becomes_outage() {
        let mut link = LinkSim::new(NetworkProfile::three_g(), 4);
        link.profile.loss_rate = 1.0;
        assert_eq!(link.transfer(1000), TransferResult::Outage);
    }

    #[test]
    fn payload_accounts_activation_size() {
        assert_eq!(LinkSim::activation_payload(32, 64), 32 * 64 * 4 + 64);
    }

    // ---- dynamic-link scenario engine ------------------------------------

    #[test]
    fn static_scenario_is_the_identity() {
        let base = NetworkProfile::three_g();
        let mut sc = LinkScenario::Static;
        assert_eq!(sc.n_contexts(), 1);
        for _ in 0..10 {
            let s = sc.next_state(&base);
            assert_eq!(s.profile, base);
            assert!(!s.outage);
            assert_eq!(s.context, 0);
            assert_eq!(s.offload_lambda, None, "static must not touch the cost model");
            let cm = CostModel::paper(5.0, 0.1, 12);
            assert_eq!(s.effective_cost(&cm), cm);
        }
    }

    #[test]
    fn markov_link_is_seed_reproducible() {
        let base = NetworkProfile::four_g();
        let run = |seed: u64| -> Vec<usize> {
            let mut sc = LinkScenario::Markov(MarkovLink::default_scenario(seed));
            (0..200).map(|_| sc.next_state(&base).context).collect()
        };
        assert_eq!(run(7), run(7), "same seed must replay the same state sequence");
        assert_ne!(run(7), run(8), "different seeds must diverge");
        // a clone replays from the same position
        let mut a = LinkScenario::Markov(MarkovLink::default_scenario(3));
        for _ in 0..17 {
            a.next_state(&base);
        }
        let mut b = a.clone();
        let sa: Vec<usize> = (0..50).map(|_| a.next_state(&base).context).collect();
        let sb: Vec<usize> = (0..50).map(|_| b.next_state(&base).context).collect();
        assert_eq!(sa, sb, "clone must carry the replay position and rng state");
    }

    #[test]
    fn markov_states_modulate_profile_and_cost() {
        let base = NetworkProfile::wifi();
        let mut sc = LinkScenario::Markov(MarkovLink::default_scenario(11));
        assert_eq!(sc.n_contexts(), 3);
        let mut seen = [false; 3];
        for _ in 0..500 {
            let s = sc.next_state(&base);
            seen[s.context] = true;
            match &*s.label {
                "good" => {
                    assert!(!s.outage);
                    assert_eq!(s.profile.uplink_mbps, base.uplink_mbps);
                    assert!((s.offload_lambda.unwrap() - 1.0).abs() < 1e-9);
                }
                "degraded" => {
                    assert!(!s.outage);
                    assert!(s.profile.uplink_mbps < base.uplink_mbps);
                    assert!(s.profile.base_latency_ms > base.base_latency_ms);
                    assert!(s.offload_lambda.unwrap() > 1.5, "degraded offload must cost more");
                }
                "outage" => assert!(s.outage),
                other => panic!("unknown state {other}"),
            }
        }
        assert!(seen.iter().all(|&v| v), "500 steps must visit every canonical state");
    }

    #[test]
    fn markov_validation_rejects_bad_chains() {
        let st = |l: &str| MarkovState {
            label: l.into(),
            bandwidth_scale: 1.0,
            latency_scale: 1.0,
            outage: false,
        };
        assert!(MarkovLink::new(vec![], vec![], 0, 1).is_err(), "empty chain");
        assert!(
            MarkovLink::new(vec![st("a")], vec![vec![1.0]], 1, 1).is_err(),
            "start out of range"
        );
        assert!(
            MarkovLink::new(vec![st("a"), st("b")], vec![vec![1.0, 0.0]], 0, 1).is_err(),
            "missing transition row"
        );
        assert!(
            MarkovLink::new(vec![st("a"), st("b")], vec![vec![0.5, 0.4], vec![0.5, 0.5]], 0, 1)
                .is_err(),
            "row must sum to 1"
        );
        assert!(
            MarkovLink::new(vec![st("a"), st("b")], vec![vec![1.5, -0.5], vec![0.5, 0.5]], 0, 1)
                .is_err(),
            "negative probability"
        );
        assert!(
            MarkovLink::new(
                vec![st("a"), st("b")],
                vec![vec![f64::NAN, 0.5], vec![0.5, 0.5]],
                0,
                1
            )
            .is_err(),
            "NaN probability (NaN defeats both the sign and the row-sum check)"
        );
        assert!(MarkovLink::new(
            vec![st("a"), st("b")],
            vec![vec![0.5, 0.5], vec![0.1, 0.9]],
            0,
            1
        )
        .is_ok());
    }

    #[test]
    fn trace_round_trips_its_file_format() {
        let trace = LinkTrace {
            segments: vec![
                TraceSegment { batches: 6, uplink_mbps: 100.0, latency_ms: 2.0, loss_rate: 0.001 },
                TraceSegment { batches: 4, uplink_mbps: 1.5, latency_ms: 100.0, loss_rate: 0.03 },
                TraceSegment { batches: 2, uplink_mbps: 0.0, latency_ms: 0.0, loss_rate: 0.0 },
            ],
        };
        let text = trace.to_text();
        let parsed = LinkTrace::parse(&text).expect("own output must parse");
        assert_eq!(parsed, trace, "parse(to_text(t)) must be the identity");
        // comments and blank lines are tolerated
        let decorated = format!("\n# hello\n{text}\n# trailing\n");
        assert_eq!(LinkTrace::parse(&decorated).unwrap(), trace);
    }

    #[test]
    fn trace_parse_rejects_malformed_lines_with_line_numbers() {
        for (bad, needle) in [
            ("1 2 3", "4 fields"),
            ("1 2 3 4 5", "4 fields"),
            ("0 10 5 0.0", "at least one batch"),
            ("x 10 5 0.0", "batches"),
            ("1 -1 5 0.0", "non-negative"),
            // "nan" *parses* as f64::NAN, so validation must reject it
            ("1 nan 5 0.0", "finite"),
            ("1 10 nan 0.0", "finite"),
            ("1 10 5 nan", "loss_rate"),
            ("1 10 5 1.5", "loss_rate"),
            ("# only comments\n", "no segments"),
        ] {
            let err = LinkTrace::parse(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{bad:?}: unhelpful error {msg}");
        }
        // line numbers point at the offending line, past comments
        let err = LinkTrace::parse("# header\n1 10 5 0.0\nbroken line here x\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
    }

    #[test]
    fn trace_replay_holds_segments_and_wraps_around() {
        let trace = LinkTrace::parse("2 100 2 0\n1 1.5 80 0\n").unwrap();
        let left = trace.segments[0].batches;
        let mut sc = LinkScenario::Trace { trace, seg: 0, left };
        assert_eq!(sc.n_contexts(), 4);
        let base = NetworkProfile::four_g();
        let labels: Vec<String> =
            (0..7).map(|_| sc.next_state(&base).label.to_string()).collect();
        assert_eq!(
            labels,
            vec!["good", "good", "poor", "good", "good", "poor", "good"],
            "2-batch good segment, 1-batch poor segment, looped"
        );
        let s = sc.next_state(&base);
        assert_eq!(&*s.label, "good");
        assert_eq!(s.profile.uplink_mbps, 100.0);
        assert_eq!(s.profile.base_latency_ms, 2.0);
        assert!((s.offload_lambda.unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn trace_outage_segments_flag_outage() {
        let trace = LinkTrace::parse("1 0 0 0\n1 50 10 0\n").unwrap();
        let left = trace.segments[0].batches;
        let mut sc = LinkScenario::Trace { trace, seg: 0, left };
        let base = NetworkProfile::wifi();
        let s = sc.next_state(&base);
        assert!(s.outage);
        assert_eq!(&*s.label, "outage");
        assert_eq!(s.context, 3);
        let s = sc.next_state(&base);
        assert!(!s.outage);
        assert_eq!(&*s.label, "good");
    }

    #[test]
    fn scenario_from_name_parses_and_rejects() {
        assert!(matches!(LinkScenario::from_name("static").unwrap(), LinkScenario::Static));
        assert!(matches!(LinkScenario::from_name("markov").unwrap(), LinkScenario::Markov(_)));
        assert!(matches!(
            LinkScenario::from_name("markov:42").unwrap(),
            LinkScenario::Markov(_)
        ));
        // markov:<seed> really selects the seed
        let base = NetworkProfile::four_g();
        let mut a = LinkScenario::from_name("markov:42").unwrap();
        let mut b = LinkScenario::Markov(MarkovLink::default_scenario(42));
        for _ in 0..50 {
            assert_eq!(a.next_state(&base), b.next_state(&base));
        }
        // errors are contextful and list the accepted values
        let err = LinkScenario::from_name("5g-only").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("5g-only") && msg.contains("static") && msg.contains("trace:"));
        let err = LinkScenario::from_name("markov:not-a-seed").unwrap_err();
        assert!(format!("{err:#}").contains("not-a-seed"));
        let err = LinkScenario::from_name("trace:/no/such/trace.txt").unwrap_err();
        assert!(format!("{err:#}").contains("/no/such/trace.txt"));
    }

    #[test]
    fn scenario_trace_from_name_loads_files() {
        let p = std::env::temp_dir()
            .join(format!("splitee_link_trace_{}.txt", std::process::id()));
        std::fs::write(&p, "3 40 8 0.002\n2 2 60 0.01\n").unwrap();
        let mut sc = LinkScenario::from_name(&format!("trace:{}", p.display())).unwrap();
        assert_eq!(sc.name(), "trace");
        let base = NetworkProfile::wifi();
        let labels: Vec<String> =
            (0..5).map(|_| sc.next_state(&base).label.to_string()).collect();
        assert_eq!(labels, vec!["good", "good", "good", "poor", "poor"]);
        std::fs::remove_file(&p).unwrap();
    }

    // ---- snapshot persistence --------------------------------------------

    #[test]
    fn link_sim_state_round_trip_resumes_the_draw_stream() {
        let mut a = LinkSim::new(NetworkProfile::three_g(), 9);
        a.profile.loss_rate = 0.3;
        let payload = 4000;
        for _ in 0..25 {
            a.transfer(payload);
        }
        let state = a.export_state();
        let mut b = LinkSim::new(NetworkProfile::three_g(), 9);
        b.profile.loss_rate = 0.3;
        b.import_state(&state).unwrap();
        for i in 0..50 {
            assert_eq!(a.transfer(payload), b.transfer(payload), "transfer {i}");
        }
    }

    #[test]
    fn markov_scenario_state_round_trip_replays_identically() {
        let base = NetworkProfile::four_g();
        let mut a = LinkScenario::Markov(MarkovLink::default_scenario(3));
        for _ in 0..23 {
            a.next_state(&base);
        }
        let state = a.export_state();
        // restore into a *freshly configured* scenario, as a restart would
        let mut b = LinkScenario::Markov(MarkovLink::default_scenario(3));
        b.import_state(&state).unwrap();
        for i in 0..100 {
            assert_eq!(a.next_state(&base), b.next_state(&base), "batch {i}");
        }
    }

    #[test]
    fn trace_scenario_state_round_trip_resumes_mid_segment() {
        let base = NetworkProfile::wifi();
        let trace = LinkTrace::parse("3 40 8 0.002\n2 2 60 0.01\n").unwrap();
        let left = trace.segments[0].batches;
        let mut a = LinkScenario::Trace { trace: trace.clone(), seg: 0, left };
        a.next_state(&base); // now mid-way through segment 0
        let state = a.export_state();
        let mut b = LinkScenario::Trace { trace: trace.clone(), seg: 0, left };
        b.import_state(&state).unwrap();
        for i in 0..10 {
            assert_eq!(a.next_state(&base), b.next_state(&base), "batch {i}");
        }
        // cursors outside the configured trace are rejected without mutation
        let bad_seg = crate::util::json::Json::obj(vec![
            ("kind", crate::util::json::Json::Str("trace".into())),
            ("seg", crate::persist::u64_hex(7)),
            ("left", crate::persist::u64_hex(1)),
        ]);
        let mut c = LinkScenario::Trace { trace: trace.clone(), seg: 0, left };
        assert!(c.import_state(&bad_seg).is_err());
        let bad_left = crate::util::json::Json::obj(vec![
            ("kind", crate::util::json::Json::Str("trace".into())),
            ("seg", crate::persist::u64_hex(0)),
            ("left", crate::persist::u64_hex(99)),
        ]);
        assert!(c.import_state(&bad_left).is_err());
        if let LinkScenario::Trace { seg, left: l, .. } = &c {
            assert_eq!((*seg, *l), (0, left), "rejected imports must not move the cursor");
        }
    }

    #[test]
    fn scenario_import_rejects_mismatched_kind() {
        let mut markov = LinkScenario::Markov(MarkovLink::default_scenario(1));
        let static_state = LinkScenario::Static.export_state();
        let err = markov.import_state(&static_state).unwrap_err();
        assert!(format!("{err:#}").contains("static"), "{err:#}");
        let mut st = LinkScenario::Static;
        assert!(st.import_state(&markov.export_state()).is_err());
        // static's own state is trivially restorable
        assert!(st.import_state(&static_state).is_ok());
    }
}

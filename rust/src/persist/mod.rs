//! Durable learned state: versioned snapshots with crash-consistent
//! persistence.
//!
//! Everything the serving system *learns* online — bandit arm statistics
//! (aggregate and per-context), the SplitEE-S final-confidence running mean,
//! the link-scenario position, the replica-pool breaker/dispatch state and
//! the executable-cache warmup set — lives in memory and dies with the
//! process, paying the full cold-start exploration regret again on every
//! restart.  This module makes that state durable:
//!
//! - [`Snapshot`] is a versioned envelope (magic + format version + config
//!   fingerprint) of named state sections, serialized through the in-repo
//!   [`Json`] substrate (the offline crate cache has no serde).
//! - [`Snapshot::save`] uses the atomic write-then-rename idiom
//!   ([`crate::util::json::write_atomic`]): write `<path>.tmp`, fsync,
//!   rename — a crash at any byte leaves the previous snapshot intact.
//! - [`Snapshot::load`] is corruption-tolerant by contract: truncated,
//!   garbage, wrong-magic, wrong-version or fingerprint-mismatched files
//!   log a warning and return `None` (cold start); they never panic and
//!   never error.  `tests/persistence.rs` sweeps a truncation through every
//!   byte offset to pin this.
//! - The hex codecs ([`f64_hex`]/[`u64_hex`] and friends) carry numeric
//!   state as bit-pattern strings, because learned state must round-trip
//!   *bit-exactly*: the JSON `f64` path would lose `-0.0` through the
//!   integer `Display` fast path, cannot represent NaN/inf at all, and
//!   rounds `u64` values above 2^53.
//!
//! The consistency point is the reply stage: all bandit updates, scenario
//! advances and metric accounting are serialized there in batch order, so a
//! snapshot taken between two reply-stage iterations is consistent by
//! construction (see ARCHITECTURE.md "Durable state & crash recovery").

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// File-format magic. A file without it is not a snapshot at all.
pub const MAGIC: &str = "splitee-snapshot";

/// Current snapshot format version.  Bump on incompatible layout changes;
/// old versions cold-start (never a migration panic).
pub const VERSION: u64 = 1;

// ---------------- bit-exact numeric codecs ----------------

/// An `f64` as its IEEE-754 bit pattern in hex — exact for every value
/// including `-0.0`, NaN payloads and infinities.
pub fn f64_hex(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

/// Inverse of [`f64_hex`].
pub fn f64_from_hex(v: &Json) -> Result<f64> {
    let s = v.as_str()?;
    let bits = u64::from_str_radix(s, 16)
        .with_context(|| format!("bad f64 bit pattern {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// A `u64` in hex — exact beyond the 2^53 integer range of a JSON number.
pub fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Inverse of [`u64_hex`].
pub fn u64_from_hex(v: &Json) -> Result<u64> {
    let s = v.as_str()?;
    u64::from_str_radix(s, 16).with_context(|| format!("bad u64 hex {s:?}"))
}

/// A slice of `f64` as an array of hex bit patterns.
pub fn arr_f64_hex(values: &[f64]) -> Json {
    Json::Arr(values.iter().map(|v| f64_hex(*v)).collect())
}

/// Inverse of [`arr_f64_hex`].
pub fn vec_f64_from_hex(v: &Json) -> Result<Vec<f64>> {
    v.as_arr()?.iter().map(f64_from_hex).collect()
}

/// An [`Rng`]'s full 256-bit state as four hex words.
pub fn rng_to_json(rng: &Rng) -> Json {
    Json::Arr(rng.state().iter().map(|w| u64_hex(*w)).collect())
}

/// Inverse of [`rng_to_json`].
pub fn rng_from_json(v: &Json) -> Result<Rng> {
    let arr = v.as_arr()?;
    if arr.len() != 4 {
        bail!("rng state needs 4 words, got {}", arr.len());
    }
    let mut s = [0u64; 4];
    for (slot, w) in s.iter_mut().zip(arr) {
        *slot = u64_from_hex(w)?;
    }
    Ok(Rng::from_state(s))
}

// ---------------- snapshot scheduling ----------------

/// Where and how often to snapshot (`--snapshot` / `--snapshot-every`, or
/// the `SPLITEE_SNAPSHOT=<path>[@<every>]` env hook for the suites).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotConfig {
    /// snapshot file path (loaded at startup, written periodically + on
    /// graceful shutdown)
    pub path: PathBuf,
    /// write every N batches; 0 = only on graceful shutdown
    pub every: u64,
}

impl SnapshotConfig {
    /// `SPLITEE_SNAPSHOT=<path>[@<every-batches>]`, `None` when unset or
    /// empty.  Invalid values panic naming the variable, like the other
    /// `SPLITEE_*` hooks — a typo'd test matrix must fail loudly.
    pub fn from_env() -> Option<SnapshotConfig> {
        let raw = std::env::var("SPLITEE_SNAPSHOT").ok()?;
        if raw.is_empty() {
            return None;
        }
        match Self::parse(&raw) {
            Ok(cfg) => Some(cfg),
            Err(e) => panic!(
                "SPLITEE_SNAPSHOT={raw:?}: {e} (expected <path>[@<every-batches>])"
            ),
        }
    }

    /// Parse `<path>[@<every>]`.  An `@` suffix must be a batch count; paths
    /// containing a literal `@` must use the CLI flags instead.
    pub fn parse(raw: &str) -> std::result::Result<SnapshotConfig, String> {
        if raw.is_empty() {
            return Err("empty snapshot path".to_string());
        }
        if let Some((path, every)) = raw.rsplit_once('@') {
            if path.is_empty() {
                return Err("empty snapshot path".to_string());
            }
            let every: u64 = every
                .parse()
                .map_err(|_| format!("bad snapshot interval {every:?}"))?;
            Ok(SnapshotConfig { path: PathBuf::from(path), every })
        } else {
            Ok(SnapshotConfig { path: PathBuf::from(raw), every: 0 })
        }
    }
}

// ---------------- the snapshot envelope ----------------

/// A versioned snapshot of all learned/replayable serving state.
///
/// The envelope carries the config fingerprint of the service that wrote it
/// (policy kind + knobs, scenario, pool shape, backend); a snapshot only
/// restores into a service with the *same* fingerprint — warm-starting a
/// 5-layer bandit from a 12-layer run would be silent corruption, not
/// recovery.  Sections are named [`Json`] blobs; readers ignore unknown
/// sections and unknown fields inside them, so old snapshots stay loadable
/// as state grows (forward compatibility is tested per exported struct).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// config fingerprint of the writing service
    pub fingerprint: String,
    /// batches fully accounted (reply stage done) when this was taken
    pub batches: u64,
    /// named state sections ("policy", "scenario", "link", "pool", "warmup")
    pub sections: BTreeMap<String, Json>,
}

impl Snapshot {
    pub fn new(fingerprint: &str, batches: u64) -> Snapshot {
        Snapshot { fingerprint: fingerprint.to_string(), batches, sections: BTreeMap::new() }
    }

    /// Add (or replace) a named state section.
    pub fn insert(&mut self, name: &str, state: Json) {
        self.sections.insert(name.to_string(), state);
    }

    /// A section by name, if present (absent sections cold-start their
    /// subsystem — that is how old snapshots stay loadable).
    pub fn section(&self, name: &str) -> Option<&Json> {
        self.sections.get(name)
    }

    /// Serialize to the on-disk text form.
    pub fn to_text(&self) -> String {
        let mut sections = BTreeMap::new();
        for (k, v) in &self.sections {
            sections.insert(k.clone(), v.clone());
        }
        Json::obj(vec![
            ("magic", Json::Str(MAGIC.to_string())),
            ("version", Json::Num(VERSION as f64)),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("batches", u64_hex(self.batches)),
            ("sections", Json::Obj(sections)),
        ])
        .to_string()
    }

    /// Parse the on-disk text form, validating magic + version.  Errors are
    /// descriptive but the serving path never surfaces them as failures —
    /// [`Snapshot::load`] turns every one into a logged cold start.
    pub fn from_text(text: &str) -> Result<Snapshot> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("not valid JSON: {e}"))?;
        let magic = v.get("magic")?.as_str()?;
        if magic != MAGIC {
            bail!("bad magic {magic:?} (expected {MAGIC:?})");
        }
        let version = v.get("version")?.as_i64()?;
        if version != VERSION as i64 {
            bail!("unsupported snapshot version {version} (this build reads {VERSION})");
        }
        let fingerprint = v.get("fingerprint")?.as_str()?.to_string();
        let batches = u64_from_hex(v.get("batches")?)?;
        let sections = v.get("sections")?.as_obj()?.clone();
        Ok(Snapshot { fingerprint, batches, sections })
    }

    /// Write atomically: `<path>.tmp` + fsync + rename.  The previous
    /// snapshot at `path` survives any mid-write crash.
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_atomic(path, &self.to_text())
            .with_context(|| format!("writing snapshot {path:?}"))
    }

    /// Load a snapshot for a service whose config fingerprint is
    /// `expected_fingerprint`.  **Never panics, never errors**: a missing,
    /// truncated, garbage, wrong-version or mismatched-fingerprint file
    /// logs a warning and returns `None` — the caller cold-starts.
    pub fn load(path: &Path, expected_fingerprint: &str) -> Option<Snapshot> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("snapshot {path:?} unreadable ({e}) — cold start");
                return None;
            }
        };
        let snap = match Snapshot::from_text(&text) {
            Ok(s) => s,
            Err(e) => {
                log::warn!("snapshot {path:?} rejected ({e:#}) — cold start");
                return None;
            }
        };
        if snap.fingerprint != expected_fingerprint {
            log::warn!(
                "snapshot {path:?} was written by a different configuration \
                 ({:?} vs this service's {:?}) — cold start",
                snap.fingerprint,
                expected_fingerprint
            );
            return None;
        }
        Some(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_hex_is_bit_exact_for_hostile_values() {
        for v in [
            0.0,
            -0.0,
            1.0,
            -1.5e-300,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            9_007_199_254_740_993.0, // 2^53 + 1 rounds in plain JSON numbers
        ] {
            let back = f64_from_hex(&f64_hex(v)).unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v} lost bits");
        }
    }

    #[test]
    fn u64_hex_covers_the_full_range() {
        for v in [0u64, 1, 1 << 53, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            assert_eq!(u64_from_hex(&u64_hex(v)).unwrap(), v);
        }
        assert!(u64_from_hex(&Json::Str("not hex".into())).is_err());
        assert!(u64_from_hex(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn rng_json_round_trip_resumes_the_stream() {
        let mut r = Rng::new(0x5EED);
        for _ in 0..11 {
            r.next_u64();
        }
        let j = rng_to_json(&r);
        let mut restored = rng_from_json(&j).unwrap();
        assert_eq!(r.next_u64(), restored.next_u64());
        assert!(rng_from_json(&Json::Arr(vec![u64_hex(1)])).is_err());
    }

    #[test]
    fn snapshot_text_round_trip() {
        let mut s = Snapshot::new("fp:test", 42);
        s.insert("policy", Json::obj(vec![("t", u64_hex(7)), ("q", f64_hex(-0.25))]));
        let back = Snapshot::from_text(&s.to_text()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.batches, 42);
        assert_eq!(
            f64_from_hex(back.section("policy").unwrap().get("q").unwrap()).unwrap(),
            -0.25
        );
    }

    #[test]
    fn unknown_fields_and_sections_are_ignored() {
        // forward compatibility: a future writer may add envelope fields and
        // sections this reader has never heard of
        let s = Snapshot::new("fp", 1);
        let mut v = json::parse(&s.to_text()).unwrap();
        if let Json::Obj(o) = &mut v {
            o.insert("future_field".into(), Json::Str("x".into()));
            if let Some(Json::Obj(secs)) = o.get_mut("sections") {
                secs.insert("future_section".into(), Json::Num(1.0));
            }
        }
        let back = Snapshot::from_text(&v.to_string()).unwrap();
        assert_eq!(back.fingerprint, "fp");
        assert!(back.section("future_section").is_some());
        assert!(back.section("never_written").is_none());
    }

    #[test]
    fn from_text_rejects_garbage_wrong_magic_wrong_version() {
        assert!(Snapshot::from_text("").is_err());
        assert!(Snapshot::from_text("{ not json").is_err());
        assert!(Snapshot::from_text("{\"magic\":\"other\"}").is_err());
        let mut s = Snapshot::new("fp", 0);
        s.insert("x", Json::Null);
        let future = s.to_text().replace("\"version\":1", "\"version\":999");
        let err = Snapshot::from_text(&future).unwrap_err().to_string();
        assert!(err.contains("999"), "error must name the version: {err}");
    }

    #[test]
    fn load_is_corruption_tolerant_and_fingerprint_checked() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("splitee_persist_load_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        assert!(Snapshot::load(&path, "fp").is_none(), "missing file cold-starts");
        let s = Snapshot::new("fp", 3);
        s.save(&path).unwrap();
        assert_eq!(Snapshot::load(&path, "fp").unwrap().batches, 3);
        assert!(Snapshot::load(&path, "other-fp").is_none(), "fingerprint mismatch");
        std::fs::write(&path, "garbage").unwrap();
        assert!(Snapshot::load(&path, "fp").is_none(), "garbage cold-starts");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn snapshot_config_parses_path_and_interval() {
        let c = SnapshotConfig::parse("/tmp/s.json").unwrap();
        assert_eq!((c.path.to_str().unwrap(), c.every), ("/tmp/s.json", 0));
        let c = SnapshotConfig::parse("/tmp/s.json@25").unwrap();
        assert_eq!((c.path.to_str().unwrap(), c.every), ("/tmp/s.json", 25));
        assert!(SnapshotConfig::parse("").is_err());
        assert!(SnapshotConfig::parse("@5").is_err());
        assert!(SnapshotConfig::parse("/tmp/s.json@soon").is_err());
    }
}

//! Host-side tensors: the minimal shape-aware containers the coordinator
//! moves between the data layer, the PJRT runtime and the policies.
//!
//! Only f32 and i32 are needed (matching the artifact formats).  These are
//! deliberately simple row-major buffers — all real math happens inside the
//! compiled XLA executables; the host only slices, batches and pads.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements, buffer has {actual}")]
    ShapeMismatch { shape: Vec<usize>, expected: usize, actual: usize },
    #[error("index {index:?} out of bounds for shape {shape:?}")]
    OutOfBounds { index: Vec<usize>, shape: Vec<usize> },
    #[error("cannot {op} tensors of shapes {a:?} and {b:?}")]
    Incompatible { op: &'static str, a: Vec<usize>, b: Vec<usize> },
}

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

macro_rules! tensor_impl {
    ($name:ident, $ty:ty) => {
        impl $name {
            pub fn new(shape: Vec<usize>, data: Vec<$ty>) -> Result<Self, TensorError> {
                let expected: usize = shape.iter().product();
                if expected != data.len() {
                    return Err(TensorError::ShapeMismatch {
                        shape,
                        expected,
                        actual: data.len(),
                    });
                }
                Ok(Self { shape, data })
            }

            pub fn zeros(shape: Vec<usize>) -> Self {
                let n: usize = shape.iter().product();
                Self { shape, data: vec![<$ty>::default(); n] }
            }

            pub fn scalar(v: $ty) -> Self {
                Self { shape: vec![], data: vec![v] }
            }

            pub fn shape(&self) -> &[usize] {
                &self.shape
            }

            pub fn ndim(&self) -> usize {
                self.shape.len()
            }

            pub fn len(&self) -> usize {
                self.data.len()
            }

            pub fn is_empty(&self) -> bool {
                self.data.is_empty()
            }

            pub fn data(&self) -> &[$ty] {
                &self.data
            }

            pub fn data_mut(&mut self) -> &mut [$ty] {
                &mut self.data
            }

            pub fn into_data(self) -> Vec<$ty> {
                self.data
            }

            /// Flat offset of a multi-index.
            pub fn offset(&self, index: &[usize]) -> Result<usize, TensorError> {
                if index.len() != self.shape.len()
                    || index.iter().zip(&self.shape).any(|(i, s)| i >= s)
                {
                    return Err(TensorError::OutOfBounds {
                        index: index.to_vec(),
                        shape: self.shape.clone(),
                    });
                }
                let mut off = 0;
                for (i, s) in index.iter().zip(&self.shape) {
                    off = off * s + i;
                }
                Ok(off)
            }

            pub fn at(&self, index: &[usize]) -> Result<$ty, TensorError> {
                Ok(self.data[self.offset(index)?])
            }

            /// Rows `lo..hi` along axis 0 as a new tensor.
            pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Self, TensorError> {
                if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
                    return Err(TensorError::OutOfBounds {
                        index: vec![lo, hi],
                        shape: self.shape.clone(),
                    });
                }
                let row: usize = self.shape[1..].iter().product();
                let mut shape = self.shape.clone();
                shape[0] = hi - lo;
                Ok(Self { shape, data: self.data[lo * row..hi * row].to_vec() })
            }

            /// Concatenate along axis 0 (all trailing dims must match).
            /// Single preallocation sized from the parts — no reallocation
            /// churn however many parts are concatenated.
            pub fn concat_rows(parts: &[&Self]) -> Result<Self, TensorError> {
                let first = parts.first().expect("concat of nothing");
                let mut shape = first.shape.clone();
                let total: usize = parts.iter().map(|p| p.data.len()).sum();
                let mut data = Vec::with_capacity(total);
                let mut rows = 0;
                for p in parts {
                    if p.shape[1..] != first.shape[1..] {
                        return Err(TensorError::Incompatible {
                            op: "concat",
                            a: first.shape.clone(),
                            b: p.shape.clone(),
                        });
                    }
                    rows += p.shape[0];
                    data.extend_from_slice(&p.data);
                }
                shape[0] = rows;
                Ok(Self { shape, data })
            }

            /// Gather `rows` (axis-0 indices, any order, duplicates allowed)
            /// into a new contiguous tensor.  This replaces the per-row
            /// `slice_rows` + `concat_rows` pattern on the serving hot path:
            /// one allocation, one copy per row.
            pub fn gather_rows(&self, rows: &[usize]) -> Result<Self, TensorError> {
                if self.shape.is_empty() {
                    return Err(TensorError::OutOfBounds {
                        index: rows.to_vec(),
                        shape: self.shape.clone(),
                    });
                }
                let row: usize = self.shape[1..].iter().product();
                let mut data = Vec::with_capacity(rows.len() * row);
                for &r in rows {
                    if r >= self.shape[0] {
                        return Err(TensorError::OutOfBounds {
                            index: vec![r],
                            shape: self.shape.clone(),
                        });
                    }
                    data.extend_from_slice(&self.data[r * row..(r + 1) * row]);
                }
                let mut shape = self.shape.clone();
                shape[0] = rows.len();
                Ok(Self { shape, data })
            }

            /// Append `other`'s rows in place along axis 0 (trailing dims must
            /// match).  In-place counterpart of [`Self::concat_rows`] for
            /// accumulation loops: amortised O(rows) instead of a fresh
            /// allocation + full copy per append.
            pub fn extend_rows(&mut self, other: &Self) -> Result<(), TensorError> {
                if self.shape.is_empty()
                    || other.shape.is_empty()
                    || self.shape[1..] != other.shape[1..]
                {
                    return Err(TensorError::Incompatible {
                        op: "extend_rows",
                        a: self.shape.clone(),
                        b: other.shape.clone(),
                    });
                }
                self.data.extend_from_slice(&other.data);
                self.shape[0] += other.shape[0];
                Ok(())
            }

            /// Pad axis 0 up to `rows` by repeating the final row.
            /// Used by the dynamic batcher to reach a compiled batch size —
            /// repeating a real row keeps the padded lanes numerically tame.
            /// One exact-size allocation; the repeated row is copied from
            /// within the destination buffer (no intermediate row clone).
            pub fn pad_rows_to(&self, rows: usize) -> Result<Self, TensorError> {
                if self.shape.is_empty() || self.shape[0] == 0 || rows < self.shape[0] {
                    return Err(TensorError::OutOfBounds {
                        index: vec![rows],
                        shape: self.shape.clone(),
                    });
                }
                let row: usize = self.shape[1..].iter().product();
                let mut data = Vec::with_capacity(rows * row);
                data.extend_from_slice(&self.data);
                let last = (self.shape[0] - 1) * row;
                for _ in self.shape[0]..rows {
                    data.extend_from_within(last..last + row);
                }
                let mut shape = self.shape.clone();
                shape[0] = rows;
                Ok(Self { shape, data })
            }
        }
    };
}

tensor_impl!(TensorF32, f32);
tensor_impl!(TensorI32, i32);

impl TensorF32 {
    /// Row-wise argmax for a [N, C] tensor.
    pub fn argmax_rows(&self) -> Result<Vec<usize>, TensorError> {
        if self.ndim() != 2 {
            return Err(TensorError::Incompatible {
                op: "argmax_rows",
                a: self.shape.clone(),
                b: vec![],
            });
        }
        let c = self.shape[1];
        Ok(self
            .data
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(TensorF32::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF32::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = TensorF32::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]).unwrap(), 0.0);
        assert_eq!(t.at(&[0, 2]).unwrap(), 2.0);
        assert_eq!(t.at(&[1, 0]).unwrap(), 3.0);
        assert!(t.at(&[2, 0]).is_err());
        assert!(t.at(&[0]).is_err());
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = TensorI32::new(vec![4, 2], (0..8).collect()).unwrap();
        let a = t.slice_rows(0, 1).unwrap();
        let b = t.slice_rows(1, 4).unwrap();
        assert_eq!(a.shape(), &[1, 2]);
        assert_eq!(b.shape(), &[3, 2]);
        let back = TensorI32::concat_rows(&[&a, &b]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn concat_rejects_mismatched_columns() {
        let a = TensorF32::zeros(vec![1, 2]);
        let b = TensorF32::zeros(vec![1, 3]);
        assert!(TensorF32::concat_rows(&[&a, &b]).is_err());
    }

    #[test]
    fn pad_repeats_last_row() {
        let t = TensorI32::new(vec![2, 2], vec![1, 2, 3, 4]).unwrap();
        let p = t.pad_rows_to(4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(p.data(), &[1, 2, 3, 4, 3, 4, 3, 4]);
        assert!(t.pad_rows_to(1).is_err());
    }

    #[test]
    fn pad_noop_when_full() {
        let t = TensorF32::zeros(vec![3, 2]);
        assert_eq!(t.pad_rows_to(3).unwrap(), t);
    }

    #[test]
    fn gather_rows_matches_slice_concat() {
        let t = TensorF32::new(vec![5, 3], (0..15).map(|x| x as f32).collect()).unwrap();
        for rows in [vec![0usize, 2, 4], vec![3, 1], vec![2, 2, 2], vec![]] {
            let gathered = t.gather_rows(&rows).unwrap();
            // reference: the old per-row slice + concat path
            let parts: Vec<TensorF32> =
                rows.iter().map(|&r| t.slice_rows(r, r + 1).unwrap()).collect();
            if parts.is_empty() {
                assert_eq!(gathered.shape(), &[0, 3]);
                assert!(gathered.is_empty());
            } else {
                let refs: Vec<&TensorF32> = parts.iter().collect();
                assert_eq!(gathered, TensorF32::concat_rows(&refs).unwrap());
            }
        }
        assert!(t.gather_rows(&[5]).is_err());
    }

    #[test]
    fn extend_rows_matches_concat() {
        let a0 = TensorI32::new(vec![2, 2], vec![1, 2, 3, 4]).unwrap();
        let b = TensorI32::new(vec![3, 2], vec![5, 6, 7, 8, 9, 10]).unwrap();
        let expected = TensorI32::concat_rows(&[&a0, &b]).unwrap();
        let mut a = a0.clone();
        a.extend_rows(&b).unwrap();
        assert_eq!(a, expected);
        // mismatched trailing dims rejected, tensor unchanged
        let bad = TensorI32::zeros(vec![1, 3]);
        assert!(a.extend_rows(&bad).is_err());
        assert_eq!(a, expected);
    }

    #[test]
    fn preallocated_pad_and_concat_unchanged() {
        // pin the exact semantics the preallocation rewrite must preserve
        let t = TensorF32::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let p = t.pad_rows_to(4).unwrap();
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(p.data(), &[1., 2., 3., 4., 5., 6., 4., 5., 6., 4., 5., 6.]);
        let c = TensorF32::concat_rows(&[&t, &p]).unwrap();
        assert_eq!(c.shape(), &[6, 3]);
        assert_eq!(&c.data()[..6], t.data());
        assert_eq!(&c.data()[6..], p.data());
    }

    #[test]
    fn argmax_rows() {
        let t = TensorF32::new(vec![2, 3], vec![0.1, 0.7, 0.2, 0.9, 0.05, 0.05]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
        assert!(TensorF32::zeros(vec![3]).argmax_rows().is_err());
    }

    #[test]
    fn scalar_tensor() {
        let t = TensorF32::scalar(5.0);
        assert_eq!(t.ndim(), 0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.at(&[]).unwrap(), 5.0);
    }
}

//! `splitee` — leader binary: experiments, serving, and artifact checks.
//!
//! ```text
//! splitee check                      verify artifacts load + run
//! splitee cache [--datasets a,b]     build confidence caches
//! splitee table1                     paper Table 1 (dataset inventory)
//! splitee table2 [--o 5 --reps 20]   paper Table 2
//! splitee figures                    paper Figures 3-6 (sweep o)
//! splitee regret                     paper Figure 7 (cumulative regret)
//! splitee sec54                      paper section 5.4 analysis
//! splitee ablations --which beta     beta/mu/alpha/side ablations
//! splitee serve --dataset imdb       live co-inference serving demo
//! splitee codec-drift                payload-codec agreement/byte report
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use splitee::config::{Manifest, Settings};
use splitee::coordinator::{BatcherConfig, Router, RouterConfig, Service, ServiceConfig};
use splitee::coordinator::service::{PolicyKind, SpeculateMode};
use splitee::cost::{CostModel, NetworkProfile};
use splitee::data::{Dataset, SampleStream};
use splitee::experiments::{ablations, codec_drift, figures, regret, report, sec5_4,
                           table2, ConfidenceCache};
use splitee::model::{ModelWeights, MultiExitModel};
use splitee::runtime::Backend;
use splitee::server::{serve_tcp, ServerConfig, ServerCounters};
use splitee::sim::{loadgen as fleet, LinkScenario, LinkSim};
use splitee::util::args::Args;
use splitee::util::logging;
use splitee::util::rng::Rng;
use splitee::util::signals;

fn main() {
    let args = Args::from_env();
    let verbosity = if args.has("quiet") { 0 } else if args.has("debug") { 2 } else { 1 };
    logging::init(verbosity);
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let settings = Settings::from_args(args).map_err(|e| anyhow::anyhow!("{e}"))?;
    // size the reference backend's kernel pool before any model loads
    settings.configure_kernel_pool();
    let sub = args.subcommand.as_deref().unwrap_or("help");
    match sub {
        "check" => check(&settings),
        "cache" => cache(args, &settings),
        "table1" => table1(&settings),
        "table2" => {
            let (manifest, backend) = open(&settings)?;
            let out = table2::run(&manifest, &backend, &settings)?;
            println!("{out}");
            Ok(())
        }
        "figures" => {
            let (manifest, backend) = open(&settings)?;
            let out = figures::run(&manifest, &backend, &settings)?;
            println!("{out}");
            Ok(())
        }
        "regret" => {
            let (manifest, backend) = open(&settings)?;
            let out = regret::run(&manifest, &backend, &settings)?;
            println!("{out}");
            Ok(())
        }
        "sec54" => {
            let (manifest, backend) = open(&settings)?;
            let out = sec5_4::run(&manifest, &backend, &settings)?;
            println!("{out}");
            Ok(())
        }
        "ablations" => {
            let (manifest, backend) = open(&settings)?;
            let which = ablations::Which::parse(args.get_or("which", "all"))
                .context("--which must be beta|mu|alpha|side|all")?;
            let dataset = args.get_or("dataset", "imdb").to_string();
            let out = ablations::run(&manifest, &backend, &settings, which, &dataset)?;
            println!("{out}");
            Ok(())
        }
        "serve" => serve(args, &settings),
        "loadgen" => loadgen(args, &settings),
        "codec-drift" => codec_drift_cmd(args, &settings),
        "help" | _ => {
            println!("{}", HELP);
            if sub != "help" {
                bail!("unknown subcommand {sub:?}");
            }
            Ok(())
        }
    }
}

const HELP: &str = "\
splitee — SplitEE: Early Exit in DNNs with Split Computing (reproduction)

USAGE: splitee <subcommand> [flags]

Subcommands
  check        verify artifacts: load manifest, compile graphs, run a sample
  cache        build confidence caches for all eval datasets
  table1       dataset inventory (paper Table 1)
  table2       main results (paper Table 2)
  figures      accuracy/cost vs offloading cost (paper Figures 3-6)
  regret       cumulative regret curves (paper Figure 7)
  sec54        beyond-layer-6 analysis (paper section 5.4)
  ablations    --which beta|mu|alpha|side|all [--dataset imdb]
  serve        live co-inference serving
               [--dataset imdb] [--requests 200] [--policy splitee|splitee-s|
                contextual|fixed:K|final] [--network wifi|5g|4g|3g]
                [--listen ADDR] [--speculate on|off|auto]
                [--link static|markov|markov:SEED|trace:PATH]
                [--replicas N] [--dispatch round-robin|least-loaded]
                [--faults kill@B:R|slow@B:RxF|flaky@R:P[,seed=S]]
                [--snapshot PATH] [--snapshot-every N]
               [--codecs identity,f16,i8,topk:64]
               with --listen HOST:PORT requests arrive over a concurrent
               TCP front end (newline JSON; optional first line
               hello {\"client\":NAME,\"link\":wifi|5g|4g|3g} registers a
               cohort; replies carry the request line number as id;
               over-capacity requests shed with retry_after_ms, never hang)
  loadgen      open-loop fleet load generator (seeded Pareto arrivals,
               diurnal/surge phases, heavy-tailed per-client mixes)
               [--requests 2000] [--clients 64] [--conns 32] [--stalled 0]
               [--rps 2000] [--network wifi|5g|4g|3g]
               [--addr HOST:PORT [--seq-len N] [--vocab N]]
               without --addr it self-hosts a synthetic serving plane on
               loopback and enforces the shed-accounting identity
  codec-drift  per-codec top-1 agreement, confidence drift and uplink byte
               ratio vs the uncompressed continuation, on the synthetic
               reference model (no artifacts needed); folds codec_* keys
               into BENCH_serving.json [--samples 512]
               [--codecs identity,f16,i8,topk:64 (default: that menu)]

Common flags
  --artifacts DIR   artifact directory (default: artifacts)
  --results DIR     results directory  (default: results)
  --backend NAME    compute backend: auto|reference|pjrt (default: auto —
                    pjrt when this build has it, else the pure-Rust
                    reference backend)
  --speculate MODE  speculative edge continuation past the split, killed
                    on exit: on|off|auto (default: auto — on when the
                    backend is decision-transparent and the host has spare
                    parallelism)
  --link SCENARIO   uplink scenario: static|markov|markov:SEED|trace:PATH
                    (default: static — the fixed --network profile; markov
                    and trace vary bandwidth/latency/offload-cost per batch;
                    pair with --policy contextual for per-context splits)
  --replicas N      cloud-tier replica lanes (default: 1); offloads retry
                    on a different replica with backoff, degrade to
                    on-device final exit when none can serve
  --dispatch NAME   replica dispatch policy: round-robin|least-loaded
  --codecs LIST     split-boundary payload codec menu, comma-joined
                    identity|f16|i8|topk:K|dedup:INNER names (default:
                    identity — bit-transparent); with more than one entry
                    the bandit learns over (split, codec) pairs and the
                    uplink is charged from the encoded bytes (also via
                    SPLITEE_CODECS in tests)
  --faults SPEC     deterministic replica fault schedule, '|'-joined
                    kill@BATCH:REPLICA, slow@BATCH:REPLICAxFACTOR and
                    flaky@REPLICA:P events, optional ',seed=N' trailer
                    (default: none; also via SPLITEE_FAULTS in tests)
  --snapshot PATH   durable learned-state snapshot: loaded at startup for a
                    warm restart when PATH exists (fingerprint-checked),
                    written crash-consistently every N batches and at
                    shutdown (also via SPLITEE_SNAPSHOT=PATH[@N])
  --snapshot-every N  snapshot cadence in batches (default: 0 — write only
                    at graceful shutdown); requires --snapshot
  --ref-threads N   reference-backend kernel-pool threads (default: the
                    SPLITEE_REF_THREADS env hook, else available
                    parallelism; numerics are bit-identical for every N)
  --o N             offloading cost in lambda units (default: 5)
  --mu X            cost weight in the reward (default: 0.1)
  --beta X          UCB exploration (default: 1.0)
  --reps N          experiment repetitions (default: 20)
  --seed N          master seed
  --quiet / --debug verbosity
";

fn open(settings: &Settings) -> Result<(Manifest, Backend)> {
    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let backend = Backend::from_name(&settings.backend)?;
    log::info!(
        "backend {} | model {}L d={} | {} tasks, {} datasets",
        backend.name(),
        manifest.model.n_layers,
        manifest.model.d_model,
        manifest.tasks.len(),
        manifest.datasets.len()
    );
    Ok((manifest, backend))
}

/// `splitee check` — end-to-end artifact sanity: compile + run one sample
/// through every graph and compare the layered path to prefix_full.
fn check(settings: &Settings) -> Result<()> {
    let (manifest, backend) = open(settings)?;
    let mut failures = 0;
    for (task_name, task) in &manifest.tasks {
        for style in task.weights.keys() {
            let model = MultiExitModel::load(&manifest, &backend, task_name, style)?;
            // one synthetic sample through the layered path
            let tokens = splitee::tensor::TensorI32::new(
                vec![1, manifest.model.seq_len],
                (0..manifest.model.seq_len as i32)
                    .map(|i| i % manifest.model.vocab as i32)
                    .collect(),
            )
            .map_err(|e| anyhow::anyhow!(e))?;
            let (_h, out) = model.run_split(&tokens, manifest.model.n_layers - 1)?;
            let all = model.forward_all_exits(&tokens)?;
            let diff = (all[manifest.model.n_layers - 1].conf[0] - out.conf[0]).abs();
            let ok = diff < 1e-3;
            if !ok {
                failures += 1;
            }
            println!(
                "{task_name}/{style}: layered final conf {:.4} vs prefix_full {:.4} ({})",
                out.conf[0],
                all[manifest.model.n_layers - 1].conf[0],
                if ok { "OK" } else { "MISMATCH" }
            );
        }
    }
    if failures > 0 {
        bail!("{failures} artifact checks failed");
    }
    println!("all artifact checks passed (backend: {})", backend.name());
    Ok(())
}

/// `splitee cache` — pre-build every confidence cache.
fn cache(args: &Args, settings: &Settings) -> Result<()> {
    let (manifest, backend) = open(settings)?;
    let datasets = args
        .get_list("datasets")
        .unwrap_or_else(|| manifest.eval_datasets());
    for d in &datasets {
        for style in ["elasticbert", "deebert"] {
            let t0 = std::time::Instant::now();
            let c = ConfidenceCache::load_or_build(&manifest, &backend, d, style)?;
            println!(
                "{d}/{style}: {} samples x {} layers ({:.1}s)",
                c.n_samples,
                c.n_layers,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    Ok(())
}

/// `splitee table1` — dataset inventory (paper Table 1).
fn table1(settings: &Settings) -> Result<()> {
    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let mut t = report::Table::new(&[
        "E. Data", "#Samples", "(paper)", "FT Data", "#Samples", "(paper)", "classes",
    ]);
    for name in manifest.eval_datasets() {
        let d = manifest.dataset(&name)?;
        let src = manifest.source_task(&name)?;
        let src_d = manifest.dataset(&src.name)?;
        t.row(vec![
            d.paper_name.clone(),
            format!("{}", d.samples),
            format!("{}", d.paper_samples),
            src_d.paper_name.clone(),
            format!("{}", src_d.samples),
            format!("{}", src_d.paper_samples),
            format!("{}", d.classes),
        ]);
    }
    println!("Table 1 — dataset inventory (sizes scaled to this testbed; see DESIGN.md)");
    println!("{}", t.render());
    Ok(())
}

/// `splitee serve` — live serving through router -> batcher -> service with
/// the co-inference simulator, driven by a dataset replay workload.
fn serve(args: &Args, settings: &Settings) -> Result<()> {
    let (manifest, backend) = open(settings)?;
    let dataset_name = args.get_or("dataset", "imdb").to_string();
    let info = manifest.dataset(&dataset_name)?.clone();
    let task = manifest.source_task(&dataset_name)?.clone();
    let n_requests = args.get_num("requests", 200usize).map_err(anyhow::Error::msg)?;
    let policy = match args.get_or("policy", "splitee") {
        "splitee" => PolicyKind::SplitEe,
        "splitee-s" => PolicyKind::SplitEeS,
        "contextual" => PolicyKind::Contextual,
        "final" => PolicyKind::FinalExit,
        other => {
            if let Some(k) = other.strip_prefix("fixed:") {
                PolicyKind::Fixed(k.parse().context("fixed:K")?)
            } else {
                bail!("unknown policy {other:?}");
            }
        }
    };
    let network = NetworkProfile::by_name(args.get_or("network", "3g"))
        .context("--network must be wifi|5g|4g|3g")?;
    let scenario = LinkScenario::from_name(&settings.link)?;

    let model = Arc::new(MultiExitModel::load(
        &manifest, &backend, &task.name, "elasticbert",
    )?);
    let dataset = Dataset::load(&manifest.root.join(&info.file), &dataset_name)?;
    let cm = CostModel::paper(network.offload_lambda, settings.mu, model.n_layers());
    let link = LinkSim::new(network, settings.seed ^ 0x11);
    let config = ServiceConfig {
        policy,
        alpha: task.alpha,
        beta: settings.beta,
        batcher: BatcherConfig {
            batch_sizes: manifest.batch_sizes.clone(),
            max_wait: std::time::Duration::from_millis(4),
        },
        coalesce: Default::default(),
        speculate: SpeculateMode::from_name(&settings.speculate)?,
        link: scenario,
        replicas: settings.replica_config()?,
        codecs: settings.codec_menu()?,
    };

    let router = Router::new(RouterConfig::default());
    let mut service = Service::new(Arc::clone(&model), cm, link, &config);
    if let Some(snap_cfg) = settings.snapshot_config() {
        if service.restore(&snap_cfg.path) {
            println!("warm restart: restored learned state from {} ({} batches served)",
                     snap_cfg.path.display(), service.batches_done());
        }
        service.set_snapshot(snap_cfg);
    }
    signals::install();

    let tcp_mode = !settings.listen.is_empty();
    let (mut service, got) = if tcp_mode {
        // network front end: the compute loop runs on a background thread,
        // the concurrent accept loop on this one
        let listener = std::net::TcpListener::bind(&settings.listen)
            .with_context(|| format!("binding {}", settings.listen))?;
        let local = listener.local_addr().context("local addr")?;
        println!("listening on {local} ({n_requests} request budget)");
        let compute = {
            let router = Arc::clone(&router);
            let batcher_config = config.batcher.clone();
            std::thread::spawn(move || {
                let outcome = service.run(router, batcher_config);
                (service, outcome)
            })
        };
        // Ctrl-C unblocks the accept loop by shutting the router down
        let watchdog = {
            let router = Arc::clone(&router);
            std::thread::spawn(move || {
                while router.is_accepting() {
                    if signals::interrupted() {
                        router.shutdown();
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(50));
                }
            })
        };
        let counters = ServerCounters::new();
        let served = serve_tcp(
            listener,
            Arc::clone(&router),
            model.seq_len(),
            Some(n_requests),
            ServerConfig::default(),
            Arc::clone(&counters),
        )?;
        router.shutdown();
        let _ = watchdog.join();
        let (service, outcome) = compute.join().expect("compute join");
        outcome?;
        println!("{}", counters.snapshot());
        (service, served)
    } else {
        // workload generator thread: replay shuffled dataset samples
        let producer = {
            let router = Arc::clone(&router);
            let mut rng = Rng::new(settings.seed);
            let stream: Vec<usize> =
                SampleStream::shuffled(&dataset, &mut rng).take(n_requests).collect();
            let tokens: Vec<_> = stream.iter().map(|&i| dataset.sample_tokens(i)).collect();
            std::thread::spawn(move || {
                let (tx, rx) = std::sync::mpsc::channel();
                for t in tokens {
                    if signals::interrupted() || router.submit(t, tx.clone()).is_none() {
                        break;
                    }
                }
                drop(tx);
                // drain replies (the service loop also records metrics)
                let mut got = 0usize;
                while rx.recv().is_ok() {
                    got += 1;
                }
                router.shutdown();
                got
            })
        };

        let batcher_config = config.batcher.clone();
        service.run(Arc::clone(&router), batcher_config)?;
        let got = producer.join().expect("producer join");
        (service, got)
    };
    if service.write_snapshot() {
        log::info!("final snapshot written ({} batches served)", service.batches_done());
    }

    println!("— serving report ({dataset_name}, policy {:?}, network {:?}) —",
             args.get_or("policy", "splitee"), args.get_or("network", "3g"));
    println!("{}", service.metrics.report());
    let menu = settings.codec_menu()?;
    let l = model.n_layers();
    // an arm is a (split, codec) pair once the menu has more than one entry
    // and the policy expanded its arm space (SplitEE-S keeps L arms):
    // 0-based arm a = (codec * L) + (split - 1)
    let arm_name = |a0: usize, n_arms: usize| {
        if menu.len() > 1 && n_arms == l * menu.len() {
            format!("L{} {}", a0 % l + 1, menu.specs[a0 / l].name())
        } else {
            format!("L{}", a0 + 1)
        }
    };
    if let Some((best, arms)) = service.bandit_summary() {
        println!("bandit: best empirical action = {}", arm_name(best - 1, arms.len()));
        for (i, (n, q)) in arms.iter().enumerate() {
            println!("  {:<12} pulls {:<6} Q {:+.4}", arm_name(i, arms.len()), n, q);
        }
    }
    if let Some(per_ctx) = service.contextual_summary() {
        for (ctx, arms) in per_ctx.iter().enumerate() {
            let modal = arms.iter().enumerate().max_by_key(|(_, (n, _))| *n).map(|(i, _)| i);
            let pulls: u64 = arms.iter().map(|(n, _)| n).sum();
            if let Some(modal) = modal.filter(|_| pulls > 0) {
                println!(
                    "context {ctx}: {pulls} pulls, modal action = {}",
                    arm_name(modal, arms.len())
                );
            }
        }
    }
    if signals::interrupted() {
        println!("interrupted: drained {got}/{n_requests} requests before shutdown");
    } else if tcp_mode {
        // in-flight pipelined requests may finish just past the budget
        anyhow::ensure!(got >= n_requests, "expected >= {n_requests} replies, got {got}");
    } else {
        anyhow::ensure!(got == n_requests, "expected {n_requests} replies, got {got}");
    }
    Ok(())
}

/// `splitee loadgen` — open-loop fleet load generation against the TCP
/// front end.  With `--addr` it drives an already-running server; without,
/// it self-hosts a synthetic-model serving plane on loopback (no artifacts
/// needed), drives it, and checks the shed-accounting identity.
fn loadgen(args: &Args, settings: &Settings) -> Result<()> {
    let mut cfg = fleet::LoadgenConfig {
        seed: settings.seed,
        ..Default::default()
    };
    cfg.requests = args.get_num("requests", cfg.requests).map_err(anyhow::Error::msg)?;
    cfg.clients = args.get_num("clients", cfg.clients).map_err(anyhow::Error::msg)?;
    cfg.conns = args.get_num("conns", cfg.conns).map_err(anyhow::Error::msg)?;
    cfg.stall_conns = args.get_num("stalled", cfg.stall_conns).map_err(anyhow::Error::msg)?;
    cfg.mean_rps = args.get_num("rps", cfg.mean_rps).map_err(anyhow::Error::msg)?;
    if cfg.clients == 0 || cfg.conns == 0 || cfg.requests == 0 {
        bail!("--clients, --conns and --requests must be positive");
    }

    if let Some(addr) = args.get("addr") {
        // external target: the server's seq_len/vocab must be supplied when
        // they differ from the synthetic defaults
        cfg.seq_len = args.get_num("seq-len", cfg.seq_len).map_err(anyhow::Error::msg)?;
        cfg.vocab = args.get_num("vocab", cfg.vocab).map_err(anyhow::Error::msg)?;
        let report = fleet::run(addr, &cfg)?;
        println!("{report}");
        return Ok(());
    }

    // self-hosted: a synthetic reference-backend serving plane on loopback
    const SYN_LAYERS: usize = 6;
    const SYN_SEQ: usize = 8;
    const SYN_VOCAB: usize = 64;
    cfg.seq_len = SYN_SEQ;
    cfg.vocab = SYN_VOCAB;
    let weights = ModelWeights::synthetic(SYN_LAYERS, 16, 32, SYN_VOCAB, SYN_SEQ, 2, 0xFEED);
    let model = Arc::new(MultiExitModel::from_weights(
        "synthetic",
        "reference",
        weights,
        2,
        SYN_SEQ,
        vec![1, 8],
        &Backend::reference(),
    )?);
    let cm = CostModel::paper(settings.offload_cost, settings.mu, model.n_layers());
    let link = LinkSim::new(
        NetworkProfile::by_name(args.get_or("network", "wifi"))
            .context("--network must be wifi|5g|4g|3g")?,
        settings.seed ^ 0x11,
    );
    let config = ServiceConfig {
        policy: PolicyKind::SplitEe,
        alpha: 0.7,
        beta: settings.beta,
        batcher: BatcherConfig {
            batch_sizes: model.batch_sizes().to_vec(),
            max_wait: std::time::Duration::from_millis(2),
        },
        coalesce: Default::default(),
        speculate: SpeculateMode::from_name(&settings.speculate)?,
        link: LinkScenario::from_name(&settings.link)?,
        replicas: settings.replica_config()?,
        codecs: settings.codec_menu()?,
    };
    let router = Router::new(RouterConfig::default());
    let mut service = Service::new(Arc::clone(&model), cm, link, &config);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("bind loopback")?;
    let addr = listener.local_addr().context("local addr")?.to_string();
    let counters = ServerCounters::new();
    let compute = {
        let router = Arc::clone(&router);
        let batcher_config = config.batcher.clone();
        std::thread::spawn(move || service.run(router, batcher_config))
    };
    let front = {
        let router = Arc::clone(&router);
        let counters = Arc::clone(&counters);
        let seq_len = model.seq_len();
        std::thread::spawn(move || {
            serve_tcp(listener, router, seq_len, None, ServerConfig::default(), counters)
        })
    };

    println!(
        "loadgen: {} requests, {} clients over {} conns (+{} stalled), target {:.0} rps -> {addr}",
        cfg.requests, cfg.clients, cfg.conns, cfg.stall_conns, cfg.mean_rps
    );
    let report = fleet::run(&addr, &cfg);
    router.shutdown();
    let served = front.join().expect("front-end join")?;
    compute.join().expect("compute join")?;
    let report = report?;
    let stat = counters.snapshot();
    println!("{report}");
    println!("{stat}");
    anyhow::ensure!(
        stat.balanced(),
        "shed accounting violated: submitted {} != served {} + shed {} + rejected {}",
        stat.submitted,
        stat.served,
        stat.shed,
        stat.rejected
    );
    anyhow::ensure!(
        report.balanced(),
        "client-side accounting violated: sent {} != served {} + shed {} + rejected {}",
        report.sent,
        report.served,
        report.shed,
        report.rejected
    );
    log::info!("front end answered {served} requests");
    Ok(())
}

/// `splitee codec-drift` — per-codec top-1 agreement, confidence drift and
/// uplink byte ratio against the uncompressed continuation, on the synthetic
/// reference model (no artifacts needed).  Folds the `codec_*` keys into
/// `BENCH_serving.json` so the regression gate sees them next to the serving
/// bench's.
fn codec_drift_cmd(args: &Args, settings: &Settings) -> Result<()> {
    let samples = args.get_num("samples", 512usize).map_err(anyhow::Error::msg)?;
    if samples == 0 {
        bail!("--samples must be positive");
    }
    // default to the full menu here: measuring only the identity codec says
    // nothing, and the serving default stays identity regardless
    let menu = match args.get("codecs") {
        Some(_) => settings.codec_menu()?,
        None => splitee::codec::CodecMenu::from_list("identity,f16,i8,topk:64")?,
    };
    let out = codec_drift::run(
        &menu,
        samples,
        settings.seed,
        std::path::Path::new("BENCH_serving.json"),
    )?;
    println!("{out}");
    Ok(())
}

//! Figures 3-6: accuracy and cost of SplitEE / SplitEE-S as the offloading
//! cost sweeps `o ∈ {1..5} lambda` across every evaluation dataset.

use anyhow::Result;

use crate::config::{Manifest, Settings};
use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::experiments::report::{write_results, Table};
use crate::experiments::runner::run_policy_repeated;
use crate::policy::{Policy, SplitEePolicy, SplitEeSPolicy};
use crate::runtime::Backend;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub dataset: String,
    pub algo: String,
    pub offload: f64,
    pub acc_pct: f64,
    pub cost_1e4: f64,
    pub offload_rate: f64,
}

/// The offload costs of the paper's sweep.
pub const OFFLOAD_SWEEP: [f64; 5] = [1.0, 2.0, 3.0, 4.0, 5.0];

/// Run the sweep for one dataset and one algorithm.
pub fn sweep_dataset(
    manifest: &Manifest,
    cache: &ConfidenceCache,
    dataset: &str,
    algo: &str,
    settings: &Settings,
) -> Result<Vec<SweepPoint>> {
    let task = manifest.source_task(dataset)?;
    let l = manifest.model.n_layers;
    let mut out = Vec::new();
    for &o in &OFFLOAD_SWEEP {
        let cm = CostModel::paper(o, settings.mu, l);
        let mut policy: Box<dyn Policy> = match algo {
            "splitee" => Box::new(SplitEePolicy::new(l, task.alpha, settings.beta)),
            "splitee-s" => Box::new(SplitEeSPolicy::new(l, task.alpha, settings.beta)),
            other => anyhow::bail!("unknown algo {other:?}"),
        };
        let rr = run_policy_repeated(cache, policy.as_mut(), &cm, settings.reps, settings.seed);
        out.push(SweepPoint {
            dataset: dataset.to_string(),
            algo: algo.to_string(),
            offload: o,
            acc_pct: rr.mean.acc_pct(),
            cost_1e4: rr.mean.cost_1e4(),
            offload_rate: rr.mean.offload_rate,
        });
    }
    Ok(out)
}

/// Run figures 3-6 (both algorithms, all datasets) and render.
pub fn run(manifest: &Manifest, backend: &Backend, settings: &Settings) -> Result<String> {
    let mut rendered = String::new();
    let mut csv = Table::new(&["figure", "algo", "dataset", "o", "acc_pct", "cost_1e4", "offload_rate"]);
    for (algo, acc_fig, cost_fig) in
        [("splitee", "fig3", "fig4"), ("splitee-s", "fig5", "fig6")]
    {
        for dataset in manifest.eval_datasets() {
            log::info!("figures: {algo} on {dataset}");
            let cache =
                ConfidenceCache::load_or_build(manifest, backend, &dataset, "elasticbert")?;
            let points = sweep_dataset(manifest, &cache, &dataset, algo, settings)?;
            let mut t = Table::new(&["o (lambda)", "accuracy %", "cost (1e4 lambda)", "offload %"]);
            for p in &points {
                t.row(vec![
                    format!("{:.0}", p.offload),
                    format!("{:.2}", p.acc_pct),
                    format!("{:.2}", p.cost_1e4),
                    format!("{:.1}", 100.0 * p.offload_rate),
                ]);
                csv.row(vec![
                    format!("{acc_fig}/{cost_fig}"),
                    p.algo.clone(),
                    p.dataset.clone(),
                    format!("{:.0}", p.offload),
                    format!("{:.3}", p.acc_pct),
                    format!("{:.3}", p.cost_1e4),
                    format!("{:.4}", p.offload_rate),
                ]);
            }
            rendered.push_str(&format!(
                "\n[{acc_fig} acc / {cost_fig} cost] {algo} on {dataset}\n{}",
                t.render()
            ));
        }
    }
    write_results(&settings.results_dir, "figures_3_6.txt", &rendered)?;
    write_results(&settings.results_dir, "figures_3_6.csv", &csv.to_csv())?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::run_policy_repeated;

    /// Figure 4/6 shape: total cost rises with the offload price.
    #[test]
    fn cost_monotone_in_offload_price_on_synthetic() {
        let cache = ConfidenceCache::synthetic(4000, 12, 21);
        let mut costs = Vec::new();
        for &o in &OFFLOAD_SWEEP {
            let cm = CostModel::paper(o, 0.1, 12);
            let mut p = SplitEePolicy::new(12, 0.85, 1.0);
            let rr = run_policy_repeated(&cache, &mut p, &cm, 3, 7);
            costs.push(rr.mean.total_cost);
        }
        // allow small bandit noise but require an overall upward trend
        assert!(
            costs[4] > costs[0],
            "cost should rise with o: {costs:?}"
        );
    }

    /// Higher o pushes the bandit to offload less (deeper splits / more
    /// exits) — the mechanism behind the paper's accuracy-vs-o discussion.
    #[test]
    fn offload_rate_falls_with_offload_price_on_synthetic() {
        let cache = ConfidenceCache::synthetic(4000, 12, 23);
        let mut rates = Vec::new();
        for &o in &[1.0, 5.0] {
            let cm = CostModel::paper(o, 0.1, 12);
            let mut p = SplitEePolicy::new(12, 0.85, 1.0);
            let rr = run_policy_repeated(&cache, &mut p, &cm, 3, 11);
            rates.push(rr.mean.offload_rate);
        }
        assert!(
            rates[1] <= rates[0] + 0.02,
            "offload rate should not grow with o: {rates:?}"
        );
    }
}

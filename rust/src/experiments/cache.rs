//! Confidence cache: per-sample, per-exit observations for one (dataset,
//! training-style) pair, produced by the real PJRT model and persisted to
//! `artifacts/cache/{dataset}_{style}.bin`.
//!
//! Binary format SPLC (little-endian):
//!
//! ```text
//!     u32 magic = 0x53504C43      u32 version = 1
//!     u32 n_layers, u32 n_samples, u32 n_classes
//!     f32 conf[L * N]     (layer-major)
//!     f32 ent[L * N]
//!     i32 pred[L * N]
//!     i32 labels[N]
//!     i32 difficulty[N]
//! ```

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};

use crate::config::Manifest;
use crate::data::Dataset;
use crate::model::MultiExitModel;
use crate::runtime::Backend;

pub const CACHE_MAGIC: u32 = 0x53504C43;
pub const FORMAT_VERSION: u32 = 1;

/// Cached per-exit observations for a whole dataset.
#[derive(Debug, Clone)]
pub struct ConfidenceCache {
    pub dataset: String,
    pub style: String,
    pub n_layers: usize,
    pub n_samples: usize,
    pub n_classes: usize,
    /// [L * N] layer-major confidence
    conf: Vec<f32>,
    /// [L * N] layer-major entropy
    ent: Vec<f32>,
    /// [L * N] layer-major predictions
    pred: Vec<i32>,
    pub labels: Vec<i32>,
    pub difficulty: Vec<i32>,
}

impl ConfidenceCache {
    /// Confidence profile of sample `i` across layers: returns a freshly
    /// assembled [L] vector (layer-major storage favours the builders; the
    /// per-sample view is what policies consume).
    pub fn sample_conf(&self, i: usize) -> Vec<f32> {
        (0..self.n_layers).map(|l| self.conf[l * self.n_samples + i]).collect()
    }

    pub fn sample_ent(&self, i: usize) -> Vec<f32> {
        (0..self.n_layers).map(|l| self.ent[l * self.n_samples + i]).collect()
    }

    #[inline]
    pub fn conf_at(&self, layer0: usize, i: usize) -> f32 {
        self.conf[layer0 * self.n_samples + i]
    }

    #[inline]
    pub fn ent_at(&self, layer0: usize, i: usize) -> f32 {
        self.ent[layer0 * self.n_samples + i]
    }

    #[inline]
    pub fn pred_at(&self, layer0: usize, i: usize) -> i32 {
        self.pred[layer0 * self.n_samples + i]
    }

    /// Accuracy of always exiting at `layer` (1-based).
    pub fn accuracy_at(&self, layer_1based: usize) -> f64 {
        let l = layer_1based - 1;
        let hits = (0..self.n_samples)
            .filter(|&i| self.pred_at(l, i) == self.labels[i])
            .count();
        hits as f64 / self.n_samples.max(1) as f64
    }

    /// Build by running the full model over the dataset (one-time cost).
    pub fn build(
        model: &MultiExitModel,
        dataset: &Dataset,
        style: &str,
        log_progress: bool,
    ) -> Result<ConfidenceCache> {
        let l = model.n_layers();
        let n = dataset.len();
        let t0 = Instant::now();
        let mut conf = vec![0f32; l * n];
        let mut ent = vec![0f32; l * n];
        let mut pred = vec![0i32; l * n];
        let chunk = 1024usize;
        let mut done = 0usize;
        while done < n {
            let hi = (done + chunk).min(n);
            let tokens = dataset.range_tokens(done, hi);
            let outs = model.forward_all_exits(&tokens)?;
            for (layer, out) in outs.iter().enumerate() {
                let base = layer * n + done;
                conf[base..base + (hi - done)].copy_from_slice(&out.conf);
                ent[base..base + (hi - done)].copy_from_slice(&out.ent);
                for (j, &p) in out.pred.iter().enumerate() {
                    pred[base + j] = p as i32;
                }
            }
            done = hi;
            if log_progress {
                log::info!(
                    "cache {}/{}: {done}/{n} samples ({:.0}/s)",
                    dataset.name,
                    style,
                    done as f64 / t0.elapsed().as_secs_f64()
                );
            }
        }
        Ok(ConfidenceCache {
            dataset: dataset.name.clone(),
            style: style.to_string(),
            n_layers: l,
            n_samples: n,
            n_classes: dataset.n_classes,
            conf,
            ent,
            pred,
            labels: dataset.labels.clone(),
            difficulty: dataset.difficulty.clone(),
        })
    }

    /// On-disk location for a (dataset, style) cache.
    pub fn path(manifest: &Manifest, dataset: &str, style: &str) -> PathBuf {
        manifest.root.join("cache").join(format!("{dataset}_{style}.bin"))
    }

    /// Load from disk, or build via the model and persist.
    pub fn load_or_build(
        manifest: &Manifest,
        backend: &Backend,
        dataset_name: &str,
        style: &str,
    ) -> Result<ConfidenceCache> {
        let path = Self::path(manifest, dataset_name, style);
        if path.exists() {
            let c = Self::read(&path, dataset_name, style)?;
            log::debug!("cache hit {path:?} ({} samples)", c.n_samples);
            return Ok(c);
        }
        let info = manifest.dataset(dataset_name)?;
        let source = info
            .source
            .clone()
            .unwrap_or_else(|| dataset_name.to_string());
        log::info!("building cache for {dataset_name} [{style}] (model {source})");
        let model = MultiExitModel::load(manifest, backend, &source, style)?;
        let data = Dataset::load(&manifest.root.join(&info.file), dataset_name)?;
        let cache = Self::build(&model, &data, style, true)?;
        std::fs::create_dir_all(path.parent().unwrap())?;
        cache.write(&path)?;
        Ok(cache)
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        let mut f = Vec::with_capacity(16 + self.conf.len() * 12);
        f.write_u32::<LittleEndian>(CACHE_MAGIC)?;
        f.write_u32::<LittleEndian>(FORMAT_VERSION)?;
        f.write_u32::<LittleEndian>(self.n_layers as u32)?;
        f.write_u32::<LittleEndian>(self.n_samples as u32)?;
        f.write_u32::<LittleEndian>(self.n_classes as u32)?;
        for &v in &self.conf {
            f.write_f32::<LittleEndian>(v)?;
        }
        for &v in &self.ent {
            f.write_f32::<LittleEndian>(v)?;
        }
        for &v in &self.pred {
            f.write_i32::<LittleEndian>(v)?;
        }
        for &v in &self.labels {
            f.write_i32::<LittleEndian>(v)?;
        }
        for &v in &self.difficulty {
            f.write_i32::<LittleEndian>(v)?;
        }
        std::fs::write(path, f).with_context(|| format!("writing cache {path:?}"))
    }

    pub fn read(path: &Path, dataset: &str, style: &str) -> Result<ConfidenceCache> {
        let bytes = std::fs::read(path).with_context(|| format!("reading cache {path:?}"))?;
        let mut r = std::io::Cursor::new(&bytes);
        let magic = r.read_u32::<LittleEndian>()?;
        if magic != CACHE_MAGIC {
            bail!("{path:?}: bad cache magic {magic:#x}");
        }
        let version = r.read_u32::<LittleEndian>()?;
        if version != FORMAT_VERSION {
            bail!("{path:?}: unsupported cache version {version}");
        }
        let l = r.read_u32::<LittleEndian>()? as usize;
        let n = r.read_u32::<LittleEndian>()? as usize;
        let c = r.read_u32::<LittleEndian>()? as usize;
        let mut conf = vec![0f32; l * n];
        r.read_f32_into::<LittleEndian>(&mut conf).context("conf")?;
        let mut ent = vec![0f32; l * n];
        r.read_f32_into::<LittleEndian>(&mut ent).context("ent")?;
        let mut pred = vec![0i32; l * n];
        r.read_i32_into::<LittleEndian>(&mut pred).context("pred")?;
        let mut labels = vec![0i32; n];
        r.read_i32_into::<LittleEndian>(&mut labels).context("labels")?;
        let mut difficulty = vec![0i32; n];
        r.read_i32_into::<LittleEndian>(&mut difficulty)
            .context("difficulty")?;
        if (r.position() as usize) != bytes.len() {
            bail!("{path:?}: trailing bytes");
        }
        Ok(ConfidenceCache {
            dataset: dataset.to_string(),
            style: style.to_string(),
            n_layers: l,
            n_samples: n,
            n_classes: c,
            conf,
            ent,
            pred,
            labels,
            difficulty,
        })
    }

    /// Construct directly from dense arrays (tests, synthetic harnesses).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        dataset: &str,
        style: &str,
        n_layers: usize,
        n_samples: usize,
        n_classes: usize,
        conf: Vec<f32>,
        ent: Vec<f32>,
        pred: Vec<i32>,
        labels: Vec<i32>,
        difficulty: Vec<i32>,
    ) -> Result<ConfidenceCache> {
        if conf.len() != n_layers * n_samples
            || ent.len() != n_layers * n_samples
            || pred.len() != n_layers * n_samples
            || labels.len() != n_samples
            || difficulty.len() != n_samples
        {
            bail!("cache arrays inconsistent with {n_layers} x {n_samples}");
        }
        Ok(ConfidenceCache {
            dataset: dataset.to_string(),
            style: style.to_string(),
            n_layers,
            n_samples,
            n_classes,
            conf,
            ent,
            pred,
            labels,
            difficulty,
        })
    }

    /// Synthetic cache from the rust-side profile generator (tests/benches
    /// without artifacts).
    pub fn synthetic(n: usize, n_layers: usize, seed: u64) -> ConfidenceCache {
        use crate::data::synth::{SynthMix, SynthProfile};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let p = SynthProfile::generate(n, n_layers, SynthMix::default(), &mut rng);
        let mut conf = vec![0f32; n_layers * n];
        let mut ent = vec![0f32; n_layers * n];
        let mut pred = vec![0i32; n_layers * n];
        let labels = vec![1i32; n];
        for i in 0..n {
            for l in 0..n_layers {
                let c = p.conf[i][l];
                conf[l * n + i] = c;
                // entropy consistent with a two-class max-prob c
                let c64 = c as f64;
                let h = -(c64 * c64.ln() + (1.0 - c64).max(1e-9) * (1.0 - c64).max(1e-9).ln());
                ent[l * n + i] = h as f32;
                pred[l * n + i] = if p.correct[i][l] { 1 } else { 0 };
            }
        }
        ConfidenceCache {
            dataset: "synthetic".into(),
            style: "synthetic".into(),
            n_layers,
            n_samples: n,
            n_classes: 2,
            conf,
            ent,
            pred,
            labels,
            difficulty: p.kind.iter().map(|&k| k as i32).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_on_disk() {
        let c = ConfidenceCache::synthetic(50, 12, 3);
        let path = std::env::temp_dir().join(format!(
            "splitee_cache_{}_{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        c.write(&path).unwrap();
        let back = ConfidenceCache::read(&path, "synthetic", "synthetic").unwrap();
        assert_eq!(back.n_samples, 50);
        assert_eq!(back.n_layers, 12);
        for i in (0..50).step_by(7) {
            assert_eq!(back.sample_conf(i), c.sample_conf(i));
            assert_eq!(back.sample_ent(i), c.sample_ent(i));
        }
        assert_eq!(back.labels, c.labels);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn read_rejects_corruption() {
        let c = ConfidenceCache::synthetic(10, 4, 1);
        let path = std::env::temp_dir().join(format!(
            "splitee_cache_bad_{}_{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        c.write(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 5);
        std::fs::write(&path, &bytes).unwrap();
        assert!(ConfidenceCache::read(&path, "x", "y").is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn accuracy_at_grows_with_depth_on_synthetic() {
        let c = ConfidenceCache::synthetic(3000, 12, 7);
        assert!(c.accuracy_at(12) > c.accuracy_at(1) + 0.1);
    }

    #[test]
    fn from_parts_validates_lengths() {
        assert!(ConfidenceCache::from_parts(
            "d", "s", 2, 3, 2,
            vec![0.5; 6], vec![0.1; 6], vec![0; 6], vec![0; 3], vec![0; 3]
        )
        .is_ok());
        assert!(ConfidenceCache::from_parts(
            "d", "s", 2, 3, 2,
            vec![0.5; 5], vec![0.1; 6], vec![0; 6], vec![0; 3], vec![0; 3]
        )
        .is_err());
    }

    #[test]
    fn layer_major_accessors_agree() {
        let c = ConfidenceCache::synthetic(20, 6, 11);
        for i in 0..20 {
            let sc = c.sample_conf(i);
            for l in 0..6 {
                assert_eq!(sc[l], c.conf_at(l, i));
            }
        }
    }
}

//! Section 5.4 — "Need for offloading": the fraction of samples the no-offload
//! cascades process beyond the 6th layer, where on-device compute already
//! exceeds the worst-case offloading cost (the paper measures DeeBERT 51%,
//! ElasticBERT 35%).

use anyhow::Result;

use crate::config::{Manifest, Settings};
use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::experiments::report::{write_results, Table};
use crate::experiments::runner::run_policy_repeated;
use crate::policy::{DeeBertPolicy, ElasticBertPolicy, SplitEePolicy};
use crate::runtime::Backend;

pub fn run(manifest: &Manifest, backend: &Backend, settings: &Settings) -> Result<String> {
    let l = manifest.model.n_layers;
    let cm = CostModel::paper(settings.offload_cost, settings.mu, l);
    let mut table = Table::new(&[
        "dataset",
        "DeeBERT >6 %",
        "ElasticBERT >6 %",
        "SplitEE >6 %",
        "SplitEE offload %",
    ]);
    let mut sums = [0.0f64; 3];
    let mut count = 0.0;
    for dataset in manifest.eval_datasets() {
        let task = manifest.source_task(&dataset)?;
        let eb = ConfidenceCache::load_or_build(manifest, backend, &dataset, "elasticbert")?;
        let db = ConfidenceCache::load_or_build(manifest, backend, &dataset, "deebert")?;

        let mut deebert = DeeBertPolicy::new(task.tau);
        let r_db = run_policy_repeated(&db, &mut deebert, &cm, 1, settings.seed).mean;
        let mut elastic = ElasticBertPolicy::new(task.alpha);
        let r_eb = run_policy_repeated(&eb, &mut elastic, &cm, 1, settings.seed).mean;
        let mut splitee = SplitEePolicy::new(l, task.alpha, settings.beta);
        let r_se =
            run_policy_repeated(&eb, &mut splitee, &cm, settings.reps, settings.seed).mean;

        sums[0] += r_db.beyond_6_rate;
        sums[1] += r_eb.beyond_6_rate;
        sums[2] += r_se.beyond_6_rate;
        count += 1.0;
        table.row(vec![
            dataset.clone(),
            format!("{:.1}", 100.0 * r_db.beyond_6_rate),
            format!("{:.1}", 100.0 * r_eb.beyond_6_rate),
            format!("{:.1}", 100.0 * r_se.beyond_6_rate),
            format!("{:.1}", 100.0 * r_se.offload_rate),
        ]);
    }
    table.row(vec![
        "MEAN".into(),
        format!("{:.1}", 100.0 * sums[0] / count),
        format!("{:.1}", 100.0 * sums[1] / count),
        format!("{:.1}", 100.0 * sums[2] / count),
        String::new(),
    ]);
    let rendered = format!(
        "Section 5.4 — samples processed on-device beyond layer 6\n\
         (paper: DeeBERT 51%, ElasticBERT 35%; processing past layer 6 costs\n\
         more than the worst-case offload o = 5 lambda)\n{}",
        table.render()
    );
    write_results(&settings.results_dir, "sec5_4_beyond6.txt", &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::run_policy_repeated;

    /// SplitEE's offload option keeps deep on-device processing far below the
    /// no-offload cascades on hard-heavy profiles.
    #[test]
    fn splitee_processes_less_deep_than_cascades() {
        let cache = ConfidenceCache::synthetic(4000, 12, 51);
        let cm = CostModel::paper(5.0, 0.1, 12);
        let mut deebert = DeeBertPolicy::new(0.25);
        let db = run_policy_repeated(&cache, &mut deebert, &cm, 1, 0).mean;
        let mut splitee = SplitEePolicy::new(12, 0.85, 1.0);
        let se = run_policy_repeated(&cache, &mut splitee, &cm, 3, 0).mean;
        assert!(
            se.beyond_6_rate < db.beyond_6_rate,
            "SplitEE {:.2} !< DeeBERT {:.2}",
            se.beyond_6_rate,
            db.beyond_6_rate
        );
    }
}

//! Report formatting: paper-style tables + CSV/JSON dumps under `results/`.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// A simple column-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    /// CSV serialisation.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a results file, creating the directory.
pub fn write_results(dir: &Path, name: &str, contents: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    log::info!("wrote {path:?}");
    Ok(())
}

/// Write a JSON results file.
pub fn write_json(dir: &Path, name: &str, value: &Json) -> Result<()> {
    write_results(dir, name, &value.to_string())
}

/// Format a signed delta in accuracy points the way the paper's Table 2 does.
pub fn fmt_acc_delta(delta_points: f64) -> String {
    if delta_points >= 0.0 {
        format!("+{delta_points:.1}")
    } else {
        format!("{delta_points:.1}")
    }
}

/// Format a relative cost delta (negative = cheaper), paper-style.
pub fn fmt_cost_delta(frac: f64) -> String {
    format!("{:+.1}%", 100.0 * frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(vec!["Final-exit".into(), "83.4".into()]);
        t.row(vec!["SplitEE".into(), "-1.3".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("Final-exit"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["name", "v"]);
        t.row(vec!["a,b".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn delta_formatting() {
        assert_eq!(fmt_acc_delta(-1.34), "-1.3");
        assert_eq!(fmt_acc_delta(0.05), "+0.1");
        assert_eq!(fmt_cost_delta(-0.666), "-66.6%");
        assert_eq!(fmt_cost_delta(0.031), "+3.1%");
    }
}

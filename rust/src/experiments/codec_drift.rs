//! Codec drift harness: how much does each split-boundary payload codec
//! move the *decisions*, and what does it save on the wire?
//!
//! For every codec in a [`CodecMenu`] this runs the same samples to a split
//! layer, ships the hidden state through `encode -> decode`, finishes the
//! forward pass from the reconstruction, and compares the final exit against
//! the uncompressed continuation:
//!
//! * **agreement** — fraction of samples whose top-1 prediction is unchanged
//!   (the quantity the acceptance gate pins: lossy uplink compression is
//!   only admissible while the decisions survive it);
//! * **conf drift** — mean |Δ confidence| at the final exit;
//! * **uplink ratio** — raw bytes / encoded bytes over the same rows,
//!   *excluding* the fixed per-transfer frame header (the header is charged
//!   by the link simulator either way, so the ratio isolates the codec);
//! * **max |err|** — worst reconstruction error of any hidden value.
//!
//! Exposed three ways: `splitee codec-drift` (synthetic model, prints the
//! table and folds `codec_*` keys into `BENCH_serving.json` next to the
//! serving bench's), the serving bench's codec leg (same [`measure`] call on
//! its own workload), and the CI smoke leg that asserts f16 agreement.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::codec::{CodecMenu, PayloadCodec};
use crate::model::{ModelWeights, MultiExitModel};
use crate::runtime::Backend;
use crate::tensor::{TensorF32, TensorI32};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::report::Table;

/// Per-codec drift measurement against the uncompressed continuation.
#[derive(Debug, Clone)]
pub struct CodecDrift {
    /// menu name of the codec (`identity`, `i8`, `topk:64`, ...)
    pub codec: String,
    /// fraction of samples with an unchanged top-1 prediction in [0, 1]
    pub agreement: f64,
    /// mean |Δ confidence| at the final exit
    pub conf_drift: f64,
    /// worst |reconstructed - original| over every hidden value
    pub max_abs_err: f64,
    /// raw uplink bytes over the measured rows (4 B per f32)
    pub raw_bytes: u64,
    /// encoded uplink bytes over the same rows (pre-dedup codec output,
    /// excluding the fixed frame header)
    pub enc_bytes: u64,
}

impl CodecDrift {
    /// raw / encoded uplink bytes (1.0 when nothing was encoded).
    pub fn uplink_ratio(&self) -> f64 {
        if self.enc_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.enc_bytes as f64
        }
    }

    /// The codec's menu name flattened into a metric-key fragment
    /// (`topk:64` -> `topk_64`), so every emitted key matches the CI
    /// gate's `codec_` prefix grammar.
    pub fn key_name(&self) -> String {
        self.codec.replace([':', ','], "_")
    }
}

/// Measure every codec in `menu` on `tokens` through `model`, offloading at
/// `split` (0-based).  The uncompressed continuation is computed once per
/// sample and shared across codecs, so the per-codec cost is one
/// encode/decode plus one cloud-share forward.
///
/// Stateful codecs (dedup) keep their cache across samples — exactly like a
/// serving run, so repeated activations count as hits here too.
pub fn measure(
    model: &MultiExitModel,
    tokens: &[TensorI32],
    split: usize,
    menu: &CodecMenu,
) -> Result<Vec<CodecDrift>> {
    let (codecs, _dedup) = menu.build();
    let mut out: Vec<CodecDrift> = codecs
        .iter()
        .map(|c| CodecDrift {
            codec: c.name(),
            agreement: 0.0,
            conf_drift: 0.0,
            max_abs_err: 0.0,
            raw_bytes: 0,
            enc_bytes: 0,
        })
        .collect();
    let mut agree = vec![0u64; codecs.len()];

    for t in tokens {
        let (h, _exit) = model.run_split(t, split)?;
        let baseline = model.forward_rest_exit(&h, split)?;
        let row = h.data();
        for (ci, codec) in codecs.iter().enumerate() {
            let enc = codec.encode(row);
            let dec = codec
                .decode(&enc.bytes, row.len())
                .with_context(|| format!("decoding a {} drift payload", codec.name()))?;
            let mut worst = 0f32;
            for (a, b) in row.iter().zip(dec.iter()) {
                worst = worst.max((a - b).abs());
            }
            let ht = TensorF32::new(h.shape().to_vec(), dec).map_err(|e| anyhow::anyhow!(e))?;
            let got = model.forward_rest_exit(&ht, split)?;
            let d = &mut out[ci];
            if got.pred[0] == baseline.pred[0] {
                agree[ci] += 1;
            }
            d.conf_drift += (got.conf[0] - baseline.conf[0]).abs() as f64;
            d.max_abs_err = d.max_abs_err.max(worst as f64);
            d.raw_bytes += 4 * row.len() as u64;
            d.enc_bytes += enc.encoded_len as u64;
        }
    }

    let n = tokens.len().max(1) as f64;
    for (ci, d) in out.iter_mut().enumerate() {
        d.agreement = agree[ci] as f64 / n;
        d.conf_drift /= n;
    }
    Ok(out)
}

/// The drift measurements as flat `codec_*` metric keys
/// (`codec_i8_uplink_ratio`, `codec_f16_agreement`, ...), the shape both
/// `BENCH_serving.json` and the CI smoke leg consume.
pub fn metric_keys(drifts: &[CodecDrift]) -> BTreeMap<String, f64> {
    let mut keys = BTreeMap::new();
    for d in drifts {
        let k = d.key_name();
        keys.insert(format!("codec_{k}_agreement"), d.agreement);
        keys.insert(format!("codec_{k}_uplink_ratio"), d.uplink_ratio());
        keys.insert(format!("codec_{k}_conf_drift"), d.conf_drift);
        keys.insert(format!("codec_{k}_max_abs_err"), d.max_abs_err);
    }
    keys
}

/// Render the measurements as the `splitee codec-drift` report table.
pub fn render(drifts: &[CodecDrift], samples: usize, split: usize) -> String {
    let mut t = Table::new(&[
        "codec", "agreement", "conf drift", "max |err|", "raw B", "enc B", "ratio",
    ]);
    for d in drifts {
        t.row(vec![
            d.codec.clone(),
            format!("{:.4}", d.agreement),
            format!("{:.5}", d.conf_drift),
            format!("{:.3e}", d.max_abs_err),
            format!("{}", d.raw_bytes),
            format!("{}", d.enc_bytes),
            format!("{:.2}x", d.uplink_ratio()),
        ]);
    }
    format!(
        "codec drift over {samples} samples, offloading at layer {} (1-based)\n{}",
        split + 1,
        t.render()
    )
}

/// The synthetic reference-backend workload the `codec-drift` subcommand and
/// the CI smoke leg measure on: the serving bench's no-artifact model (12
/// layers, d=32, T=16 — 512-value uplink rows) and a seeded token stream.
pub fn synthetic_workload(
    samples: usize,
    seed: u64,
) -> Result<(Arc<MultiExitModel>, Vec<TensorI32>)> {
    let (layers, d, ff, vocab, seq, classes) = (12, 32, 64, 256, 16, 2);
    let weights = ModelWeights::synthetic(layers, d, ff, vocab, seq, classes, 0xBE7C);
    let model = Arc::new(MultiExitModel::from_weights(
        "synthetic",
        "reference",
        weights,
        4,
        seq,
        vec![1, 8],
        &Backend::reference(),
    )?);
    let mut rng = Rng::new(seed);
    let tokens = (0..samples)
        .map(|_| {
            TensorI32::new(
                vec![1, seq],
                (0..seq).map(|_| rng.below(vocab as u64) as i32).collect(),
            )
            .map_err(|e| anyhow::anyhow!(e))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((model, tokens))
}

/// `splitee codec-drift` — measure `menu` on the synthetic workload, fold
/// the `codec_*` keys into `bench_path` (creating it if absent, preserving
/// every non-`codec_` key an earlier bench run wrote), and return the
/// printable report.
pub fn run(
    menu: &CodecMenu,
    samples: usize,
    seed: u64,
    bench_path: &std::path::Path,
) -> Result<String> {
    let (model, tokens) = synthetic_workload(samples, seed)?;
    let split = model.n_layers() / 2 - 1;
    let drifts = measure(&model, &tokens, split, menu)?;

    let mut obj: BTreeMap<String, Json> = match std::fs::read_to_string(bench_path) {
        Ok(text) => json::parse(&text)
            .with_context(|| format!("parsing {}", bench_path.display()))?
            .as_obj()
            .with_context(|| format!("{} is not a JSON object", bench_path.display()))?
            .clone(),
        Err(_) => BTreeMap::new(),
    };
    for (k, v) in metric_keys(&drifts) {
        obj.insert(k, Json::Num(v));
    }
    json::write_atomic(bench_path, &Json::Obj(obj).to_string())
        .with_context(|| format!("writing {}", bench_path.display()))?;

    Ok(format!(
        "{}\ncodec_* keys folded into {}",
        render(&drifts, samples, split),
        bench_path.display()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_workload() -> (Arc<MultiExitModel>, Vec<TensorI32>) {
        let weights = ModelWeights::synthetic(4, 8, 16, 32, 4, 2, 0xD01F);
        let model = Arc::new(
            MultiExitModel::from_weights(
                "synthetic",
                "reference",
                weights,
                2,
                4,
                vec![1, 4],
                &Backend::reference(),
            )
            .expect("tiny model"),
        );
        let mut rng = Rng::new(0xA11CE);
        let tokens = (0..12)
            .map(|_| {
                TensorI32::new(vec![1, 4], (0..4).map(|_| rng.below(32) as i32).collect())
                    .expect("tokens")
            })
            .collect();
        (model, tokens)
    }

    #[test]
    fn identity_never_drifts_and_lossy_codecs_stay_bounded() {
        let (model, tokens) = tiny_workload();
        let menu = CodecMenu::from_list("identity,f16,i8").expect("menu");
        let drifts = measure(&model, &tokens, 1, &menu).expect("measure");
        assert_eq!(drifts.len(), 3);
        let id = &drifts[0];
        assert_eq!(id.codec, "identity");
        assert_eq!(id.agreement, 1.0, "identity must be bit-transparent");
        assert_eq!(id.conf_drift, 0.0);
        assert_eq!(id.max_abs_err, 0.0);
        assert_eq!(id.raw_bytes, id.enc_bytes);
        // f16 is near-lossless (~1e-3 relative error): decisions survive.
        // i8 quantizes harder, so on this tiny random model only a loose
        // floor is pinned here — the CI smoke leg holds the tight one on
        // the full synthetic reference workload.
        assert!(drifts[1].agreement >= 0.9, "f16 agreement {}", drifts[1].agreement);
        assert!(drifts[2].agreement >= 0.5, "i8 agreement {}", drifts[2].agreement);
        for lossy in &drifts[1..] {
            assert!(lossy.enc_bytes < lossy.raw_bytes, "{} must compress", lossy.codec);
        }
        // 4 B -> 1 B payload plus one 4-byte scale per row
        assert!(drifts[2].uplink_ratio() > 3.0, "i8 ratio {}", drifts[2].uplink_ratio());
    }

    #[test]
    fn metric_keys_flatten_names_for_the_gate() {
        let drifts = vec![CodecDrift {
            codec: "topk:64".to_string(),
            agreement: 0.5,
            conf_drift: 0.1,
            max_abs_err: 0.2,
            raw_bytes: 100,
            enc_bytes: 50,
        }];
        let keys = metric_keys(&drifts);
        assert_eq!(keys.get("codec_topk_64_agreement"), Some(&0.5));
        assert_eq!(keys.get("codec_topk_64_uplink_ratio"), Some(&2.0));
        assert!(keys.contains_key("codec_topk_64_conf_drift"));
        assert!(keys.contains_key("codec_topk_64_max_abs_err"));
    }
}

//! Table 2 — the paper's main result: accuracy + cost for every baseline
//! across every evaluation dataset at `o = 5 lambda`, `mu = 0.1`, 20 reps.

use anyhow::Result;

use crate::config::{Manifest, Settings};
use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::experiments::report::{fmt_acc_delta, fmt_cost_delta, write_results, Table};
use crate::experiments::runner::{run_policy_repeated, EvalResult};
use crate::policy::{DeeBertPolicy, ElasticBertPolicy, FinalExitPolicy,
                    RandomExitPolicy, SplitEePolicy, SplitEeSPolicy};
use crate::runtime::Backend;

/// Rows for one dataset: the six models of the paper's Table 2.
#[derive(Debug, Clone)]
pub struct DatasetRows {
    pub dataset: String,
    pub results: Vec<EvalResult>,
}

/// Run the Table 2 experiment for one dataset.
pub fn run_dataset(
    manifest: &Manifest,
    backend: &Backend,
    dataset: &str,
    settings: &Settings,
) -> Result<DatasetRows> {
    let task = manifest.source_task(dataset)?;
    let cm = CostModel::paper(settings.offload_cost, settings.mu, manifest.model.n_layers);
    let eb_cache = ConfidenceCache::load_or_build(manifest, backend, dataset, "elasticbert")?;
    let db_cache = ConfidenceCache::load_or_build(manifest, backend, dataset, "deebert")?;
    let l = manifest.model.n_layers;
    let reps = settings.reps;
    let seed = settings.seed;

    let mut results = Vec::new();

    // Order matches the paper's table.
    let mut final_exit = FinalExitPolicy;
    results.push(run_policy_repeated(&eb_cache, &mut final_exit, &cm, 1, seed).mean);

    let mut random = RandomExitPolicy::new(task.alpha, seed ^ 0xA5);
    results.push(run_policy_repeated(&eb_cache, &mut random, &cm, reps, seed).mean);

    // DeeBERT runs on its own two-stage-trained weights (its own cache).
    let mut deebert = DeeBertPolicy::new(task.tau);
    results.push(run_policy_repeated(&db_cache, &mut deebert, &cm, 1, seed).mean);

    let mut elastic = ElasticBertPolicy::new(task.alpha);
    results.push(run_policy_repeated(&eb_cache, &mut elastic, &cm, 1, seed).mean);

    let mut splitee = SplitEePolicy::new(l, task.alpha, settings.beta);
    results.push(run_policy_repeated(&eb_cache, &mut splitee, &cm, reps, seed).mean);

    let mut splitee_s = SplitEeSPolicy::new(l, task.alpha, settings.beta);
    results.push(run_policy_repeated(&eb_cache, &mut splitee_s, &cm, reps, seed).mean);

    Ok(DatasetRows { dataset: dataset.to_string(), results })
}

/// Run the whole table and render it paper-style (deltas vs Final-exit).
pub fn run(manifest: &Manifest, backend: &Backend, settings: &Settings) -> Result<String> {
    let datasets = manifest.eval_datasets();
    let mut per_dataset = Vec::new();
    for d in &datasets {
        log::info!("table2: dataset {d}");
        per_dataset.push(run_dataset(manifest, backend, d, settings)?);
    }

    // paper-style: first row absolute, then deltas
    let mut header: Vec<String> = vec!["Model/Data".into()];
    for rows in &per_dataset {
        let paper = &manifest.dataset(&rows.dataset)?.paper_name;
        header.push(format!("{paper} Acc", paper = paper));
        header.push(format!("{paper} Cost"));
    }
    let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    let n_models = per_dataset[0].results.len();
    for m in 0..n_models {
        let name = per_dataset[0].results[m].policy.clone();
        let mut cells = vec![name];
        for rows in &per_dataset {
            let base = &rows.results[0]; // Final-exit
            let r = &rows.results[m];
            if m == 0 {
                cells.push(format!("{:.1}", r.acc_pct()));
                cells.push(format!("{:.1}", r.cost_1e4()));
            } else {
                cells.push(fmt_acc_delta(r.acc_pct() - base.acc_pct()));
                cells.push(fmt_cost_delta(r.total_cost / base.total_cost - 1.0));
            }
        }
        table.row(cells);
    }

    let rendered = format!(
        "Table 2 (o = {} lambda, mu = {}, reps = {}; cost in 1e4 lambda units)\n{}",
        settings.offload_cost,
        settings.mu,
        settings.reps,
        table.render()
    );
    write_results(&settings.results_dir, "table2.txt", &rendered)?;
    write_results(&settings.results_dir, "table2.csv", &table.to_csv())?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::run_policy_repeated;

    /// Table-2 shape on the synthetic cache: SplitEE cuts cost >40% with
    /// accuracy within 4 points of Final-exit; DeeBERT (no offload) pays
    /// more than SplitEE on hard-heavy profiles.
    #[test]
    fn headline_shape_on_synthetic_cache() {
        let cache = ConfidenceCache::synthetic(6000, 12, 9);
        let cm = CostModel::paper(5.0, 0.1, 12);
        let mut fe = FinalExitPolicy;
        let fe_r = run_policy_repeated(&cache, &mut fe, &cm, 1, 1).mean;
        let mut se = SplitEePolicy::new(12, 0.92, 1.0);
        let se_r = run_policy_repeated(&cache, &mut se, &cm, 3, 1).mean;
        let mut ss = SplitEeSPolicy::new(12, 0.92, 1.0);
        let ss_r = run_policy_repeated(&cache, &mut ss, &cm, 3, 1).mean;

        assert!(se_r.total_cost < 0.65 * fe_r.total_cost);
        assert!(se_r.acc_pct() > fe_r.acc_pct() - 4.0);
        assert!(ss_r.total_cost < 0.75 * fe_r.total_cost);
        // SplitEE (single-head inference) tends to be cheaper than
        // SplitEE-S (per-layer heads) — paper section 5.5 — though the two
        // can flip when -S converges to a shallower split (SciTail row in
        // Table 2), so allow a modest margin.
        assert!(se_r.total_cost < ss_r.total_cost * 1.15,
                "SplitEE {:.0} vs SplitEE-S {:.0}", se_r.total_cost, ss_r.total_cost);
    }
}

//! Experiment harness: regenerates every table and figure of the paper.
//!
//! | paper artifact | module | CLI |
//! |----------------|--------|-----|
//! | Table 1 (dataset sizes)        | [`report`]  | `splitee table1` |
//! | Table 2 (acc + cost, o = 5)    | [`table2`]  | `splitee table2` |
//! | Figures 3-6 (sweep o)          | [`figures`] | `splitee figures` |
//! | Figure 7 (cumulative regret)   | [`regret`]  | `splitee regret` |
//! | section 5.4 (beyond-layer-6)   | [`sec5_4`]  | `splitee sec54` |
//! | ablations (beta, mu, alpha...) | [`ablations`] | `splitee ablations` |
//! | codec drift (beyond the paper)  | [`codec_drift`] | `splitee codec-drift` |
//!
//! The harness evaluates policies on **confidence caches**: one full forward
//! pass per dataset through the PJRT `prefix_full` graph records every
//! exit's (confidence, entropy, prediction) per sample; bandit repetitions
//! then replay shuffles of the cache.  This mirrors the paper's released
//! evaluation (precomputed logits) and makes 20-repetition sweeps tractable.

pub mod ablations;
pub mod cache;
pub mod codec_drift;
pub mod figures;
pub mod regret;
pub mod report;
pub mod runner;
pub mod sec5_4;
pub mod table2;

pub use cache::ConfidenceCache;
pub use runner::{EvalResult, run_policy_once};

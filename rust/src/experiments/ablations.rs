//! Ablations over the design choices DESIGN.md section 5 calls out:
//! exploration beta, cost weight mu, exit threshold alpha (including the
//! adaptive-threshold extension), and the side-observation depth.

use anyhow::Result;

use crate::config::{Manifest, Settings};
use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::experiments::report::{write_results, Table};
use crate::experiments::runner::run_policy_repeated;
use crate::policy::{AdaptiveThresholdPolicy, PerSamplePolicy, Policy, SplitEePolicy,
                    SplitEeSPolicy};
use crate::runtime::Backend;

pub const BETA_SWEEP: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];
pub const MU_SWEEP: [f64; 5] = [0.02, 0.05, 0.1, 0.2, 0.5];
pub const ALPHA_SWEEP: [f64; 5] = [0.7, 0.8, 0.85, 0.9, 0.95];

fn eval(
    cache: &ConfidenceCache,
    policy: &mut dyn Policy,
    cm: &CostModel,
    reps: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let r = run_policy_repeated(cache, policy, cm, reps, seed);
    (r.mean.acc_pct(), r.mean.cost_1e4(), r.mean.offload_rate)
}

/// Which ablation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Which {
    Beta,
    Mu,
    Alpha,
    Side,
    All,
}

impl Which {
    pub fn parse(s: &str) -> Option<Which> {
        match s {
            "beta" => Some(Which::Beta),
            "mu" => Some(Which::Mu),
            "alpha" => Some(Which::Alpha),
            "side" => Some(Which::Side),
            "all" => Some(Which::All),
            _ => None,
        }
    }
}

pub fn run(
    manifest: &Manifest,
    backend: &Backend,
    settings: &Settings,
    which: Which,
    dataset: &str,
) -> Result<String> {
    let l = manifest.model.n_layers;
    let task = manifest.source_task(dataset)?;
    let cache = ConfidenceCache::load_or_build(manifest, backend, dataset, "elasticbert")?;
    let mut rendered = format!("Ablations on {dataset} (reps = {})\n", settings.reps);

    if matches!(which, Which::Beta | Which::All) {
        let mut t = Table::new(&["beta", "acc %", "cost 1e4", "offload"]);
        for &beta in &BETA_SWEEP {
            let cm = CostModel::paper(settings.offload_cost, settings.mu, l);
            let mut p = SplitEePolicy::new(l, task.alpha, beta);
            let (a, c, o) = eval(&cache, &mut p, &cm, settings.reps, settings.seed);
            t.row(vec![format!("{beta}"), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        }
        rendered.push_str(&format!("\n[beta sweep — SplitEE exploration]\n{}", t.render()));
    }

    if matches!(which, Which::Mu | Which::All) {
        let mut t = Table::new(&["mu", "acc %", "cost 1e4", "offload"]);
        for &mu in &MU_SWEEP {
            let cm = CostModel::paper(settings.offload_cost, mu, l);
            let mut p = SplitEePolicy::new(l, task.alpha, settings.beta);
            let (a, c, o) = eval(&cache, &mut p, &cm, settings.reps, settings.seed);
            t.row(vec![format!("{mu}"), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        }
        rendered.push_str(&format!("\n[mu sweep — cost weight in eq. 1]\n{}", t.render()));
    }

    if matches!(which, Which::Alpha | Which::All) {
        let cm = CostModel::paper(settings.offload_cost, settings.mu, l);
        let mut t = Table::new(&["alpha", "acc %", "cost 1e4", "offload"]);
        for &alpha in &ALPHA_SWEEP {
            let mut p = SplitEePolicy::new(l, alpha, settings.beta);
            let (a, c, o) = eval(&cache, &mut p, &cm, settings.reps, settings.seed);
            t.row(vec![format!("{alpha}"), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        }
        // future-work extensions for comparison
        let mut at = AdaptiveThresholdPolicy::new(l, settings.beta);
        let (a, c, o) = eval(&cache, &mut at, &cm, settings.reps, settings.seed);
        t.row(vec!["adaptive".into(), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        let mut ps = PerSamplePolicy::new(l, task.alpha, settings.beta);
        let (a, c, o) = eval(&cache, &mut ps, &cm, settings.reps, settings.seed);
        t.row(vec!["per-sample".into(), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        rendered.push_str(&format!(
            "\n[alpha sweep — exit threshold; calibrated value {:.2};\n adaptive = learned-threshold extension, per-sample = per-sample split extension]\n{}",
            task.alpha,
            t.render()
        ));
    }

    if matches!(which, Which::Side | Which::All) {
        let cm = CostModel::paper(settings.offload_cost, settings.mu, l);
        let mut t = Table::new(&["variant", "acc %", "cost 1e4", "offload"]);
        let mut se = SplitEePolicy::new(l, task.alpha, settings.beta);
        let (a, c, o) = eval(&cache, &mut se, &cm, settings.reps, settings.seed);
        t.row(vec!["SplitEE (no side info)".into(), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        let mut ss = SplitEeSPolicy::new(l, task.alpha, settings.beta);
        let (a, c, o) = eval(&cache, &mut ss, &cm, settings.reps, settings.seed);
        t.row(vec!["SplitEE-S (full side info)".into(), format!("{a:.2}"), format!("{c:.2}"), format!("{o:.3}")]);
        rendered.push_str(&format!(
            "\n[side observations — inference cost vs convergence (sec. 5.5)]\n{}",
            t.render()
        ));
    }

    write_results(&settings.results_dir, &format!("ablations_{dataset}.txt"), &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn which_parse() {
        assert_eq!(Which::parse("beta"), Some(Which::Beta));
        assert_eq!(Which::parse("all"), Some(Which::All));
        assert!(Which::parse("nope").is_none());
    }

    /// Higher mu weights cost more -> cheaper operating points.
    #[test]
    fn mu_controls_cost_on_synthetic() {
        let cache = ConfidenceCache::synthetic(4000, 12, 61);
        let mut lo = SplitEePolicy::new(12, 0.85, 1.0);
        let mut hi = SplitEePolicy::new(12, 0.85, 1.0);
        let cm_lo = CostModel::paper(5.0, 0.02, 12);
        let cm_hi = CostModel::paper(5.0, 0.5, 12);
        let (_, c_lo, _) = eval(&cache, &mut lo, &cm_lo, 3, 1);
        let (_, c_hi, _) = eval(&cache, &mut hi, &cm_hi, 3, 1);
        assert!(c_hi <= c_lo + 0.05, "mu=0.5 cost {c_hi} vs mu=0.02 cost {c_lo}");
    }
}

//! Shared evaluation loop: replay a confidence cache through a policy in a
//! shuffled online order and aggregate the paper's metrics.

use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::policy::{Policy, SampleView};
use crate::util::rng::Rng;

/// Metrics of one policy pass over one dataset.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub policy: String,
    pub dataset: String,
    /// fraction correct
    pub accuracy: f64,
    /// total cost in lambda units
    pub total_cost: f64,
    /// mean per-sample cost in lambda units
    pub mean_cost: f64,
    pub offload_rate: f64,
    /// samples answered per (1-based) layer
    pub per_layer: Vec<u64>,
    /// fraction of samples *processed* beyond layer 6 (paper section 5.4)
    pub beyond_6_rate: f64,
    pub n: usize,
}

impl EvalResult {
    /// Accuracy in percent.
    pub fn acc_pct(&self) -> f64 {
        100.0 * self.accuracy
    }

    /// Total cost in the paper's reporting unit (10^4 lambda).
    pub fn cost_1e4(&self) -> f64 {
        self.total_cost / 1e4
    }
}

/// Run one policy over one shuffled pass of the cache.
pub fn run_policy_once(
    cache: &ConfidenceCache,
    policy: &mut dyn Policy,
    cm: &CostModel,
    rng: &mut Rng,
) -> EvalResult {
    let order = rng.permutation(cache.n_samples);
    run_policy_order(cache, policy, cm, &order)
}

/// Run one policy over an explicit sample order.
pub fn run_policy_order(
    cache: &ConfidenceCache,
    policy: &mut dyn Policy,
    cm: &CostModel,
    order: &[usize],
) -> EvalResult {
    let l = cache.n_layers;
    let mut hits = 0usize;
    let mut total_cost = 0.0;
    let mut offloads = 0usize;
    let mut per_layer = vec![0u64; l + 1];
    let mut beyond6 = 0usize;
    let mut conf_buf = vec![0f32; l];
    let mut ent_buf = vec![0f32; l];
    for &i in order {
        for layer in 0..l {
            conf_buf[layer] = cache.conf_at(layer, i);
            ent_buf[layer] = cache.ent_at(layer, i);
        }
        let view = SampleView { conf: &conf_buf, ent: &ent_buf };
        let o = policy.decide(&view, cm);
        let pred = cache.pred_at(o.infer_layer - 1, i);
        if pred == cache.labels[i] {
            hits += 1;
        }
        total_cost += o.cost;
        if o.offloaded {
            offloads += 1;
        }
        per_layer[o.infer_layer] += 1;
        // "processed beyond layer 6": on-device compute deeper than 6
        // (offloaded samples stop on-device at the split; cascades/final
        // exit process locally to the exit layer).
        let local_depth = if o.offloaded { o.split } else { o.infer_layer };
        if local_depth > 6 {
            beyond6 += 1;
        }
    }
    let n = order.len();
    EvalResult {
        policy: policy.name(),
        dataset: cache.dataset.clone(),
        accuracy: hits as f64 / n.max(1) as f64,
        total_cost,
        mean_cost: total_cost / n.max(1) as f64,
        offload_rate: offloads as f64 / n.max(1) as f64,
        per_layer,
        beyond_6_rate: beyond6 as f64 / n.max(1) as f64,
        n,
    }
}

/// Run `reps` shuffled repetitions (each from a freshly-reset policy) and
/// average the headline metrics; also returns the per-rep values for CIs.
pub struct RepeatedResult {
    pub mean: EvalResult,
    pub acc_by_rep: Vec<f64>,
    pub cost_by_rep: Vec<f64>,
}

/// Repetitions are independent — each gets its own forked RNG and its own
/// policy clone (then `reset()`, the same state the serial reset-per-rep
/// loop started each rep from) — so they fan out across the shared
/// [`crate::util::threadpool`] pool.  RNG forks are drawn from the root in
/// rep order and results are aggregated in rep order, so every number is
/// bit-identical to the serial loop.
pub fn run_policy_repeated(
    cache: &ConfidenceCache,
    policy: &mut dyn Policy,
    cm: &CostModel,
    reps: usize,
    seed: u64,
) -> RepeatedResult {
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..reps).map(|rep| root.fork(rep as u64)).collect();
    let results: Vec<EvalResult> = if reps <= 1 {
        rngs.into_iter()
            .map(|mut rng| {
                policy.reset();
                run_policy_once(cache, policy, cm, &mut rng)
            })
            .collect()
    } else {
        let jobs: Vec<(Box<dyn Policy>, Rng)> =
            rngs.into_iter().map(|rng| (policy.clone_box(), rng)).collect();
        crate::util::threadpool::global().scope_map(jobs, |(mut p, mut rng)| {
            p.reset();
            run_policy_once(cache, p.as_mut(), cm, &mut rng)
        })
    };
    let mut acc_by_rep = Vec::with_capacity(reps);
    let mut cost_by_rep = Vec::with_capacity(reps);
    let mut agg: Option<EvalResult> = None;
    for r in results {
        acc_by_rep.push(r.accuracy);
        cost_by_rep.push(r.total_cost);
        agg = Some(match agg.take() {
            None => r,
            Some(mut a) => {
                a.accuracy += r.accuracy;
                a.total_cost += r.total_cost;
                a.mean_cost += r.mean_cost;
                a.offload_rate += r.offload_rate;
                a.beyond_6_rate += r.beyond_6_rate;
                for (x, y) in a.per_layer.iter_mut().zip(&r.per_layer) {
                    *x += *y;
                }
                a
            }
        });
    }
    let mut mean = agg.expect("reps >= 1");
    let k = reps as f64;
    mean.accuracy /= k;
    mean.total_cost /= k;
    mean.mean_cost /= k;
    mean.offload_rate /= k;
    mean.beyond_6_rate /= k;
    RepeatedResult { mean, acc_by_rep, cost_by_rep }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{FinalExitPolicy, SplitEePolicy};

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    #[test]
    fn final_exit_cost_is_constant_l() {
        let cache = ConfidenceCache::synthetic(500, 12, 1);
        let mut p = FinalExitPolicy;
        let mut rng = Rng::new(0);
        let r = run_policy_once(&cache, &mut p, &cm(), &mut rng);
        assert!((r.mean_cost - 12.0).abs() < 1e-9);
        assert_eq!(r.offload_rate, 0.0);
        assert_eq!(r.per_layer[12], 500);
        assert!((r.beyond_6_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn splitee_beats_final_exit_cost_with_small_acc_drop() {
        // The paper's headline on a synthetic-but-faithful profile.
        let cache = ConfidenceCache::synthetic(6000, 12, 2);
        // (see comment below on alpha)
        let c = cm();
        // alpha = 0.92 keeps the synthetic trap samples (confidently wrong
        // around 0.85 at shallow exits) below the exit threshold, matching
        // the calibrated thresholds the real datasets get.
        let mut fe = FinalExitPolicy;
        let mut se = SplitEePolicy::new(12, 0.92, 1.0);
        let mut rng = Rng::new(1);
        let r_fe = run_policy_once(&cache, &mut fe, &c, &mut rng);
        let mut rng = Rng::new(1);
        let r_se = run_policy_once(&cache, &mut se, &c, &mut rng);
        assert!(
            r_se.total_cost < 0.65 * r_fe.total_cost,
            "cost reduction too small: {} vs {}",
            r_se.total_cost,
            r_fe.total_cost
        );
        assert!(
            r_se.accuracy > r_fe.accuracy - 0.035,
            "accuracy dropped too much: {} vs {}",
            r_se.accuracy,
            r_fe.accuracy
        );
    }

    #[test]
    fn repeated_runs_average_and_reset() {
        let cache = ConfidenceCache::synthetic(1000, 12, 3);
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        let rr = run_policy_repeated(&cache, &mut p, &cm(), 5, 42);
        assert_eq!(rr.acc_by_rep.len(), 5);
        let m = rr.acc_by_rep.iter().sum::<f64>() / 5.0;
        assert!((rr.mean.accuracy - m).abs() < 1e-12);
        // reshuffles differ -> bandit trajectories differ a little
        let distinct: std::collections::BTreeSet<u64> =
            rr.cost_by_rep.iter().map(|c| (*c * 100.0) as u64).collect();
        assert!(distinct.len() > 1);
    }

    #[test]
    fn repeated_parallel_matches_serial_reference() {
        // run_policy_repeated fans reps out over the thread pool; every
        // per-rep number must stay bit-identical to the serial
        // reset-per-rep loop it replaced
        let cache = ConfidenceCache::synthetic(800, 12, 9);
        let c = cm();
        let mut serial_acc = Vec::new();
        let mut serial_cost = Vec::new();
        let mut root = Rng::new(77);
        let mut p_ref = SplitEePolicy::new(12, 0.85, 1.0);
        for rep in 0..4u64 {
            p_ref.reset();
            let mut rng = root.fork(rep);
            let r = run_policy_once(&cache, &mut p_ref, &c, &mut rng);
            serial_acc.push(r.accuracy);
            serial_cost.push(r.total_cost);
        }
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        let rr = run_policy_repeated(&cache, &mut p, &c, 4, 77);
        assert_eq!(rr.acc_by_rep, serial_acc);
        assert_eq!(rr.cost_by_rep, serial_cost);
    }

    #[test]
    fn order_determinism() {
        let cache = ConfidenceCache::synthetic(300, 12, 5);
        let order: Vec<usize> = (0..300).collect();
        let c = cm();
        let mut p1 = SplitEePolicy::new(12, 0.85, 1.0);
        let mut p2 = SplitEePolicy::new(12, 0.85, 1.0);
        let a = run_policy_order(&cache, &mut p1, &c, &order);
        let b = run_policy_order(&cache, &mut p2, &c, &order);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.total_cost, b.total_cost);
        assert_eq!(a.per_layer, b.per_layer);
    }
}

//! Figure 7 — expected cumulative regret (paper eq. 3) with 95% CIs over 20
//! reshuffled repetitions, for SplitEE, SplitEE-S and the Random baseline.
//!
//! Regret per round is `r(i*) − r(i_t)` where `i*` is the oracle split layer
//! maximising the dataset's expected reward (computed from the cache, eq. 2)
//! and both rewards are evaluated on the *same* sample the policy saw.

use anyhow::Result;

use crate::config::{Manifest, Settings};
use crate::cost::CostModel;
use crate::experiments::cache::ConfidenceCache;
use crate::experiments::report::{write_results, Table};
use crate::policy::{oracle_split, reward_for_split, Policy, RandomExitPolicy,
                    SplitEePolicy, SplitEeSPolicy};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::stats;

/// Mean cumulative-regret curve with a CI band.
#[derive(Debug, Clone)]
pub struct RegretCurve {
    pub algo: String,
    pub dataset: String,
    /// (round, mean cumulative regret, 95% CI half-width)
    pub points: Vec<(usize, f64, f64)>,
    pub oracle_arm: usize,
    pub final_mean: f64,
}

/// Run regret curves with an explicit exit threshold alpha.
#[allow(clippy::too_many_arguments)]
pub fn regret_curves_with_alpha(
    cache: &ConfidenceCache,
    algo_name: &str,
    make_policy: &mut dyn FnMut() -> Box<dyn Policy>,
    cm: &CostModel,
    alpha: f64,
    reps: usize,
    seed: u64,
    resolution: usize,
) -> RegretCurve {
    let probe = make_policy();
    let side = probe.uses_side_info();
    drop(probe);
    let profiles: Vec<(Vec<f32>, Vec<f32>)> = (0..cache.n_samples)
        .map(|i| (cache.sample_conf(i), cache.sample_ent(i)))
        .collect();
    let (oracle_arm, _means) = oracle_split(&profiles, cm, alpha, side);

    let n = cache.n_samples;
    let mut root = Rng::new(seed);
    // per-rep downsampled curves
    let mut curves: Vec<Vec<f64>> = Vec::with_capacity(reps);
    let step = (n as f64 / resolution as f64).max(1.0);
    let mut rounds: Vec<usize> = Vec::new();
    {
        let mut x = step;
        while (x as usize) <= n {
            rounds.push(x as usize);
            x += step;
        }
        if rounds.last() != Some(&n) {
            rounds.push(n);
        }
    }
    for rep in 0..reps {
        let mut rng = root.fork(rep as u64);
        let order = rng.permutation(n);
        let mut policy = make_policy();
        let mut cum = 0.0;
        let mut curve = Vec::with_capacity(rounds.len());
        let mut next_idx = 0usize;
        for (t, &i) in order.iter().enumerate() {
            let (conf, ent) = &profiles[i];
            let view = crate::policy::SampleView { conf, ent };
            let o = policy.decide(&view, cm);
            let r_opt = reward_for_split(&view, cm, oracle_arm, alpha, side);
            cum += r_opt - o.reward;
            if next_idx < rounds.len() && t + 1 == rounds[next_idx] {
                curve.push(cum);
                next_idx += 1;
            }
        }
        curves.push(curve);
    }

    let mut points = Vec::with_capacity(rounds.len());
    for (k, &round) in rounds.iter().enumerate() {
        let vals: Vec<f64> = curves.iter().map(|c| c[k]).collect();
        points.push((round, stats::mean(&vals), stats::ci95_half_width(&vals)));
    }
    let final_mean = points.last().map(|p| p.1).unwrap_or(0.0);
    RegretCurve {
        algo: algo_name.to_string(),
        dataset: cache.dataset.clone(),
        points,
        oracle_arm,
        final_mean,
    }
}

/// Run figure 7 for all datasets.
pub fn run(manifest: &Manifest, backend: &Backend, settings: &Settings) -> Result<String> {
    let mut rendered = String::new();
    let mut csv = Table::new(&["dataset", "algo", "round", "mean_cum_regret", "ci95"]);
    let l = manifest.model.n_layers;
    let cm = CostModel::paper(settings.offload_cost, settings.mu, l);
    for dataset in manifest.eval_datasets() {
        log::info!("regret: dataset {dataset}");
        let task = manifest.source_task(&dataset)?;
        let alpha = task.alpha;
        let beta = settings.beta;
        let cache = ConfidenceCache::load_or_build(manifest, backend, &dataset, "elasticbert")?;

        let seed = settings.seed ^ 0xF16_7;
        let mut algos: Vec<(&str, Box<dyn FnMut() -> Box<dyn Policy>>)> = vec![
            ("SplitEE", Box::new(move || Box::new(SplitEePolicy::new(l, alpha, beta)))),
            ("SplitEE-S", Box::new(move || Box::new(SplitEeSPolicy::new(l, alpha, beta)))),
            ("Random", Box::new(move || Box::new(RandomExitPolicy::new(alpha, 0xDEAD)))),
        ];
        let mut summary = Table::new(&["algo", "oracle i*", "final regret", "ci95", "half-point round"]);
        for (name, make) in algos.iter_mut() {
            let curve = regret_curves_with_alpha(
                &cache, name, make.as_mut(), &cm, alpha, settings.reps, seed, 50,
            );
            // the round by which half the final regret is accumulated — a
            // saturation proxy (paper: SplitEE ~2000, SplitEE-S ~1000)
            let half = curve
                .points
                .iter()
                .find(|(_, m, _)| *m >= curve.final_mean / 2.0)
                .map(|(r, _, _)| *r)
                .unwrap_or(0);
            summary.row(vec![
                curve.algo.clone(),
                format!("{}", curve.oracle_arm),
                format!("{:.1}", curve.final_mean),
                format!("{:.1}", curve.points.last().map(|p| p.2).unwrap_or(0.0)),
                format!("{half}"),
            ]);
            for (round, mean, ci) in &curve.points {
                csv.row(vec![
                    dataset.clone(),
                    curve.algo.clone(),
                    format!("{round}"),
                    format!("{mean:.3}"),
                    format!("{ci:.3}"),
                ]);
            }
        }
        rendered.push_str(&format!("\n[fig7] {dataset}\n{}", summary.render()));
    }
    write_results(&settings.results_dir, "figure7_regret.txt", &rendered)?;
    write_results(&settings.results_dir, "figure7_regret.csv", &csv.to_csv())?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitee_regret_sublinear_and_below_random() {
        let cache = ConfidenceCache::synthetic(6000, 12, 31);
        let cm = CostModel::paper(5.0, 0.1, 12);
        let alpha = 0.85;
        let mut mk_se: Box<dyn FnMut() -> Box<dyn Policy>> =
            Box::new(move || Box::new(SplitEePolicy::new(12, alpha, 1.0)));
        let mut mk_rand: Box<dyn FnMut() -> Box<dyn Policy>> =
            Box::new(move || Box::new(RandomExitPolicy::new(alpha, 1)));
        let se = regret_curves_with_alpha(&cache, "SplitEE", mk_se.as_mut(), &cm, alpha, 3, 5, 30);
        let rd = regret_curves_with_alpha(&cache, "Random", mk_rand.as_mut(), &cm, alpha, 3, 5, 30);
        assert!(se.final_mean < rd.final_mean * 0.6,
                "SplitEE {:.1} vs Random {:.1}", se.final_mean, rd.final_mean);
        // sublinear: second half adds less than the first half
        let half = se.points[se.points.len() / 2].1;
        assert!(se.final_mean - half < half * 1.2,
                "curve not flattening: half {half:.1} final {:.1}", se.final_mean);
    }

    #[test]
    fn splitee_s_saturates_no_later_than_splitee() {
        let cache = ConfidenceCache::synthetic(5000, 12, 37);
        let cm = CostModel::paper(5.0, 0.1, 12);
        let alpha = 0.85;
        let mut mk_se: Box<dyn FnMut() -> Box<dyn Policy>> =
            Box::new(move || Box::new(SplitEePolicy::new(12, alpha, 1.0)));
        let mut mk_ss: Box<dyn FnMut() -> Box<dyn Policy>> =
            Box::new(move || Box::new(SplitEeSPolicy::new(12, alpha, 1.0)));
        let se = regret_curves_with_alpha(&cache, "SplitEE", mk_se.as_mut(), &cm, alpha, 4, 9, 40);
        let ss = regret_curves_with_alpha(&cache, "SplitEE-S", mk_ss.as_mut(), &cm, alpha, 4, 9, 40);
        // figure-7 claim: side observations reduce cumulative regret
        assert!(
            ss.final_mean < se.final_mean,
            "SplitEE-S {:.1} !< SplitEE {:.1}",
            ss.final_mean,
            se.final_mean
        );
    }

    #[test]
    fn oracle_policy_has_zero_regret() {
        use crate::policy::FixedSplitPolicy;
        let cache = ConfidenceCache::synthetic(2000, 12, 41);
        let cm = CostModel::paper(5.0, 0.1, 12);
        let alpha = 0.85;
        let profiles: Vec<(Vec<f32>, Vec<f32>)> = (0..cache.n_samples)
            .map(|i| (cache.sample_conf(i), cache.sample_ent(i)))
            .collect();
        let (oracle, _) = oracle_split(&profiles, &cm, alpha, false);
        let mut mk: Box<dyn FnMut() -> Box<dyn Policy>> =
            Box::new(move || Box::new(FixedSplitPolicy::new(oracle, alpha)));
        let curve = regret_curves_with_alpha(&cache, "Oracle", mk.as_mut(), &cm, alpha, 2, 3, 20);
        assert!(curve.final_mean.abs() < 1e-6, "oracle regret {}", curve.final_mean);
    }
}

//! # SplitEE — Early Exit in Deep Neural Networks with Split Computing
//!
//! Production reproduction of *SplitEE* (Bajpai, Trivedi, Yadav, Hanawal,
//! 2023): a multi-armed-bandit coordinator that learns, online and without
//! labels, **where to split** a multi-exit DNN between an edge device and the
//! cloud, and decides **per sample** whether to exit at the split layer or
//! offload.
//!
//! Three-layer architecture (the full module map, request data flow and
//! test-suite invariants live in the repository's `ARCHITECTURE.md`):
//!
//! * **L1** — Pallas kernels (attention / ffn / exit head), authored in
//!   `python/compile/kernels/`, validated against a pure-jnp oracle;
//! * **L2** — the multi-exit JAX encoder, AOT-lowered to HLO-text artifacts
//!   (`make artifacts`; python never runs on the request path);
//! * **L3** — this crate: the pluggable-backend [`runtime`] (an
//!   always-available pure-Rust `reference` backend, plus the PJRT backend
//!   behind the `pjrt` cargo feature), the multi-exit [`model`] executor,
//!   the [`policy`] zoo (SplitEE, SplitEE-S, the paper's baselines and the
//!   context-aware [`policy::ContextualSplitPolicy`]), the edge/cloud
//!   [`sim`]ulator with its dynamic-link scenario engine
//!   ([`sim::link::LinkScenario`]), the serving [`coordinator`] and the
//!   [`experiments`] harness that regenerates every table and figure of the
//!   paper.
//!
//! The deployment-facing switches every serving entry point takes:
//!
//! * `--backend auto|reference|pjrt` — which [`runtime::Backend`] executes
//!   the model (`reference` runs everywhere, no artifacts needed);
//! * `--speculate on|off|auto` — the edge stage's speculative continuation
//!   past the split ([`coordinator::SpeculateMode`], kill-on-exit,
//!   decision-invariant);
//! * `--link static|markov|markov:<seed>|trace:<path>` — the uplink
//!   scenario ([`sim::link::LinkScenario`]): fixed, Markov-modulated, or a
//!   replayed trace; pair dynamic links with `--policy contextual`;
//! * `--codecs identity,f16,i8,topk:64` — the split-boundary payload
//!   [`codec`] menu: the bandit learns over `(split, codec)` pairs and the
//!   uplink is charged from the encoded bytes (`identity`, the default, is
//!   bit-transparent).
//!
//! Quick start (after `make artifacts && cargo build --release`; see the
//! repository `README.md` for the artifact-free reference-backend path):
//!
//! ```text
//! splitee table2             # paper Table 2
//! splitee figures            # paper Figures 3-6
//! splitee regret             # paper Figure 7
//! splitee serve --dataset imdb --requests 200
//! splitee serve --policy contextual --link markov
//! ```

pub mod bandit;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod experiments;
pub mod model;
pub mod persist;
pub mod policy;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tensor;
pub mod util;

pub use config::{Manifest, Settings};

//! Wire format of the TCP front-end.

use crate::coordinator::router::Response;
use crate::util::json::Json;

/// Parse a comma-separated token line; must have exactly `seq_len` ids.
pub fn parse_tokens(line: &str, seq_len: usize) -> Result<Vec<i32>, String> {
    let parts: Vec<&str> = line.trim().split(',').collect();
    if parts.len() != seq_len {
        return Err(format!("expected {seq_len} tokens, got {}", parts.len()));
    }
    parts
        .iter()
        .map(|p| {
            p.trim()
                .parse::<i32>()
                .map_err(|e| format!("bad token {p:?}: {e}"))
                .and_then(|v| {
                    if v < 0 {
                        Err(format!("negative token {v}"))
                    } else {
                        Ok(v)
                    }
                })
        })
        .collect()
}

/// Serialise a served response as a JSON line.
pub fn format_response(r: &Response) -> String {
    let j = Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("pred", Json::Num(r.prediction as f64)),
        ("conf", Json::Num(r.confidence as f64)),
        ("layer", Json::Num(r.infer_layer as f64)),
        ("offloaded", Json::Bool(r.offloaded)),
        ("latency_ms", Json::Num((r.latency_ms * 1000.0).round() / 1000.0)),
    ]);
    format!("{j}\n")
}

/// Serialise an error as a JSON line.
pub fn format_error(msg: &str) -> String {
    format!("{}\n", Json::obj(vec![("error", Json::Str(msg.to_string()))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parse_valid_line() {
        assert_eq!(parse_tokens("1, 2,3 ,4", 4).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn parse_rejects_wrong_arity() {
        assert!(parse_tokens("1,2,3", 4).is_err());
        assert!(parse_tokens("", 4).is_err());
    }

    #[test]
    fn parse_rejects_garbage_and_negative() {
        assert!(parse_tokens("1,x,3,4", 4).is_err());
        assert!(parse_tokens("1,-2,3,4", 4).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let r = Response {
            id: 7,
            prediction: 1,
            confidence: 0.93,
            infer_layer: 4,
            offloaded: true,
            latency_ms: 2.4567,
        };
        let line = format_response(&r);
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(v.get("layer").unwrap().as_i64().unwrap(), 4);
        assert!(v.get("offloaded").unwrap().as_bool().unwrap());
        assert!((v.get("latency_ms").unwrap().as_f64().unwrap() - 2.457).abs() < 1e-9);
    }

    #[test]
    fn error_line_is_json() {
        let v = json::parse(format_error("boom \"x\"").trim()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "boom \"x\"");
    }
}

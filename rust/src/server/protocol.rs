//! Wire format of the TCP front-end.
//!
//! Line-oriented: each request is one comma-separated token line; each reply
//! is one JSON line carrying the request's **correlation id** — the 0-based
//! line number of the request on its connection — so a pipelining client can
//! match replies to requests without assuming ordering.  An optional first
//! line `hello {"client":"...","link":"wifi|5g|4g|3g"}` registers the
//! connection's identity and link profile for per-cohort metrics.

use crate::coordinator::router::{ClientTag, Response};
use crate::cost::NetworkProfile;
use crate::util::json::{self, Json};

/// Parse a comma-separated token line; must have exactly `seq_len` ids.
pub fn parse_tokens(line: &str, seq_len: usize) -> Result<Vec<i32>, String> {
    let parts: Vec<&str> = line.trim().split(',').collect();
    if parts.len() != seq_len {
        return Err(format!("expected {seq_len} tokens, got {}", parts.len()));
    }
    parts
        .iter()
        .map(|p| {
            p.trim()
                .parse::<i32>()
                .map_err(|e| format!("bad token {p:?}: {e}"))
                .and_then(|v| {
                    if v < 0 {
                        Err(format!("negative token {v}"))
                    } else {
                        Ok(v)
                    }
                })
        })
        .collect()
}

/// Serialise a served response as a JSON line.  `corr` is the connection's
/// correlation id (the request's line number), emitted exactly — routing it
/// through f64 would corrupt ids above 2^53.
pub fn format_response(corr: u64, r: &Response) -> String {
    let j = Json::obj(vec![
        ("id", Json::UInt(corr)),
        ("pred", Json::Num(r.prediction as f64)),
        ("conf", Json::Num(r.confidence as f64)),
        ("layer", Json::Num(r.infer_layer as f64)),
        ("offloaded", Json::Bool(r.offloaded)),
        ("latency_ms", Json::Num((r.latency_ms * 1000.0).round() / 1000.0)),
    ]);
    format!("{j}\n")
}

/// Serialise a connection-level error (no request to correlate) as a JSON
/// line.
pub fn format_error(msg: &str) -> String {
    format!("{}\n", Json::obj(vec![("error", Json::Str(msg.to_string()))]))
}

/// Serialise a per-request error, correlated to the offending line.
pub fn format_error_id(corr: u64, msg: &str) -> String {
    let j = Json::obj(vec![
        ("error", Json::Str(msg.to_string())),
        ("id", Json::UInt(corr)),
    ]);
    format!("{j}\n")
}

/// Serialise a load-shed rejection: the request was *not* queued and the
/// client should retry after the hinted delay.
pub fn format_shed(corr: u64, retry_after_ms: u64) -> String {
    let j = Json::obj(vec![
        ("error", Json::Str("shed".to_string())),
        ("id", Json::UInt(corr)),
        ("retry_after_ms", Json::UInt(retry_after_ms)),
    ]);
    format!("{j}\n")
}

/// Parse an optional `hello {...}` identity line.
///
/// Returns `None` when the line is not a hello at all (it should be treated
/// as a request), `Some(Err)` when it is a malformed hello, and
/// `Some(Ok(tag))` on success.  `client` is required; `link` is optional and
/// must name a known [`NetworkProfile`] (defaults to `"unspecified"`).
pub fn parse_hello(line: &str) -> Option<Result<ClientTag, String>> {
    let rest = line.trim().strip_prefix("hello")?;
    if !rest.starts_with([' ', '\t', '{']) {
        return None; // e.g. a token line that happens to start with "hello"
    }
    Some(parse_hello_body(rest.trim()))
}

fn parse_hello_body(body: &str) -> Result<ClientTag, String> {
    let v = json::parse(body).map_err(|e| format!("bad hello payload: {e}"))?;
    let client = v
        .opt("client")
        .and_then(|c| c.as_str().ok())
        .ok_or_else(|| "hello payload needs a \"client\" string".to_string())?
        .to_string();
    if client.is_empty() {
        return Err("hello client must be non-empty".to_string());
    }
    let link = match v.opt("link") {
        None => "unspecified".to_string(),
        Some(l) => {
            let name = l
                .as_str()
                .map_err(|_| "hello link must be a string".to_string())?;
            let p = NetworkProfile::by_name(name)
                .ok_or_else(|| format!("unknown link profile {name:?} (wifi|5g|4g|3g)"))?;
            p.kind.name().to_string()
        }
    };
    Ok(ClientTag { client, link })
}

/// Serialise the acknowledgement of a hello line.
pub fn format_hello_ack(tag: &ClientTag) -> String {
    let j = Json::obj(vec![
        ("hello", Json::Str(tag.client.clone())),
        ("link", Json::Str(tag.link.clone())),
    ]);
    format!("{j}\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_valid_line() {
        assert_eq!(parse_tokens("1, 2,3 ,4", 4).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn parse_rejects_wrong_arity() {
        assert!(parse_tokens("1,2,3", 4).is_err());
        assert!(parse_tokens("", 4).is_err());
    }

    #[test]
    fn parse_rejects_garbage_and_negative() {
        assert!(parse_tokens("1,x,3,4", 4).is_err());
        assert!(parse_tokens("1,-2,3,4", 4).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let r = Response {
            id: 999, // router id: NOT what goes on the wire
            prediction: 1,
            confidence: 0.93,
            infer_layer: 4,
            offloaded: true,
            latency_ms: 2.4567,
        };
        let line = format_response(7, &r);
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.get("id").unwrap().as_u64().unwrap(), 7);
        assert_eq!(v.get("layer").unwrap().as_i64().unwrap(), 4);
        assert!(v.get("offloaded").unwrap().as_bool().unwrap());
        assert!((v.get("latency_ms").unwrap().as_f64().unwrap() - 2.457).abs() < 1e-9);
    }

    #[test]
    fn correlation_id_is_exact_at_u64_max() {
        // f64 can only represent even numbers near 2^64; the integer path
        // must carry the id bit-exactly
        let r = Response {
            id: 0,
            prediction: 0,
            confidence: 0.5,
            infer_layer: 1,
            offloaded: false,
            latency_ms: 1.0,
        };
        for corr in [u64::MAX, u64::MAX - 1, (1u64 << 53) + 1] {
            let v = json::parse(format_response(corr, &r).trim()).unwrap();
            assert_eq!(v.get("id").unwrap().as_u64().unwrap(), corr);
        }
        let line = format_response(u64::MAX, &r);
        assert!(line.contains("18446744073709551615"), "{line}");
    }

    #[test]
    fn error_line_is_json() {
        let v = json::parse(format_error("boom \"x\"").trim()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "boom \"x\"");
    }

    #[test]
    fn correlated_error_and_shed_lines() {
        let v = json::parse(format_error_id(3, "expected 8 tokens").trim()).unwrap();
        assert_eq!(v.get("id").unwrap().as_u64().unwrap(), 3);
        assert!(v.get("error").unwrap().as_str().unwrap().contains("tokens"));
        let v = json::parse(format_shed(9, 25).trim()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "shed");
        assert_eq!(v.get("id").unwrap().as_u64().unwrap(), 9);
        assert_eq!(v.get("retry_after_ms").unwrap().as_u64().unwrap(), 25);
    }

    #[test]
    fn hello_parses_identity_and_link() {
        let t = parse_hello(r#"hello {"client":"edge-7","link":"5g"}"#)
            .expect("is a hello")
            .expect("valid");
        assert_eq!(t.client, "edge-7");
        assert_eq!(t.link, "5g");
        // link optional
        let t = parse_hello(r#"hello {"client":"x"}"#).unwrap().unwrap();
        assert_eq!(t.link, "unspecified");
        // case-insensitive profile lookup normalizes to the canonical name
        let t = parse_hello(r#"hello {"client":"x","link":"WiFi"}"#).unwrap().unwrap();
        assert_eq!(t.link, "wifi");
    }

    #[test]
    fn hello_rejects_malformed_payloads() {
        assert!(parse_hello("hello {not json}").unwrap().is_err());
        assert!(parse_hello(r#"hello {"link":"wifi"}"#).unwrap().is_err());
        assert!(parse_hello(r#"hello {"client":""}"#).unwrap().is_err());
        assert!(parse_hello(r#"hello {"client":"x","link":"carrier-pigeon"}"#)
            .unwrap()
            .is_err());
        // not hellos at all
        assert!(parse_hello("1,2,3,4").is_none());
        assert!(parse_hello("helloworld").is_none());
    }

    #[test]
    fn hello_ack_roundtrips() {
        let tag = ClientTag { client: "edge-1".into(), link: "wifi".into() };
        let v = json::parse(format_hello_ack(&tag).trim()).unwrap();
        assert_eq!(v.get("hello").unwrap().as_str().unwrap(), "edge-1");
        assert_eq!(v.get("link").unwrap().as_str().unwrap(), "wifi");
        // acks carry no "id": the reply pump must not confuse them with
        // request replies
        assert!(v.opt("id").is_none());
    }
}

//! TCP front-end: a line-oriented protocol over the coordinator.
//!
//! Protocol (one request per line):
//!
//! ```text
//!     -> 12,907,34,...,101\n          (seq_len comma-separated token ids)
//!     <- {"id":0,"pred":1,"conf":0.93,"layer":4,"offloaded":false,
//!         "latency_ms":2.41}\n
//! ```
//!
//! Malformed lines get `{"error": "..."}` and the connection stays open.
//! Used by `splitee serve --listen <addr>` and the `serve_stream` example's
//! `--tcp` mode.

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::tensor::TensorI32;
use protocol::{format_error, format_response, parse_tokens};

/// Serve connections until `max_requests` have been answered (None = forever).
/// The compute loop runs elsewhere (a `Service::run` thread on the same
/// router); this function only handles socket I/O.
pub fn serve_tcp(
    listener: TcpListener,
    router: Arc<Router>,
    seq_len: usize,
    max_requests: Option<usize>,
) -> Result<usize> {
    let mut answered = 0usize;
    listener.set_nonblocking(false).ok();
    loop {
        if let Some(maxr) = max_requests {
            if answered >= maxr {
                return Ok(answered);
            }
        }
        let (stream, peer) = listener.accept().context("accept")?;
        log::info!("connection from {peer}");
        match handle_connection(stream, &router, seq_len, max_requests.map(|m| m - answered)) {
            Ok(n) => answered += n,
            Err(e) => log::warn!("connection error: {e:#}"),
        }
        if !router.is_accepting() {
            return Ok(answered);
        }
    }
}

/// Handle one client connection; returns the number of answered requests.
pub fn handle_connection(
    stream: TcpStream,
    router: &Router,
    seq_len: usize,
    budget: Option<usize>,
) -> Result<usize> {
    let mut writer = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    let mut answered = 0usize;
    for line in reader.lines() {
        let line = line.context("read line")?;
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            break;
        }
        match parse_tokens(&line, seq_len) {
            Ok(tokens) => {
                let (tx, rx) = mpsc::channel();
                let Some(_id) = router.submit(TensorI32::new(vec![1, seq_len], tokens)
                    .map_err(|e| anyhow::anyhow!(e))?, tx) else {
                    writer.write_all(format_error("server shutting down").as_bytes())?;
                    break;
                };
                let resp = rx.recv().context("reply channel closed")?;
                writer.write_all(format_response(&resp).as_bytes())?;
                answered += 1;
                if budget.map(|b| answered >= b).unwrap_or(false) {
                    break;
                }
            }
            Err(msg) => {
                writer.write_all(format_error(&msg).as_bytes())?;
            }
        }
    }
    Ok(answered)
}

//! TCP front-end: a concurrent line-oriented protocol over the coordinator.
//!
//! Protocol (one request per line, replies correlated by line number):
//!
//! ```text
//!     -> hello {"client":"edge-7","link":"4g"}\n      (optional first line)
//!     <- {"hello":"edge-7","link":"4g"}\n
//!     -> 12,907,34,...,101\n          (seq_len comma-separated token ids)
//!     <- {"id":0,"pred":1,"conf":0.93,"layer":4,"offloaded":false,
//!         "latency_ms":2.41}\n
//! ```
//!
//! `id` is the 0-based request line number on the connection (the hello line
//! and blank lines don't count), so a pipelining client can match replies to
//! requests.  Malformed lines get `{"error":"...","id":N}` and the
//! connection stays open; over-capacity requests get an immediate
//! `{"error":"shed","id":N,"retry_after_ms":M}` and are *not* queued.
//!
//! Concurrency model: the accept loop spawns one thread per connection
//! (bounded by [`ServerConfig::max_connections`]); each connection runs a
//! reader that submits every parsed line to the router immediately
//! (pipelining), a reply pump that pairs router replies with correlation
//! ids, and a writer that owns the socket's send side — so a stalled or
//! slow client can never block accepts, other clients, or the compute
//! pipeline.  The accounting identity
//! `submitted == served + shed + rejected` holds over [`ServerCounters`]
//! once the server has quiesced.
//!
//! Used by `splitee serve --listen <addr>`, `splitee loadgen`, and the
//! `serve_stream` example's `--tcp` mode.

pub mod protocol;

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::router::{Admission, ClientTag, Response, Router};
use crate::tensor::TensorI32;
use protocol::{
    format_error, format_error_id, format_hello_ack, format_response, format_shed, parse_hello,
    parse_tokens,
};

/// Front-end limits and timeouts.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// maximum simultaneously served connections; extra accepts get an
    /// error line and an immediate close
    pub max_connections: usize,
    /// per-connection cap on accepted-but-unanswered requests; beyond it
    /// the connection's own traffic is shed before reaching the router
    pub max_pending_per_conn: usize,
    /// retry hint carried by shed replies
    pub shed_retry_after_ms: u64,
    /// socket read timeout: how often a blocked reader wakes to check the
    /// stop flag (teardown latency, not a client-visible deadline)
    pub read_timeout: Duration,
    /// accept-loop poll interval while no connection is pending
    pub accept_poll: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 256,
            max_pending_per_conn: 128,
            shed_retry_after_ms: 25,
            read_timeout: Duration::from_millis(50),
            accept_poll: Duration::from_millis(2),
        }
    }
}

/// Shared request/connection accounting for the front end.  Shared atomics:
/// connection threads record, the accept loop and tests snapshot.  All
/// ordering is `Relaxed` — each counter is independently monotone and the
/// identity is only asserted after the server has quiesced.
#[derive(Debug, Default)]
pub struct ServerCounters {
    /// request lines taken off sockets (excludes hello/quit/blank lines)
    pub submitted: AtomicU64,
    /// requests whose reply arrived from the pipeline — counted at
    /// `recv()`, *not* after the socket write, so a vanished client can't
    /// make the serve budget over-serve
    pub served: AtomicU64,
    /// requests refused by admission control (router window or
    /// per-connection pending cap full); the client got a shed line
    pub shed: AtomicU64,
    /// requests that failed to parse or arrived during shutdown
    pub rejected: AtomicU64,
    /// connections accepted into a serving thread
    pub conn_accepted: AtomicU64,
    /// connections turned away at the connection cap (not part of the
    /// request identity — no request line was ever read)
    pub conn_rejected: AtomicU64,
}

impl ServerCounters {
    pub fn new() -> Arc<ServerCounters> {
        Arc::new(ServerCounters::default())
    }

    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> ServerStat {
        ServerStat {
            submitted: self.submitted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            conn_accepted: self.conn_accepted.load(Ordering::Relaxed),
            conn_rejected: self.conn_rejected.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`ServerCounters`] (field semantics there).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStat {
    pub submitted: u64,
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    pub conn_accepted: u64,
    pub conn_rejected: u64,
}

impl ServerStat {
    /// The accounting identity the server tests pin: once quiesced, every
    /// submitted request resolved exactly once as served, shed, or
    /// rejected.  (Mid-flight, accepted-but-unanswered requests make
    /// `submitted` run ahead.)
    pub fn balanced(&self) -> bool {
        self.submitted == self.served + self.shed + self.rejected
    }

    /// Fraction of submitted requests that were load-shed.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted as f64
        }
    }
}

impl std::fmt::Display for ServerStat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tcp      submitted {}   served {}   shed {} ({:.1}%)   rejected {}   \
             conns {} accepted, {} at-capacity",
            self.submitted,
            self.served,
            self.shed,
            100.0 * self.shed_rate(),
            self.rejected,
            self.conn_accepted,
            self.conn_rejected,
        )
    }
}

/// Serve connections concurrently until `budget` requests have been
/// answered (None = until the router shuts down).  The compute loop runs
/// elsewhere (a `Service::run` thread on the same router); this function
/// only handles socket I/O.  Returns the number of requests answered during
/// this call, after joining every connection thread.
pub fn serve_tcp(
    listener: TcpListener,
    router: Arc<Router>,
    seq_len: usize,
    budget: Option<usize>,
    config: ServerConfig,
    counters: Arc<ServerCounters>,
) -> Result<usize> {
    listener.set_nonblocking(true).context("listener set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let active = Arc::new(AtomicUsize::new(0));
    let base_served = counters.served.load(Ordering::Relaxed);
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();

    let answered =
        |counters: &ServerCounters| (counters.served.load(Ordering::Relaxed) - base_served) as usize;

    loop {
        if budget.map(|b| answered(&counters) >= b).unwrap_or(false) {
            break;
        }
        if !router.is_accepting() {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if active.load(Ordering::Relaxed) >= config.max_connections {
                    counters.conn_rejected.fetch_add(1, Ordering::Relaxed);
                    log::warn!("rejecting {peer}: at connection capacity");
                    let mut s = stream;
                    let _ = s.write_all(format_error("server at connection capacity").as_bytes());
                    continue; // drop closes the socket
                }
                counters.conn_accepted.fetch_add(1, Ordering::Relaxed);
                active.fetch_add(1, Ordering::Relaxed);
                log::info!("connection from {peer}");
                let router = Arc::clone(&router);
                let counters = Arc::clone(&counters);
                let stop = Arc::clone(&stop);
                let active = Arc::clone(&active);
                let config = config.clone();
                handles.push(std::thread::spawn(move || {
                    let r = handle_connection(stream, &router, seq_len, &config, &counters, &stop);
                    active.fetch_sub(1, Ordering::Relaxed);
                    match r {
                        Ok(n) => log::info!("connection {peer} closed after {n} replies"),
                        Err(e) => log::warn!("connection {peer} error: {e:#}"),
                    }
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(config.accept_poll);
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                for h in handles {
                    let _ = h.join();
                }
                return Err(e).context("accept");
            }
        }
        // reap finished connection threads so the vec stays bounded
        handles.retain(|h| !h.is_finished());
    }

    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    Ok(answered(&counters))
}

/// Handle one client connection; returns the number of answered requests.
///
/// Three roles share the connection so a slow socket never blocks the
/// pipeline: the calling thread reads and submits lines (pipelined — it
/// never waits for a reply), a pump thread pairs each router reply with its
/// correlation id (valid because per-connection replies arrive in
/// submission order) and counts it served the moment `recv()` succeeds, and
/// a writer thread owns the send side, draining reply lines even after a
/// write failure so accounting stays exact.
pub fn handle_connection(
    stream: TcpStream,
    router: &Router,
    seq_len: usize,
    config: &ServerConfig,
    counters: &ServerCounters,
    stop: &AtomicBool,
) -> Result<usize> {
    stream
        .set_read_timeout(Some(config.read_timeout))
        .context("set_read_timeout")?;
    let writer_stream = stream.try_clone().context("clone stream")?;
    let (out_tx, out_rx) = mpsc::channel::<String>();
    let (corr_tx, corr_rx) = mpsc::channel::<u64>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let pending = AtomicUsize::new(0);
    let served_here = AtomicUsize::new(0);

    std::thread::scope(|s| -> Result<()> {
        // writer: sole owner of the send side
        s.spawn(move || {
            let mut w = writer_stream;
            let mut broken = false;
            for line in out_rx {
                if !broken && w.write_all(line.as_bytes()).is_err() {
                    // client gone: keep draining so senders never block and
                    // the pump's served/rejected accounting continues
                    broken = true;
                }
            }
        });

        // reply pump: pair replies with correlation ids, in order
        {
            let out_tx = out_tx.clone();
            let pending = &pending;
            let served_here = &served_here;
            s.spawn(move || {
                while let Ok(corr) = corr_rx.recv() {
                    match resp_rx.recv() {
                        Ok(resp) => {
                            counters.served.fetch_add(1, Ordering::Relaxed);
                            served_here.fetch_add(1, Ordering::Relaxed);
                            pending.fetch_sub(1, Ordering::Relaxed);
                            let _ = out_tx.send(format_response(corr, &resp));
                        }
                        Err(_) => {
                            // pipeline tore down before serving this request
                            counters.rejected.fetch_add(1, Ordering::Relaxed);
                            pending.fetch_sub(1, Ordering::Relaxed);
                            let _ = out_tx.send(format_error_id(corr, "server shutting down"));
                        }
                    }
                }
            });
        }

        // reader: this thread — parse lines, submit immediately, never wait
        // for replies
        let mut reader = BufReader::new(stream);
        let mut tag: Option<Arc<ClientTag>> = None;
        let mut first_line = true;
        let mut corr: u64 = 0;
        let mut line = String::new();
        let result: Result<()> = loop {
            // a chatty client never hits the read timeout, so teardown must
            // also be observed between lines
            if stop.load(Ordering::Relaxed) {
                break Ok(());
            }
            match reader.read_line(&mut line) {
                Ok(0) => break Ok(()), // EOF
                Ok(_) if !line.ends_with('\n') => {
                    // final unterminated line before EOF
                }
                Ok(_) => {}
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    // timeout may leave a partial line in `line`: keep it
                    // and resume reading unless the server is tearing down
                    if stop.load(Ordering::Relaxed) {
                        break Ok(());
                    }
                    continue;
                }
                Err(e) => break Err(e).context("read line"),
            }
            let trimmed = line.trim().to_string();
            let at_eof = !line.ends_with('\n');
            line.clear();
            if trimmed.is_empty() {
                if at_eof {
                    break Ok(());
                }
                continue;
            }
            if first_line {
                first_line = false;
                if let Some(hello) = parse_hello(&trimmed) {
                    match hello {
                        Ok(t) => {
                            let t = Arc::new(t);
                            let _ = out_tx.send(format_hello_ack(&t));
                            tag = Some(t);
                        }
                        Err(msg) => {
                            let _ = out_tx.send(format_error(&msg));
                        }
                    }
                    continue;
                }
            }
            if trimmed == "quit" {
                break Ok(());
            }
            let this_corr = corr;
            corr += 1;
            counters.submitted.fetch_add(1, Ordering::Relaxed);
            match parse_tokens(&trimmed, seq_len) {
                Err(msg) => {
                    counters.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = out_tx.send(format_error_id(this_corr, &msg));
                }
                Ok(toks) => {
                    if pending.load(Ordering::Relaxed) >= config.max_pending_per_conn {
                        counters.shed.fetch_add(1, Ordering::Relaxed);
                        let _ = out_tx
                            .send(format_shed(this_corr, config.shed_retry_after_ms));
                    } else {
                        match TensorI32::new(vec![1, seq_len], toks) {
                            Err(e) => {
                                counters.rejected.fetch_add(1, Ordering::Relaxed);
                                let _ = out_tx.send(format_error_id(this_corr, &e.to_string()));
                            }
                            Ok(t) => match router.try_submit(t, resp_tx.clone(), tag.clone()) {
                                Admission::Accepted(_) => {
                                    pending.fetch_add(1, Ordering::Relaxed);
                                    let _ = corr_tx.send(this_corr);
                                }
                                Admission::Shed => {
                                    counters.shed.fetch_add(1, Ordering::Relaxed);
                                    let _ = out_tx.send(format_shed(
                                        this_corr,
                                        config.shed_retry_after_ms,
                                    ));
                                }
                                Admission::Shutdown => {
                                    counters.rejected.fetch_add(1, Ordering::Relaxed);
                                    let _ = out_tx.send(format_error_id(
                                        this_corr,
                                        "server shutting down",
                                    ));
                                    break Ok(());
                                }
                            },
                        }
                    }
                }
            }
            if at_eof {
                break Ok(());
            }
        };
        // closing these lets the pump drain outstanding replies and exit,
        // then the writer flush and exit; the scope joins both
        drop(resp_tx);
        drop(corr_tx);
        drop(out_tx);
        result
    })?;
    Ok(served_here.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_identity_and_shed_rate() {
        let c = ServerCounters::new();
        c.submitted.fetch_add(10, Ordering::Relaxed);
        c.served.fetch_add(7, Ordering::Relaxed);
        c.shed.fetch_add(2, Ordering::Relaxed);
        c.rejected.fetch_add(1, Ordering::Relaxed);
        let s = c.snapshot();
        assert!(s.balanced());
        assert!((s.shed_rate() - 0.2).abs() < 1e-12);
        // one more in flight: identity intentionally not yet satisfied
        c.submitted.fetch_add(1, Ordering::Relaxed);
        assert!(!c.snapshot().balanced());
    }

    #[test]
    fn empty_stat_does_not_divide_by_zero() {
        let s = ServerStat::default();
        assert!(s.balanced());
        assert_eq!(s.shed_rate(), 0.0);
        let line = s.to_string();
        assert!(line.contains("submitted 0"), "{line}");
    }

    #[test]
    fn default_config_is_sane() {
        let c = ServerConfig::default();
        assert!(c.max_connections > 0);
        assert!(c.max_pending_per_conn > 0);
        assert!(c.read_timeout > Duration::ZERO);
        assert!(c.accept_poll > Duration::ZERO);
    }
}

//! Host tensor <-> XLA literal conversion.

use anyhow::{bail, Context, Result};

use crate::tensor::{TensorF32, TensorI32};

/// f32 tensor -> literal with the tensor's shape.
pub fn literal_f32(t: &TensorF32) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .context("reshaping f32 literal")
}

/// i32 tensor -> literal with the tensor's shape.
pub fn literal_i32(t: &TensorI32) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .context("reshaping i32 literal")
}

/// literal -> f32 tensor (shape taken from the literal).
pub fn tensor_f32(lit: &xla::Literal) -> Result<TensorF32> {
    let shape = literal_dims(lit)?;
    let data = lit.to_vec::<f32>().context("reading f32 literal")?;
    TensorF32::new(shape, data).map_err(|e| anyhow::anyhow!(e))
}

/// literal -> i32 tensor.
pub fn tensor_i32(lit: &xla::Literal) -> Result<TensorI32> {
    let shape = literal_dims(lit)?;
    let data = lit.to_vec::<i32>().context("reading i32 literal")?;
    TensorI32::new(shape, data).map_err(|e| anyhow::anyhow!(e))
}

fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    match lit.shape().context("literal shape")? {
        xla::Shape::Array(a) => Ok(a.dims().iter().map(|&d| d as usize).collect()),
        other => bail!("expected array literal, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise the conversion layer without a PJRT client;
    // Literal construction is pure host-side XLA.

    #[test]
    fn f32_roundtrip() {
        let t = TensorF32::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let lit = literal_f32(&t).unwrap();
        let back = tensor_f32(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_roundtrip() {
        let t = TensorI32::new(vec![4], vec![7, -1, 0, 42]).unwrap();
        let lit = literal_i32(&t).unwrap();
        let back = tensor_i32(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn wrong_dtype_read_fails() {
        let t = TensorF32::new(vec![2], vec![1.0, 2.0]).unwrap();
        let lit = literal_f32(&t).unwrap();
        assert!(tensor_i32(&lit).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = TensorF32::scalar(3.5);
        let lit = literal_f32(&t).unwrap();
        let back = tensor_f32(&lit).unwrap();
        assert_eq!(back.data(), &[3.5]);
    }
}

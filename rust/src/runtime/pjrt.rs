//! The PJRT compute backend: executes the AOT-compiled HLO artifacts as
//! fused **partition ranges** (see the module docs in `runtime/mod.rs`).
//!
//! The serving hot path is partitioned at the split layer: one fused
//! `chain{n}` executable covers `blocks[i..j)` in a single launch (the
//! activation stays device-resident inside the module), the exit head is one
//! more launch, and the hidden state crosses the host boundary only where
//! the system semantics require it.  Between launches the activation is
//! carried as a raw XLA literal inside the opaque [`Hidden`] handle.  When
//! an artifact set predates the chain graphs the executor falls back to
//! per-block launches with the same literal passthrough, so outputs are
//! identical either way.
//!
//! The fused `chain{n}` executables are weight-parameterized like `block`,
//! so one compiled module serves *every* range of length `n`; they are
//! compiled lazily per `(length, batch)` through the runtime's bounded LRU
//! cache rather than eagerly at load.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::executable::{Arg, Executable, Runtime};
use super::literal::{literal_f32, tensor_f32};
use super::lru::CacheStats;
use super::{ComputeBackend, HeadOut, Hidden, HiddenRepr, ModelExecutor, ModelSpec};
use crate::model::weights::ModelWeights;
use crate::tensor::{TensorF32, TensorI32};

/// XLA-literal activation handle (the pjrt backend's [`HiddenRepr`]).
struct LiteralHidden(xla::Literal);

impl std::fmt::Debug for LiteralHidden {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("LiteralHidden")
    }
}

impl HiddenRepr for LiteralHidden {
    fn to_tensor(&self) -> Result<TensorF32> {
        tensor_f32(&self.0)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The PJRT backend: one shared client + compiled-executable cache; every
/// loaded model compiles through it.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime) -> PjrtBackend {
        PjrtBackend { runtime }
    }

    /// Backend over a fresh CPU client.
    pub fn cpu() -> Result<PjrtBackend> {
        Ok(PjrtBackend { runtime: Runtime::cpu()? })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend").field("runtime", &self.runtime).finish()
    }
}

// SAFETY: the runtime's executables are internally synchronized (see
// `Executable`); compilation is serialized under the runtime's dedicated
// compile lock, so the thread-affine client never compiles from two threads
// at once.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load_model(&self, spec: &ModelSpec<'_>) -> Result<Box<dyn ModelExecutor>> {
        let manifest = spec.manifest.with_context(|| {
            format!(
                "the pjrt backend executes compiled HLO artifacts — load {}/{} \
                 through a manifest (run `make artifacts`), or use the reference \
                 backend for artifact-free models",
                spec.task, spec.style
            )
        })?;
        let weights = Arc::clone(&spec.weights);
        let n_layers = weights.n_layers;
        let head_graph = format!("head_c{}", weights.n_classes);
        let mut embed = BTreeMap::new();
        let mut block = BTreeMap::new();
        let mut head = BTreeMap::new();
        for &b in &spec.batch_sizes {
            embed.insert(b, self.runtime.load(&manifest.hlo_path("embed", b)?)?);
            block.insert(b, self.runtime.load(&manifest.hlo_path("block", b)?)?);
            head.insert(b, self.runtime.load(&manifest.hlo_path(&head_graph, b)?)?);
        }
        let prefix_graph = format!("prefix_full_c{}", weights.n_classes);
        let prefix_full = match manifest.hlo_path(&prefix_graph, spec.cache_batch) {
            Ok(path) => Some((spec.cache_batch, self.runtime.load(&path)?)),
            Err(_) => None,
        };
        // Fused block-range graphs (chain2..chainL): record paths only; the
        // runtime compiles each lazily on first use behind its LRU cache.
        // Length-1 ranges reuse the plain `block` executable.
        let mut chain = BTreeMap::new();
        for len in 2..=n_layers {
            let graph = format!("chain{len}");
            for &b in &spec.batch_sizes {
                if let Ok(path) = manifest.hlo_path(&graph, b) {
                    chain.insert((len, b), path);
                }
            }
        }
        let lits = if std::env::var("SPLITEE_NO_LITERAL_CACHE").is_ok() {
            None
        } else {
            Some(build_lit_cache(&weights)?)
        };
        Ok(Box::new(PjrtExecutor {
            n_layers,
            n_classes: weights.n_classes,
            weights,
            runtime: self.runtime.clone(),
            embed,
            block,
            head,
            prefix_full,
            chain,
            lits,
            batch_sizes: spec.batch_sizes.clone(),
        }))
    }
}

struct LitCache {
    embed: Vec<xla::Literal>,
    blocks: Vec<Vec<xla::Literal>>,
    heads: Vec<Vec<xla::Literal>>,
    prefix: Vec<xla::Literal>,
}

fn build_lit_cache(weights: &ModelWeights) -> Result<LitCache> {
    let conv = |ts: &[TensorF32]| -> Result<Vec<xla::Literal>> {
        ts.iter().map(literal_f32).collect()
    };
    Ok(LitCache {
        embed: conv(&weights.embed)?,
        blocks: weights.blocks.iter().map(|b| conv(b)).collect::<Result<_>>()?,
        heads: weights.heads.iter().map(|h| conv(h)).collect::<Result<_>>()?,
        prefix: {
            let mut all = conv(&weights.embed)?;
            for b in &weights.blocks {
                all.extend(conv(b)?);
            }
            for h in &weights.heads {
                all.extend(conv(h)?);
            }
            all
        },
    })
}

/// One trained model bound to its compiled executables.
pub(crate) struct PjrtExecutor {
    weights: Arc<ModelWeights>,
    runtime: Runtime,
    embed: BTreeMap<usize, Arc<Executable>>,
    block: BTreeMap<usize, Arc<Executable>>,
    head: BTreeMap<usize, Arc<Executable>>,
    prefix_full: Option<(usize, Arc<Executable>)>,
    /// fused block-range artifacts: (range length, batch) -> HLO path,
    /// loaded lazily through the runtime's LRU cache
    chain: BTreeMap<(usize, usize), PathBuf>,
    /// Weight tensors pre-converted to XLA literals — skips the host copy on
    /// every layer execution (L3 perf pass; disable for A/B measurement with
    /// SPLITEE_NO_LITERAL_CACHE=1).
    lits: Option<LitCache>,
    batch_sizes: Vec<usize>,
    n_layers: usize,
    n_classes: usize,
}

impl std::fmt::Debug for PjrtExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtExecutor")
            .field("layers", &self.n_layers)
            .field("classes", &self.n_classes)
            .field("fused_ranges", &self.chain.len())
            .finish()
    }
}

// SAFETY: the literal cache is immutable after construction and literals are
// plain host buffers; the PJRT CPU executables are internally synchronized.
// The runtime handle is only used for lazy chain compiles, which are
// serialized under the runtime's dedicated compile lock (cache-hit probes
// never compile), so the thread-affine client never compiles from two
// threads at once.  The executor is only ever used behind `Arc`/`Box` with
// `&self` access.
unsafe impl Send for PjrtExecutor {}
unsafe impl Sync for PjrtExecutor {}

impl PjrtExecutor {
    fn pick_exec<'a>(
        table: &'a BTreeMap<usize, Arc<Executable>>,
        batch: usize,
    ) -> Result<&'a Arc<Executable>> {
        table
            .get(&batch)
            .with_context(|| format!("no executable compiled for batch {batch}"))
    }

    fn lit_of<'a>(&self, h: &'a Hidden) -> Result<&'a xla::Literal> {
        h.repr()
            .as_any()
            .downcast_ref::<LiteralHidden>()
            .map(|l| &l.0)
            .context("hidden state does not belong to the pjrt backend")
    }

    fn push_block_args<'a>(&'a self, args: &mut Vec<Arg<'a>>, layer: usize) {
        match &self.lits {
            Some(l) => args.extend(l.blocks[layer].iter().map(Arg::Lit)),
            None => args.extend(self.weights.blocks[layer].iter().map(Arg::F32)),
        }
    }

    /// Run blocks `start..end` (0-based, end exclusive) from a hidden-state
    /// argument, returning the raw output literal.  One fused launch when
    /// the `chain{end-start}` artifact exists; otherwise per-block launches
    /// with literal passthrough (no host materialization either way).
    fn run_blocks_arg(
        &self,
        h: Arg<'_>,
        batch: usize,
        start: usize,
        end: usize,
    ) -> Result<xla::Literal> {
        if start >= end || end > self.n_layers {
            bail!(
                "block range [{start}, {end}) out of bounds (L = {})",
                self.n_layers
            );
        }
        let len = end - start;
        if len > 1 {
            if let Some(path) = self.chain.get(&(len, batch)) {
                let exe = self
                    .runtime
                    .load(path)
                    .with_context(|| format!("loading fused range chain{len} (batch {batch})"))?;
                let mut args: Vec<Arg<'_>> = Vec::with_capacity(1 + 16 * len);
                args.push(h);
                match &self.lits {
                    Some(l) => {
                        for blk in &l.blocks[start..end] {
                            args.extend(blk.iter().map(Arg::Lit));
                        }
                    }
                    None => {
                        args.extend(self.weights.block_range_args(start, end).map(Arg::F32))
                    }
                }
                let mut out = exe.run(&args)?;
                if out.is_empty() {
                    bail!("chain{len} returned no outputs");
                }
                return Ok(out.remove(0));
            }
        }
        // fallback: per-block launches, activation carried as a literal
        let exe = Self::pick_exec(&self.block, batch)?;
        let mut cur = {
            let mut args: Vec<Arg<'_>> = Vec::with_capacity(17);
            args.push(h);
            self.push_block_args(&mut args, start);
            let mut out = exe.run(&args)?;
            if out.is_empty() {
                bail!("block returned no outputs");
            }
            out.remove(0)
        };
        for layer in (start + 1)..end {
            let mut out = {
                let mut args: Vec<Arg<'_>> = Vec::with_capacity(17);
                args.push(Arg::Lit(&cur));
                self.push_block_args(&mut args, layer);
                exe.run(&args)?
            };
            if out.is_empty() {
                bail!("block returned no outputs");
            }
            cur = out.remove(0);
        }
        Ok(cur)
    }

    fn exit_head_arg(&self, h: Arg<'_>, batch: usize, layer: usize) -> Result<HeadOut> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (L = {})", self.n_layers);
        }
        let exe = Self::pick_exec(&self.head, batch)?;
        let mut args = vec![h];
        match &self.lits {
            Some(l) => args.extend(l.heads[layer].iter().map(Arg::Lit)),
            None => args.extend(self.weights.heads[layer].iter().map(Arg::F32)),
        }
        let out = exe.run(&args)?;
        if out.len() != 3 {
            bail!("exit head returned {} outputs, expected 3", out.len());
        }
        let probs = tensor_f32(&out[0])?;
        let conf = tensor_f32(&out[1])?;
        let ent = tensor_f32(&out[2])?;
        Ok(HeadOut {
            probs,
            conf: conf.into_data(),
            ent: ent.into_data(),
        })
    }
}

impl ModelExecutor for PjrtExecutor {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn embed(&self, tokens: &TensorI32) -> Result<Hidden> {
        let b = tokens.shape()[0];
        let exe = Self::pick_exec(&self.embed, b)?;
        let mut args = vec![Arg::I32(tokens)];
        match &self.lits {
            Some(l) => args.extend(l.embed.iter().map(Arg::Lit)),
            None => args.extend(self.weights.embed.iter().map(Arg::F32)),
        }
        let mut out = exe.run(&args)?;
        if out.is_empty() {
            bail!("embed returned no outputs");
        }
        Ok(Hidden::new(b, Box::new(LiteralHidden(out.remove(0)))))
    }

    fn blocks(&self, h: &Hidden, start: usize, end: usize) -> Result<Hidden> {
        let lit = self.run_blocks_arg(Arg::Lit(self.lit_of(h)?), h.batch(), start, end)?;
        Ok(Hidden::new(h.batch(), Box::new(LiteralHidden(lit))))
    }

    fn blocks_host(&self, h: &TensorF32, start: usize, end: usize) -> Result<Hidden> {
        let b = h.shape()[0];
        let lit = self.run_blocks_arg(Arg::F32(h), b, start, end)?;
        Ok(Hidden::new(b, Box::new(LiteralHidden(lit))))
    }

    fn exit_head(&self, h: &Hidden, layer: usize) -> Result<HeadOut> {
        self.exit_head_arg(Arg::Lit(self.lit_of(h)?), h.batch(), layer)
    }

    fn exit_head_host(&self, h: &TensorF32, layer: usize) -> Result<HeadOut> {
        self.exit_head_arg(Arg::F32(h), h.shape()[0], layer)
    }

    /// Full forward through every exit at once via the fused `prefix_full`
    /// graph.  tokens [B, T] with any B — batching/padding handled here.
    ///
    /// Accumulators are preallocated from the batch plan (`n` rows, `C`
    /// classes known up front), so covering a large cache is one exact-size
    /// allocation per layer instead of a re-concatenation per chunk.
    fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<HeadOut>> {
        let (cache_b, exe) = self
            .prefix_full
            .as_ref()
            .context("prefix_full graph not in manifest")?;
        let n = tokens.shape()[0];
        let c = self.n_classes;
        let layers = self.n_layers;
        let mut probs_acc: Vec<Vec<f32>> =
            (0..layers).map(|_| Vec::with_capacity(n * c)).collect();
        let mut conf_acc: Vec<Vec<f32>> = (0..layers).map(|_| Vec::with_capacity(n)).collect();
        let mut ent_acc: Vec<Vec<f32>> = (0..layers).map(|_| Vec::with_capacity(n)).collect();
        let mut done = 0usize;
        while done < n {
            let real = (*cache_b).min(n - done);
            let chunk = tokens
                .slice_rows(done, done + real)
                .map_err(|e| anyhow::anyhow!(e))?
                .pad_rows_to(*cache_b)
                .map_err(|e| anyhow::anyhow!(e))?;
            let mut args = vec![Arg::I32(&chunk)];
            let flat;
            match &self.lits {
                Some(l) => args.extend(l.prefix.iter().map(Arg::Lit)),
                None => {
                    flat = self.weights.prefix_full_args();
                    args.extend(flat.iter().map(|t| Arg::F32(t)));
                }
            }
            let out = exe.run_f32(&args)?;
            // output layout: (probs [L,B,C], conf [L,B], ent [L,B])
            if out.len() != 3 {
                bail!("prefix_full returned {} outputs, expected 3", out.len());
            }
            let (probs, conf, ent) = (&out[0], &out[1], &out[2]);
            let b = probs.shape()[1];
            if probs.shape()[2] != c {
                bail!("prefix_full emitted {} classes, weights have {c}", probs.shape()[2]);
            }
            // copy the `real` unpadded rows of each stacked layer straight
            // into the preallocated accumulators
            for l in 0..layers {
                probs_acc[l].extend_from_slice(&probs.data()[l * b * c..l * b * c + real * c]);
                conf_acc[l].extend_from_slice(&conf.data()[l * b..l * b + real]);
                ent_acc[l].extend_from_slice(&ent.data()[l * b..l * b + real]);
            }
            done += real;
        }
        probs_acc
            .into_iter()
            .zip(conf_acc)
            .zip(ent_acc)
            .map(|((p, cf), en)| {
                let probs = TensorF32::new(vec![n, c], p).map_err(|e| anyhow::anyhow!(e))?;
                Ok(HeadOut { probs, conf: cf, ent: en })
            })
            .collect()
    }

    /// Ensure the fused range executable for blocks `start..end` at `batch`
    /// is compiled (no-op when absent or length 1).  The serving stages call
    /// this *before* their timed regions so a first-use (or post-eviction)
    /// chain compile is never recorded as simulated compute latency.
    fn warm_range(&self, batch: usize, start: usize, end: usize) -> Result<()> {
        if end > start && end - start > 1 {
            if let Some(path) = self.chain.get(&(end - start, batch)) {
                self.runtime.load(path).with_context(|| {
                    format!("pre-warming fused range chain{} (batch {batch})", end - start)
                })?;
            }
        }
        Ok(())
    }

    /// Speculative full-batch continuations are *not* decision-transparent
    /// here: a gathered offload chunk may pad to a different compiled batch
    /// size than the edge batch, so it can execute a different `chain{n}` /
    /// head executable whose floats agree only to tolerance (cf. the
    /// `batched_execution_matches_single` bars).  Substituting a speculative
    /// result for the serial-path launch could therefore drift a bandit
    /// decision by an ulp, so the coordinator disables speculation entirely
    /// on this backend (`Service::new` never builds a lane for it).  The
    /// lane itself is backend-agnostic and can still drive this executor
    /// directly — the pjrt-gated test in `tests/speculation.rs` does.
    fn speculation_transparent(&self) -> bool {
        false
    }

    /// True when every multi-block range has a fused artifact (all lengths
    /// 2..=L at every compiled batch size), i.e. the serving path runs one
    /// block-range launch per partition.
    fn has_fused_ranges(&self) -> bool {
        self.batch_sizes
            .iter()
            .all(|&b| (2..=self.n_layers).all(|len| self.chain.contains_key(&(len, b))))
    }

    fn cache_stats(&self) -> CacheStats {
        self.runtime.cache_stats()
    }
}

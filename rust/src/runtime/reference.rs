//! The pure-Rust reference backend: executes embed / transformer blocks /
//! exit heads directly from the host-side [`ModelWeights`], no compiled
//! artifacts and no external libraries.
//!
//! The math mirrors `python/compile/kernels/ref.py` operation for operation
//! (pre-LN attention and FFN with residuals, tanh-approximate GELU, stable
//! softmax, entropy in nats with the same `1e-12` floor), so outputs agree
//! with the AOT-compiled PJRT graphs to float tolerance — asserted by the
//! reference-vs-pjrt parity test in `tests/integration.rs`.  Fused-range
//! semantics are trivial here (`blocks(start..end)` is one "launch" however
//! many layers it covers), which keeps launch-count metrics comparable with
//! the PJRT partition path.
//!
//! Naive loops on purpose: this backend exists so the full stack builds,
//! tests and benches **everywhere** — correctness and portability first,
//! with per-row work laid out so the obvious SIMD/thread upgrades stay easy.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{
    count_launch, ComputeBackend, HeadOut, Hidden, HiddenRepr, ModelExecutor, ModelSpec,
};
use crate::model::weights::ModelWeights;
use crate::tensor::{TensorF32, TensorI32};

/// LayerNorm epsilon — matches `ref.py::layer_norm`.
const LN_EPS: f32 = 1e-5;
/// sqrt(2/pi) for the tanh-approximate GELU — matches `jax.nn.gelu`.
const GELU_C: f32 = 0.797_884_56;
/// Entropy log floor — matches `ref.py::exit_head_ref`.
const ENT_EPS: f32 = 1e-12;

/// Host-tensor activation handle (the reference backend's [`HiddenRepr`]).
#[derive(Debug)]
struct HostHidden(TensorF32);

impl HiddenRepr for HostHidden {
    fn to_tensor(&self) -> Result<TensorF32> {
        Ok(self.0.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The always-available pure-Rust backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReferenceBackend;

impl ComputeBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load_model(&self, spec: &ModelSpec<'_>) -> Result<Box<dyn ModelExecutor>> {
        Ok(Box::new(ReferenceExecutor::new(spec)?))
    }
}

/// One model bound to the reference math.
pub(crate) struct ReferenceExecutor {
    weights: Arc<ModelWeights>,
    n_heads: usize,
    d_model: usize,
    n_layers: usize,
}

impl std::fmt::Debug for ReferenceExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReferenceExecutor")
            .field("layers", &self.n_layers)
            .field("d_model", &self.d_model)
            .field("heads", &self.n_heads)
            .finish()
    }
}

impl ReferenceExecutor {
    fn new(spec: &ModelSpec<'_>) -> Result<ReferenceExecutor> {
        let weights = Arc::clone(&spec.weights);
        let tok = &weights.embed[0];
        if tok.ndim() != 2 {
            bail!("embed.tok must be 2-D [vocab, d_model], got {:?}", tok.shape());
        }
        let d_model = tok.shape()[1];
        if spec.n_heads == 0 || d_model % spec.n_heads != 0 {
            bail!(
                "d_model {d_model} is not divisible by n_heads {} — \
                 reference attention needs equal head widths",
                spec.n_heads
            );
        }
        Ok(ReferenceExecutor {
            n_layers: weights.n_layers,
            weights,
            n_heads: spec.n_heads,
            d_model,
        })
    }

    fn host_of<'a>(&self, h: &'a Hidden) -> Result<&'a TensorF32> {
        h.repr()
            .as_any()
            .downcast_ref::<HostHidden>()
            .map(|hh| &hh.0)
            .context("hidden state does not belong to the reference backend")
    }

    /// Embedding math: tokens [B, T] -> h0 [B, T, D].
    fn embed_math(&self, tokens: &TensorI32) -> Result<TensorF32> {
        if tokens.ndim() != 2 {
            bail!("tokens must be [B, T], got shape {:?}", tokens.shape());
        }
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        let tok = &self.weights.embed[0];
        let pos = &self.weights.embed[1];
        let (ln_g, ln_b) = (&self.weights.embed[2], &self.weights.embed[3]);
        let vocab = tok.shape()[0];
        let d = self.d_model;
        if pos.ndim() != 2 || pos.shape()[1] != d {
            bail!("embed.pos must be [T, {d}], got {:?}", pos.shape());
        }
        if t > pos.shape()[0] {
            bail!(
                "sequence length {t} exceeds the positional table ({} rows)",
                pos.shape()[0]
            );
        }
        let mut h = vec![0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                let id = tokens.data()[bi * t + ti];
                if id < 0 || id as usize >= vocab {
                    bail!(
                        "token id {id} at [{bi}, {ti}] is outside the vocabulary \
                         (0..{vocab})"
                    );
                }
                let row = &mut h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                let tk = &tok.data()[id as usize * d..(id as usize + 1) * d];
                let ps = &pos.data()[ti * d..(ti + 1) * d];
                for j in 0..d {
                    row[j] = tk[j] + ps[j];
                }
            }
        }
        layer_norm_rows(&mut h, d, ln_g.data(), ln_b.data());
        TensorF32::new(vec![b, t, d], h).map_err(|e| anyhow::anyhow!(e))
    }

    /// One transformer block (pre-LN attention + pre-LN FFN, both residual).
    fn block_math(&self, x: Vec<f32>, b: usize, t: usize, layer: usize) -> Vec<f32> {
        // BLOCK_PARAM_ORDER: ln1_g ln1_b wq bq wk bk wv bv wo bo
        //                    ln2_g ln2_b w1 b1 w2 b2
        let p = &self.weights.blocks[layer];
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = d / heads;
        let n = b * t;

        // ---- attention: x + (softmax(QK^T / sqrt(dh)) V) Wo + bo
        let mut hn = x.clone();
        layer_norm_rows(&mut hn, d, p[0].data(), p[1].data());
        let q = matmul_bias(&hn, p[2].data(), p[3].data(), n, d, d);
        let k = matmul_bias(&hn, p[4].data(), p[5].data(), n, d, d);
        let v = matmul_bias(&hn, p[6].data(), p[7].data(), n, d, d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut o = vec![0f32; n * d];
        let mut scores = vec![0f32; t];
        for bi in 0..b {
            for hi in 0..heads {
                let hoff = hi * dh;
                for ti in 0..t {
                    let qoff = (bi * t + ti) * d + hoff;
                    for (si, s) in scores.iter_mut().enumerate() {
                        let koff = (bi * t + si) * d + hoff;
                        let mut dot = 0f32;
                        for dd in 0..dh {
                            dot += q[qoff + dd] * k[koff + dd];
                        }
                        *s = dot * scale;
                    }
                    softmax_inplace(&mut scores);
                    let ooff = (bi * t + ti) * d + hoff;
                    for (si, &w) in scores.iter().enumerate() {
                        let voff = (bi * t + si) * d + hoff;
                        for dd in 0..dh {
                            o[ooff + dd] += w * v[voff + dd];
                        }
                    }
                }
            }
        }
        let proj = matmul_bias(&o, p[8].data(), p[9].data(), n, d, d);
        let mut x = x;
        for i in 0..n * d {
            x[i] += proj[i];
        }

        // ---- FFN: x + W2 gelu(W1 LN2(x) + b1) + b2
        let f = p[12].shape()[1];
        let mut hn = x.clone();
        layer_norm_rows(&mut hn, d, p[10].data(), p[11].data());
        let mut a = matmul_bias(&hn, p[12].data(), p[13].data(), n, d, f);
        for v in a.iter_mut() {
            *v = gelu_tanh(*v);
        }
        let proj = matmul_bias(&a, p[14].data(), p[15].data(), n, f, d);
        for i in 0..n * d {
            x[i] += proj[i];
        }
        x
    }

    /// Blocks `start..end` over a [B, T, D] tensor.
    fn run_blocks(&self, h: &TensorF32, start: usize, end: usize) -> Result<TensorF32> {
        if h.ndim() != 3 || h.shape()[2] != self.d_model {
            bail!(
                "hidden state must be [B, T, {}], got {:?}",
                self.d_model,
                h.shape()
            );
        }
        if start >= end || end > self.n_layers {
            bail!(
                "block range [{start}, {end}) out of bounds (L = {})",
                self.n_layers
            );
        }
        let (b, t) = (h.shape()[0], h.shape()[1]);
        let mut x = h.data().to_vec();
        for layer in start..end {
            x = self.block_math(x, b, t, layer);
        }
        TensorF32::new(vec![b, t, self.d_model], x).map_err(|e| anyhow::anyhow!(e))
    }

    /// Exit head after `layer` over a [B, T, D] tensor.
    fn head_math(&self, h: &TensorF32, layer: usize) -> Result<HeadOut> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (L = {})", self.n_layers);
        }
        if h.ndim() != 3 || h.shape()[2] != self.d_model {
            bail!(
                "hidden state must be [B, T, {}], got {:?}",
                self.d_model,
                h.shape()
            );
        }
        // HEAD_PARAM_ORDER: ln_g ln_b wc bc
        let p = &self.weights.heads[layer];
        let (b, t, d) = (h.shape()[0], h.shape()[1], self.d_model);
        let c = p[2].shape()[1];
        // [CLS] pooling: row 0 of every sample
        let mut cls = vec![0f32; b * d];
        for bi in 0..b {
            cls[bi * d..(bi + 1) * d].copy_from_slice(&h.data()[bi * t * d..bi * t * d + d]);
        }
        layer_norm_rows(&mut cls, d, p[0].data(), p[1].data());
        let mut logits = matmul_bias(&cls, p[2].data(), p[3].data(), b, d, c);
        let mut conf = Vec::with_capacity(b);
        let mut ent = Vec::with_capacity(b);
        for row in logits.chunks_exact_mut(c) {
            softmax_inplace(row);
            let mut mx = row[0];
            let mut h_nats = 0f32;
            for &pv in row.iter() {
                if pv > mx {
                    mx = pv;
                }
                h_nats -= pv * (pv + ENT_EPS).ln();
            }
            conf.push(mx);
            ent.push(h_nats);
        }
        let probs = TensorF32::new(vec![b, c], logits).map_err(|e| anyhow::anyhow!(e))?;
        Ok(HeadOut { probs, conf, ent })
    }
}

impl ModelExecutor for ReferenceExecutor {
    fn backend_name(&self) -> &'static str {
        "reference"
    }

    fn embed(&self, tokens: &TensorI32) -> Result<Hidden> {
        let h = self.embed_math(tokens)?;
        count_launch();
        let b = h.shape()[0];
        Ok(Hidden::new(b, Box::new(HostHidden(h))))
    }

    fn blocks(&self, h: &Hidden, start: usize, end: usize) -> Result<Hidden> {
        let out = self.run_blocks(self.host_of(h)?, start, end)?;
        count_launch();
        Ok(Hidden::new(h.batch(), Box::new(HostHidden(out))))
    }

    fn blocks_host(&self, h: &TensorF32, start: usize, end: usize) -> Result<Hidden> {
        let out = self.run_blocks(h, start, end)?;
        count_launch();
        let b = out.shape()[0];
        Ok(Hidden::new(b, Box::new(HostHidden(out))))
    }

    fn exit_head(&self, h: &Hidden, layer: usize) -> Result<HeadOut> {
        let out = self.head_math(self.host_of(h)?, layer)?;
        count_launch();
        Ok(out)
    }

    fn exit_head_host(&self, h: &TensorF32, layer: usize) -> Result<HeadOut> {
        let out = self.head_math(h, layer)?;
        count_launch();
        Ok(out)
    }

    fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<HeadOut>> {
        let h0 = self.embed_math(tokens)?;
        // one "launch" for the whole sweep — the analogue of PJRT's fused
        // prefix_full module, keeping cross-backend launch units comparable
        count_launch();
        let (b, t) = (h0.shape()[0], h0.shape()[1]);
        let mut x = h0.into_data();
        let mut out = Vec::with_capacity(self.n_layers);
        for layer in 0..self.n_layers {
            x = self.block_math(x, b, t, layer);
            let h = TensorF32::new(vec![b, t, self.d_model], x.clone())
                .map_err(|e| anyhow::anyhow!(e))?;
            out.push(self.head_math(&h, layer)?);
        }
        Ok(out)
    }

    fn has_fused_ranges(&self) -> bool {
        // any blocks(start..end) call is one "launch" here, whatever its
        // length — the fused-partition invariant holds by construction
        true
    }

    fn speculation_transparent(&self) -> bool {
        // every operation here is row-independent (attention and softmax
        // reduce within a sample, never across the batch), so computing the
        // continuation over the full padded batch and reading out rows is
        // bit-identical to gathering first — the invariant
        // `reference_batched_execution_matches_single` pins.  Speculative
        // results are therefore safe to consume verbatim.
        true
    }
}

/// LayerNorm over the last axis, row by row (`ref.py::layer_norm`).
fn layer_norm_rows(x: &mut [f32], d: usize, g: &[f32], b: &[f32]) {
    debug_assert!(d > 0 && x.len() % d == 0 && g.len() == d && b.len() == d);
    for row in x.chunks_exact_mut(d) {
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            row[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
}

/// out[n, m] = x[n, k] @ w[k, m] + bias[m] (row-major, k-outer accumulation).
fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), k * m);
    debug_assert_eq!(bias.len(), m);
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let xi = &x[i * k..(i + 1) * k];
        let oi = &mut out[i * m..(i + 1) * m];
        oi.copy_from_slice(bias);
        for (kk, &xv) in xi.iter().enumerate() {
            let wrow = &w[kk * m..(kk + 1) * m];
            for j in 0..m {
                oi[j] += xv * wrow[j];
            }
        }
    }
    out
}

/// Numerically stable in-place softmax over one row.
fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Tanh-approximate GELU (`jax.nn.gelu(..., approximate=True)`).
fn gelu_tanh(v: f32) -> f32 {
    0.5 * v * (1.0 + (GELU_C * (v + 0.044715 * v * v * v)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        layer_norm_rows(&mut x, 4, &g, &b);
        let mu: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6, "mean {mu}");
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
        // gain/bias applied after normalization
        let mut y = vec![1.0f32, 2.0, 3.0, 4.0];
        layer_norm_rows(&mut y, 4, &[2.0; 4], &[1.0; 4]);
        for (a, c) in x.iter().zip(&y) {
            assert!((a * 2.0 + 1.0 - c).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_bias_matches_hand_computation() {
        // [2,3] @ [3,2] + bias
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let bias = [10.0, 20.0];
        let out = matmul_bias(&x, &w, &bias, 2, 3, 2);
        assert_eq!(out, vec![1.0 + 3.0 + 10.0, 2.0 + 3.0 + 20.0, 4.0 + 6.0 + 10.0, 5.0 + 6.0 + 20.0]);
    }

    #[test]
    fn softmax_is_stable_and_normalized() {
        let mut row = vec![1000.0f32, 1001.0, 1002.0];
        softmax_inplace(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(row[2] > row[1] && row[1] > row[0]);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gelu_matches_known_values() {
        assert_eq!(gelu_tanh(0.0), 0.0);
        // gelu(1) ≈ 0.841192 (tanh approximation)
        assert!((gelu_tanh(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu_tanh(-1.0) + 0.158808).abs() < 1e-4);
        // large inputs saturate to identity / zero
        assert!((gelu_tanh(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_tanh(-10.0).abs() < 1e-4);
    }

    #[test]
    fn executor_rejects_bad_ranges_and_tokens() {
        use crate::model::ModelWeights;
        let weights = Arc::new(ModelWeights::synthetic(2, 8, 16, 32, 4, 2, 7));
        let spec = ModelSpec {
            task: "t",
            style: "s",
            weights,
            n_heads: 2,
            seq_len: 4,
            batch_sizes: vec![1],
            cache_batch: 1,
            manifest: None,
        };
        let exec = ReferenceExecutor::new(&spec).unwrap();
        let tokens = TensorI32::new(vec![1, 4], vec![0, 1, 2, 3]).unwrap();
        let h = exec.embed(&tokens).unwrap();
        assert!(exec.blocks(&h, 1, 1).is_err(), "empty range");
        assert!(exec.blocks(&h, 0, 3).is_err(), "range past L");
        assert!(exec.exit_head(&h, 2).is_err(), "head past L");
        // out-of-vocabulary token ids are a clear error, not a panic
        let bad = TensorI32::new(vec![1, 4], vec![0, 1, 2, 64]).unwrap();
        let err = exec.embed(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("vocabulary"));
    }

    #[test]
    fn head_probs_are_a_distribution() {
        use crate::model::ModelWeights;
        let weights = Arc::new(ModelWeights::synthetic(2, 8, 16, 32, 4, 3, 11));
        let spec = ModelSpec {
            task: "t",
            style: "s",
            weights,
            n_heads: 2,
            seq_len: 4,
            batch_sizes: vec![1, 2],
            cache_batch: 2,
            manifest: None,
        };
        let exec = ReferenceExecutor::new(&spec).unwrap();
        let tokens = TensorI32::new(vec![2, 4], vec![5, 1, 9, 3, 0, 31, 7, 2]).unwrap();
        let h0 = exec.embed(&tokens).unwrap();
        let h1 = exec.blocks(&h0, 0, 2).unwrap();
        let out = exec.exit_head(&h1, 1).unwrap();
        assert_eq!(out.probs.shape(), &[2, 3]);
        for row in out.probs.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "probs sum {sum}");
        }
        for (i, &c) in out.conf.iter().enumerate() {
            assert!(c >= 1.0 / 3.0 - 1e-4 && c <= 1.0, "conf[{i}] = {c}");
            assert!(out.ent[i] >= 0.0 && out.ent[i] <= (3f32).ln() + 1e-4);
        }
    }
}

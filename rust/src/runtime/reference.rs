//! The pure-Rust reference backend: executes embed / transformer blocks /
//! exit heads directly from the host-side [`ModelWeights`], no compiled
//! artifacts and no external libraries.
//!
//! The math mirrors `python/compile/kernels/ref.py` operation for operation
//! (pre-LN attention and FFN with residuals, tanh-approximate GELU, stable
//! softmax, entropy in nats with the same `1e-12` floor), so outputs agree
//! with the AOT-compiled PJRT graphs to float tolerance — asserted by the
//! reference-vs-pjrt parity test in `tests/integration.rs`.  Fused-range
//! semantics are trivial here (`blocks(start..end)` is one "launch" however
//! many layers it covers), which keeps launch-count metrics comparable with
//! the PJRT partition path.
//!
//! # Kernel design: blocked, parallel, bit-exact
//!
//! The kernels are blocked and multi-threaded but **bit-identical to the
//! naive serial loops for every thread count, including 1**.  The rule that
//! makes that possible: *partition the output, never the reduction axis*.
//! Every output row (GEMM), (sample, head) pair (attention) and row chunk
//! (LayerNorm / GELU / residual add) is owned by exactly one task, and each
//! output element accumulates its reduction terms in the same ascending
//! serial order as the naive loop ([`matmul_bias_naive`] is kept as the
//! oracle the tile-boundary tests compare against).  No atomics, no
//! tree-reductions, no FMA contraction — chunking and thread count can then
//! never change a single bit.  `speculation_transparent`, the fused-range
//! bit-exactness suite and the golden fixtures all pin this.
//!
//! Fan-out runs on a **dedicated kernel pool** (`SPLITEE_REF_THREADS` /
//! `--ref-threads`, default = available parallelism), never on
//! [`crate::util::threadpool::global`]: the experiment and serving layers
//! already occupy the global pool's workers, and nesting `scope_map` across
//! two distinct pools is deadlock-free by construction (same-pool re-entry
//! runs inline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use super::{
    count_launch, ComputeBackend, HeadOut, Hidden, HiddenRepr, ModelExecutor, ModelSpec,
};
use crate::model::weights::ModelWeights;
use crate::tensor::{TensorF32, TensorI32};
use crate::util::threadpool::ThreadPool;

/// LayerNorm epsilon — matches `ref.py::layer_norm`.
const LN_EPS: f32 = 1e-5;
/// sqrt(2/pi) for the tanh-approximate GELU — matches `jax.nn.gelu`.
const GELU_C: f32 = 0.797_884_56;
/// Entropy log floor — matches `ref.py::exit_head_ref`.
const ENT_EPS: f32 = 1e-12;

/// GEMM k-tile: one tile of `w` rows (`GEMM_KC * m` floats) stays hot in
/// cache while it feeds every output row.
const GEMM_KC: usize = 128;
/// GEMM m-tile: output columns processed per pass, sized so a `w` tile row
/// plus four output row segments fit in L1.
const GEMM_NC: usize = 256;
/// GEMM register-blocked row count: the micro-kernel streams one `w` tile
/// row into this many output rows at once, quartering `w` traffic.
const GEMM_MR: usize = 4;
/// Fan-out floor: a task never owns fewer rows than this, so tiny inputs
/// skip the pool instead of paying per-job overhead.
const MIN_ROWS_PER_TASK: usize = 8;

// ---------------------------------------------------------------------------
// Dedicated kernel pool

/// Thread count requested via [`set_kernel_threads`] (0 = decide
/// automatically from the env hook / machine).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(0);
/// The process-wide kernel pool, created on first shared-pool model load.
static KERNEL_POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// Set the shared kernel pool's size — the `--ref-threads` hook.  Takes
/// effect on the first model load; once the pool exists its size is fixed
/// for the process, and a mismatched later request only logs a warning.
/// `0` means "decide automatically": the `SPLITEE_REF_THREADS` env hook if
/// set, else the machine's available parallelism.
pub fn set_kernel_threads(n: usize) {
    KERNEL_THREADS.store(n, Ordering::SeqCst);
    if let Some(pool) = KERNEL_POOL.get() {
        if n > 0 && pool.worker_count() != n {
            log::warn!(
                "reference kernel pool already running with {} threads — \
                 ref-threads={n} ignored for this process",
                pool.worker_count()
            );
        }
    }
}

/// Resolve the kernel-pool size: [`set_kernel_threads`] if set, else the
/// `SPLITEE_REF_THREADS` env hook (invalid values fail loudly, naming the
/// variable), else available parallelism.
fn configured_kernel_threads() -> usize {
    let set = KERNEL_THREADS.load(Ordering::SeqCst);
    if set > 0 {
        return set;
    }
    if let Ok(v) = std::env::var("SPLITEE_REF_THREADS") {
        return match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => panic!(
                "SPLITEE_REF_THREADS={v:?} is invalid — expected a positive \
                 integer kernel-pool thread count"
            ),
        };
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The dedicated compute pool shared-pool executors fan kernels onto.
///
/// Deliberately distinct from [`crate::util::threadpool::global`]: the
/// experiment/serving layers already run *on* the global pool's workers, and
/// kernel fan-out from those workers onto a second pool is the supported
/// nesting pattern — two pools never wait on each other's queues, and
/// same-pool re-entry runs inline in `scope_map` — so model math can never
/// deadlock against an outer `scope_map`.
fn kernel_pool() -> Arc<ThreadPool> {
    Arc::clone(KERNEL_POOL.get_or_init(|| Arc::new(ThreadPool::new(configured_kernel_threads()))))
}

// ---------------------------------------------------------------------------
// Kernels

/// Rows each task owns when fanning `rows` of per-row work over `pool`: an
/// even split across workers, floored at [`MIN_ROWS_PER_TASK`].  Returns
/// `rows` (i.e. "stay serial") for single-worker pools.
fn rows_per_task(pool: &ThreadPool, rows: usize) -> usize {
    if pool.worker_count() <= 1 {
        return rows.max(1);
    }
    rows.div_ceil(pool.worker_count()).max(MIN_ROWS_PER_TASK)
}

/// Apply `f` to contiguous row chunks of `buf` (row width `row_w`) in
/// parallel.  Each row is owned by exactly one task — output partitioning —
/// so any per-row math is bit-identical to the serial pass for every worker
/// count.  `f` receives the starting row index of its chunk.
fn par_rows<F>(pool: &ThreadPool, buf: &mut [f32], row_w: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Send + Sync,
{
    if buf.is_empty() {
        return;
    }
    debug_assert!(row_w > 0 && buf.len() % row_w == 0);
    let rows = buf.len() / row_w;
    let per = rows_per_task(pool, rows);
    if per >= rows {
        f(0, buf);
        return;
    }
    let tasks: Vec<(usize, &mut [f32])> = buf.chunks_mut(per * row_w).enumerate().collect();
    pool.scope_map(tasks, |(ci, chunk)| f(ci * per, chunk));
}

/// Zip-fan-out: split `a` into `a_chunk`-sized pieces and `b` into
/// `b_chunk`-sized pieces and hand piece `i` of each to `f(i, ..)` on the
/// pool.  Both slices must split into the same number of pieces; each piece
/// pair is owned by exactly one task.
fn par_zip_chunks<F>(
    pool: &ThreadPool,
    a: &mut [f32],
    a_chunk: usize,
    b: &mut [f32],
    b_chunk: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Send + Sync,
{
    if a.is_empty() || a_chunk == 0 || b_chunk == 0 {
        return;
    }
    debug_assert_eq!(a.len() / a_chunk, b.len() / b_chunk);
    let tasks: Vec<(usize, (&mut [f32], &mut [f32]))> =
        a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate().collect();
    if pool.worker_count() <= 1 || tasks.len() <= 1 {
        for (i, (ac, bc)) in tasks {
            f(i, ac, bc);
        }
        return;
    }
    pool.scope_map(tasks, |(i, (ac, bc))| f(i, ac, bc));
}

/// `x += y`, row-partitioned over the pool.  Each element is touched by
/// exactly one task and gets exactly one add — order-free, bit-exact.
fn add_rows(pool: &ThreadPool, x: &mut [f32], y: &[f32], row_w: usize) {
    debug_assert_eq!(x.len(), y.len());
    par_rows(pool, x, row_w, |r0, chunk| {
        let ys = &y[r0 * row_w..r0 * row_w + chunk.len()];
        for (xv, yv) in chunk.iter_mut().zip(ys) {
            *xv += yv;
        }
    });
}

/// The naive triple loop: `out[n, m] = x[n, k] @ w[k, m] + bias[m]`,
/// row-major, ascending-k accumulation.  This is the numerics **oracle**:
/// the blocked kernel and its parallel fan-out are required (and tested) to
/// be bit-identical to it for every shape and thread count.
pub fn matmul_bias_naive(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), k * m);
    debug_assert_eq!(bias.len(), m);
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let xi = &x[i * k..(i + 1) * k];
        let oi = &mut out[i * m..(i + 1) * m];
        oi.copy_from_slice(bias);
        for (kk, &xv) in xi.iter().enumerate() {
            let wrow = &w[kk * m..(kk + 1) * m];
            for j in 0..m {
                oi[j] += xv * wrow[j];
            }
        }
    }
    out
}

/// Serial blocked GEMM over a row block: `out[rows, m] = x[rows, k] @ w +
/// bias`.
///
/// Loop order is k-tile → m-tile → [`GEMM_MR`]-row micro-kernel.  The k
/// tiles are visited in ascending order and each `out[i][j]` accumulates its
/// k terms within a tile in ascending order too, so the per-element
/// accumulation sequence is exactly the naive loop's — bit-identical results
/// by construction; tiling only changes *which* elements are in flight, not
/// any element's own order of operations.  The inner loops run over zipped
/// equal-length subslices, so the hot path carries no bounds checks and
/// autovectorizes.
fn gemm_block(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    k: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), rows * m);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * m);
    debug_assert_eq!(bias.len(), m);
    if rows == 0 || m == 0 {
        return;
    }
    for orow in out.chunks_exact_mut(m) {
        orow.copy_from_slice(bias);
    }
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + GEMM_KC).min(k);
        let mut j0 = 0;
        while j0 < m {
            let j1 = (j0 + GEMM_NC).min(m);
            let mut r = 0;
            // micro-kernel: one pass over a w tile row feeds GEMM_MR output
            // rows, so each w element is loaded once per GEMM_MR rows
            while r + GEMM_MR <= rows {
                let block = &mut out[r * m..(r + GEMM_MR) * m];
                let (o0, rest) = block.split_at_mut(m);
                let (o1, rest) = rest.split_at_mut(m);
                let (o2, o3) = rest.split_at_mut(m);
                let (o0, o1, o2, o3) =
                    (&mut o0[j0..j1], &mut o1[j0..j1], &mut o2[j0..j1], &mut o3[j0..j1]);
                let xr = &x[r * k..(r + GEMM_MR) * k];
                for kk in k0..k1 {
                    let (x0, x1, x2, x3) = (xr[kk], xr[k + kk], xr[2 * k + kk], xr[3 * k + kk]);
                    let wrow = &w[kk * m + j0..kk * m + j1];
                    for ((((wj, a0), a1), a2), a3) in wrow
                        .iter()
                        .zip(o0.iter_mut())
                        .zip(o1.iter_mut())
                        .zip(o2.iter_mut())
                        .zip(o3.iter_mut())
                    {
                        *a0 += x0 * wj;
                        *a1 += x1 * wj;
                        *a2 += x2 * wj;
                        *a3 += x3 * wj;
                    }
                }
                r += GEMM_MR;
            }
            // remainder rows, one at a time
            while r < rows {
                let orow = &mut out[r * m + j0..r * m + j1];
                let xr = &x[r * k..(r + 1) * k];
                for kk in k0..k1 {
                    let xv = xr[kk];
                    let wrow = &w[kk * m + j0..kk * m + j1];
                    for (a, wj) in orow.iter_mut().zip(wrow) {
                        *a += xv * wj;
                    }
                }
                r += 1;
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// `out[n, m] = x[n, k] @ w[k, m] + bias[m]` via the blocked kernel on the
/// calling thread.  Bit-identical to [`matmul_bias_naive`] for every shape
/// (asserted by the tile-boundary tests).
pub fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    gemm_block(&mut out, x, w, bias, n, k, m);
    out
}

/// [`matmul_bias`] with the row loop fanned out over `pool`.  Output rows
/// are partitioned across tasks; the reduction (k) axis never is, so the
/// result is bit-identical to the serial kernel for every thread count.
pub fn matmul_bias_par(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    gemm_into(pool, &mut out, x, w, bias, n, k, m);
    out
}

/// Blocked GEMM into a caller-provided buffer, row-parallel over `pool`.
#[allow(clippy::too_many_arguments)]
fn gemm_into(
    pool: &ThreadPool,
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || m == 0 {
        return;
    }
    let per = rows_per_task(pool, n);
    if per >= n {
        gemm_block(out, x, w, bias, n, k, m);
        return;
    }
    let tasks: Vec<(usize, &mut [f32])> = out.chunks_mut(per * m).enumerate().collect();
    pool.scope_map(tasks, |(ci, chunk)| {
        let r0 = ci * per;
        let rows = chunk.len() / m;
        gemm_block(chunk, &x[r0 * k..(r0 + rows) * k], w, bias, rows, k, m);
    });
}

/// Reusable scratch for the block math: one allocation set serves every
/// layer of a `run_blocks` / `forward_all_exits` sweep instead of ~7 fresh
/// `Vec`s per block.  Stale contents never leak: every kernel writing into a
/// buffer initializes each element it covers (GEMM from the bias row,
/// attention from a zero fill, LayerNorm/copy from the input).
#[derive(Default)]
struct Workspace {
    /// LN output, then reused as the projection output of each sublayer.
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention output in head-major layout `[B][heads][T][dh]` — each
    /// (sample, head) task owns one contiguous `T * dh` chunk.
    o_heads: Vec<f32>,
    /// Attention output transposed back to row-major `[B*T, D]`.
    o: Vec<f32>,
    /// FFN hidden activations `[B*T, F]`.
    ffn: Vec<f32>,
    /// Per-(sample, head) score rows, `B * heads` chunks of length `T`.
    scores: Vec<f32>,
}

impl Workspace {
    fn ensure(&mut self, n: usize, d: usize, f: usize, b: usize, heads: usize, t: usize) {
        self.hn.resize(n * d, 0.0);
        self.q.resize(n * d, 0.0);
        self.k.resize(n * d, 0.0);
        self.v.resize(n * d, 0.0);
        self.o_heads.resize(n * d, 0.0);
        self.o.resize(n * d, 0.0);
        self.ffn.resize(n * f, 0.0);
        self.scores.resize(b * heads * t, 0.0);
    }
}

/// Host-tensor activation handle (the reference backend's [`HiddenRepr`]).
#[derive(Debug)]
struct HostHidden(TensorF32);

impl HiddenRepr for HostHidden {
    fn to_tensor(&self) -> Result<TensorF32> {
        Ok(self.0.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The always-available pure-Rust backend.
///
/// By default every loaded model shares the process-wide kernel pool (sized
/// by [`set_kernel_threads`] / `SPLITEE_REF_THREADS`);
/// [`ReferenceBackend::with_threads`] instead gives each loaded model a
/// private pool of exactly `n` workers — that is what lets one test process
/// compare several thread counts bit for bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReferenceBackend {
    threads: Option<usize>,
}

impl ReferenceBackend {
    /// Backend whose executors run kernels on a private `n`-thread pool
    /// (tests and benches; production paths use the shared pool).
    pub fn with_threads(n: usize) -> ReferenceBackend {
        ReferenceBackend { threads: Some(n.max(1)) }
    }
}

impl ComputeBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load_model(&self, spec: &ModelSpec<'_>) -> Result<Box<dyn ModelExecutor>> {
        let pool = match self.threads {
            Some(n) => Arc::new(ThreadPool::new(n)),
            None => kernel_pool(),
        };
        Ok(Box::new(ReferenceExecutor::new(spec, pool)?))
    }
}

/// One model bound to the reference math.
pub(crate) struct ReferenceExecutor {
    weights: Arc<ModelWeights>,
    pool: Arc<ThreadPool>,
    n_heads: usize,
    d_model: usize,
    n_layers: usize,
}

impl std::fmt::Debug for ReferenceExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReferenceExecutor")
            .field("layers", &self.n_layers)
            .field("d_model", &self.d_model)
            .field("heads", &self.n_heads)
            .field("kernel_threads", &self.pool.worker_count())
            .finish()
    }
}

impl ReferenceExecutor {
    fn new(spec: &ModelSpec<'_>, pool: Arc<ThreadPool>) -> Result<ReferenceExecutor> {
        let weights = Arc::clone(&spec.weights);
        let tok = &weights.embed[0];
        if tok.ndim() != 2 {
            bail!("embed.tok must be 2-D [vocab, d_model], got {:?}", tok.shape());
        }
        let d_model = tok.shape()[1];
        if spec.n_heads == 0 || d_model % spec.n_heads != 0 {
            bail!(
                "d_model {d_model} is not divisible by n_heads {} — \
                 reference attention needs equal head widths",
                spec.n_heads
            );
        }
        Ok(ReferenceExecutor {
            n_layers: weights.n_layers,
            weights,
            pool,
            n_heads: spec.n_heads,
            d_model,
        })
    }

    fn host_of<'a>(&self, h: &'a Hidden) -> Result<&'a TensorF32> {
        h.repr()
            .as_any()
            .downcast_ref::<HostHidden>()
            .map(|hh| &hh.0)
            .context("hidden state does not belong to the reference backend")
    }

    /// Validate a [B, T, D] activation and return (B, T).
    fn check_hidden(&self, h: &TensorF32) -> Result<(usize, usize)> {
        if h.ndim() != 3 || h.shape()[2] != self.d_model {
            bail!(
                "hidden state must be [B, T, {}], got {:?}",
                self.d_model,
                h.shape()
            );
        }
        Ok((h.shape()[0], h.shape()[1]))
    }

    /// Embedding math: tokens [B, T] -> h0 [B, T, D].
    fn embed_math(&self, tokens: &TensorI32) -> Result<TensorF32> {
        if tokens.ndim() != 2 {
            bail!("tokens must be [B, T], got shape {:?}", tokens.shape());
        }
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        let tok = &self.weights.embed[0];
        let pos = &self.weights.embed[1];
        let (ln_g, ln_b) = (&self.weights.embed[2], &self.weights.embed[3]);
        let vocab = tok.shape()[0];
        let d = self.d_model;
        if pos.ndim() != 2 || pos.shape()[1] != d {
            bail!("embed.pos must be [T, {d}], got {:?}", pos.shape());
        }
        if t > pos.shape()[0] {
            bail!(
                "sequence length {t} exceeds the positional table ({} rows)",
                pos.shape()[0]
            );
        }
        let mut h = vec![0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                let id = tokens.data()[bi * t + ti];
                if id < 0 || id as usize >= vocab {
                    bail!(
                        "token id {id} at [{bi}, {ti}] is outside the vocabulary \
                         (0..{vocab})"
                    );
                }
                let row = &mut h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                let tk = &tok.data()[id as usize * d..(id as usize + 1) * d];
                let ps = &pos.data()[ti * d..(ti + 1) * d];
                for j in 0..d {
                    row[j] = tk[j] + ps[j];
                }
            }
        }
        let (g, bb) = (ln_g.data(), ln_b.data());
        par_rows(&self.pool, &mut h, d, |_, rows| layer_norm_rows(rows, d, g, bb));
        TensorF32::new(vec![b, t, d], h).map_err(|e| anyhow::anyhow!(e))
    }

    /// One transformer block (pre-LN attention + pre-LN FFN, both residual),
    /// in place over the flat [B*T, D] activation, scratch from `ws`.
    fn block_math(&self, x: &mut [f32], b: usize, t: usize, layer: usize, ws: &mut Workspace) {
        // BLOCK_PARAM_ORDER: ln1_g ln1_b wq bq wk bk wv bv wo bo
        //                    ln2_g ln2_b w1 b1 w2 b2
        let p = &self.weights.blocks[layer];
        let d = self.d_model;
        let heads = self.n_heads;
        let dh = d / heads;
        let n = b * t;
        let f = p[12].shape()[1];
        let pool = &*self.pool;
        ws.ensure(n, d, f, b, heads, t);

        // ---- attention: x + (softmax(QK^T / sqrt(dh)) V) Wo + bo
        ws.hn.copy_from_slice(x);
        {
            let (g, bb) = (p[0].data(), p[1].data());
            par_rows(pool, &mut ws.hn, d, |_, rows| layer_norm_rows(rows, d, g, bb));
        }
        gemm_into(pool, &mut ws.q, &ws.hn, p[2].data(), p[3].data(), n, d, d);
        gemm_into(pool, &mut ws.k, &ws.hn, p[4].data(), p[5].data(), n, d, d);
        gemm_into(pool, &mut ws.v, &ws.hn, p[6].data(), p[7].data(), n, d, d);
        let scale = 1.0 / (dh as f32).sqrt();
        {
            // one task per (sample, head): task i owns o_heads chunk i
            // ([T, dh], head-major) and scores chunk i ([T]) exclusively
            let (q, kmat, v) = (&ws.q[..], &ws.k[..], &ws.v[..]);
            par_zip_chunks(pool, &mut ws.o_heads, t * dh, &mut ws.scores, t, |task, orow, scores| {
                let (bi, hi) = (task / heads, task % heads);
                let hoff = hi * dh;
                orow.fill(0.0);
                for ti in 0..t {
                    let qoff = (bi * t + ti) * d + hoff;
                    for (si, s) in scores.iter_mut().enumerate() {
                        let koff = (bi * t + si) * d + hoff;
                        let mut dot = 0f32;
                        for (qv, kv) in q[qoff..qoff + dh].iter().zip(&kmat[koff..koff + dh]) {
                            dot += qv * kv;
                        }
                        *s = dot * scale;
                    }
                    softmax_inplace(scores);
                    let ot = &mut orow[ti * dh..(ti + 1) * dh];
                    for (si, &wgt) in scores.iter().enumerate() {
                        let voff = (bi * t + si) * d + hoff;
                        for (ov, vv) in ot.iter_mut().zip(&v[voff..voff + dh]) {
                            *ov += wgt * vv;
                        }
                    }
                }
            });
        }
        {
            // deterministic transpose back to row-major [B*T, D]
            let o_heads = &ws.o_heads[..];
            par_rows(pool, &mut ws.o, d, |r0, chunk| {
                for (ri, orow) in chunk.chunks_exact_mut(d).enumerate() {
                    let row = r0 + ri;
                    let (bi, ti) = (row / t, row % t);
                    for hi in 0..heads {
                        let src = ((bi * heads + hi) * t + ti) * dh;
                        orow[hi * dh..(hi + 1) * dh].copy_from_slice(&o_heads[src..src + dh]);
                    }
                }
            });
        }
        gemm_into(pool, &mut ws.hn, &ws.o, p[8].data(), p[9].data(), n, d, d);
        add_rows(pool, x, &ws.hn, d);

        // ---- FFN: x + W2 gelu(W1 LN2(x) + b1) + b2
        ws.hn.copy_from_slice(x);
        {
            let (g, bb) = (p[10].data(), p[11].data());
            par_rows(pool, &mut ws.hn, d, |_, rows| layer_norm_rows(rows, d, g, bb));
        }
        gemm_into(pool, &mut ws.ffn, &ws.hn, p[12].data(), p[13].data(), n, d, f);
        par_rows(pool, &mut ws.ffn, f, |_, chunk| {
            for v in chunk.iter_mut() {
                *v = gelu_tanh(*v);
            }
        });
        gemm_into(pool, &mut ws.hn, &ws.ffn, p[14].data(), p[15].data(), n, f, d);
        add_rows(pool, x, &ws.hn, d);
    }

    /// Blocks `start..end` over a [B, T, D] tensor.
    fn run_blocks(&self, h: &TensorF32, start: usize, end: usize) -> Result<TensorF32> {
        let (b, t) = self.check_hidden(h)?;
        if start >= end || end > self.n_layers {
            bail!(
                "block range [{start}, {end}) out of bounds (L = {})",
                self.n_layers
            );
        }
        let mut x = h.data().to_vec();
        let mut ws = Workspace::default();
        for layer in start..end {
            self.block_math(&mut x, b, t, layer, &mut ws);
        }
        TensorF32::new(vec![b, t, self.d_model], x).map_err(|e| anyhow::anyhow!(e))
    }

    /// Exit head after `layer` over a flat [B, T, D] activation — borrowed,
    /// so `forward_all_exits` never clones the activation between layers.
    fn head_math(&self, h: &[f32], b: usize, t: usize, layer: usize) -> Result<HeadOut> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (L = {})", self.n_layers);
        }
        // HEAD_PARAM_ORDER: ln_g ln_b wc bc
        let p = &self.weights.heads[layer];
        let d = self.d_model;
        debug_assert_eq!(h.len(), b * t * d);
        let c = p[2].shape()[1];
        // [CLS] pooling: row 0 of every sample
        let mut cls = vec![0f32; b * d];
        for bi in 0..b {
            cls[bi * d..(bi + 1) * d].copy_from_slice(&h[bi * t * d..bi * t * d + d]);
        }
        layer_norm_rows(&mut cls, d, p[0].data(), p[1].data());
        let mut logits = matmul_bias(&cls, p[2].data(), p[3].data(), b, d, c);
        let mut conf = Vec::with_capacity(b);
        let mut ent = Vec::with_capacity(b);
        for row in logits.chunks_exact_mut(c) {
            softmax_inplace(row);
            let mut mx = row[0];
            let mut h_nats = 0f32;
            for &pv in row.iter() {
                if pv > mx {
                    mx = pv;
                }
                h_nats -= pv * (pv + ENT_EPS).ln();
            }
            conf.push(mx);
            ent.push(h_nats);
        }
        let probs = TensorF32::new(vec![b, c], logits).map_err(|e| anyhow::anyhow!(e))?;
        Ok(HeadOut { probs, conf, ent })
    }
}

impl ModelExecutor for ReferenceExecutor {
    fn backend_name(&self) -> &'static str {
        "reference"
    }

    fn embed(&self, tokens: &TensorI32) -> Result<Hidden> {
        let h = self.embed_math(tokens)?;
        count_launch();
        let b = h.shape()[0];
        Ok(Hidden::new(b, Box::new(HostHidden(h))))
    }

    fn blocks(&self, h: &Hidden, start: usize, end: usize) -> Result<Hidden> {
        let out = self.run_blocks(self.host_of(h)?, start, end)?;
        count_launch();
        Ok(Hidden::new(h.batch(), Box::new(HostHidden(out))))
    }

    fn blocks_host(&self, h: &TensorF32, start: usize, end: usize) -> Result<Hidden> {
        let out = self.run_blocks(h, start, end)?;
        count_launch();
        let b = out.shape()[0];
        Ok(Hidden::new(b, Box::new(HostHidden(out))))
    }

    fn exit_head(&self, h: &Hidden, layer: usize) -> Result<HeadOut> {
        let ht = self.host_of(h)?;
        let (b, t) = self.check_hidden(ht)?;
        let out = self.head_math(ht.data(), b, t, layer)?;
        count_launch();
        Ok(out)
    }

    fn exit_head_host(&self, h: &TensorF32, layer: usize) -> Result<HeadOut> {
        let (b, t) = self.check_hidden(h)?;
        let out = self.head_math(h.data(), b, t, layer)?;
        count_launch();
        Ok(out)
    }

    fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<HeadOut>> {
        let h0 = self.embed_math(tokens)?;
        // one "launch" for the whole sweep — the analogue of PJRT's fused
        // prefix_full module, keeping cross-backend launch units comparable
        count_launch();
        let (b, t) = (h0.shape()[0], h0.shape()[1]);
        let mut x = h0.into_data();
        let mut ws = Workspace::default();
        let mut out = Vec::with_capacity(self.n_layers);
        for layer in 0..self.n_layers {
            self.block_math(&mut x, b, t, layer, &mut ws);
            out.push(self.head_math(&x, b, t, layer)?);
        }
        Ok(out)
    }

    fn has_fused_ranges(&self) -> bool {
        // any blocks(start..end) call is one "launch" here, whatever its
        // length — the fused-partition invariant holds by construction
        true
    }

    fn speculation_transparent(&self) -> bool {
        // every operation here is row-independent (attention and softmax
        // reduce within a sample, never across the batch), so computing the
        // continuation over the full padded batch and reading out rows is
        // bit-identical to gathering first — the invariant
        // `reference_batched_execution_matches_single` pins.  The parallel
        // kernels preserve this: tasks partition output rows, never the
        // reduction axis, so thread count cannot change a bit either.
        // Speculative results are therefore safe to consume verbatim.
        true
    }
}

/// LayerNorm over the last axis, row by row (`ref.py::layer_norm`).
fn layer_norm_rows(x: &mut [f32], d: usize, g: &[f32], b: &[f32]) {
    debug_assert!(d > 0 && x.len() % d == 0 && g.len() == d && b.len() == d);
    for row in x.chunks_exact_mut(d) {
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            row[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
}

/// Numerically stable in-place softmax over one row.
fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Tanh-approximate GELU (`jax.nn.gelu(..., approximate=True)`).
fn gelu_tanh(v: f32) -> f32 {
    0.5 * v * (1.0 + (GELU_C * (v + 0.044715 * v * v * v)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill in [-0.5, 0.5) (LCG, no deps).
    fn fill(len: usize, seed: u32) -> Vec<f32> {
        let mut s = seed | 1;
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
                (s >> 8) as f32 / (1u32 << 24) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        layer_norm_rows(&mut x, 4, &g, &b);
        let mu: f32 = x.iter().sum::<f32>() / 4.0;
        let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6, "mean {mu}");
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
        // gain/bias applied after normalization
        let mut y = vec![1.0f32, 2.0, 3.0, 4.0];
        layer_norm_rows(&mut y, 4, &[2.0; 4], &[1.0; 4]);
        for (a, c) in x.iter().zip(&y) {
            assert!((a * 2.0 + 1.0 - c).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_bias_matches_hand_computation() {
        // [2,3] @ [3,2] + bias
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let bias = [10.0, 20.0];
        let out = matmul_bias(&x, &w, &bias, 2, 3, 2);
        assert_eq!(out, vec![1.0 + 3.0 + 10.0, 2.0 + 3.0 + 20.0, 4.0 + 6.0 + 10.0, 5.0 + 6.0 + 20.0]);
    }

    #[test]
    fn blocked_gemm_is_bit_identical_to_naive_at_tile_boundaries() {
        // rows around the GEMM_MR micro-kernel (incl. 0- and 1-row inputs),
        // k around the GEMM_KC tile, m around the GEMM_NC tile
        let ns = [0usize, 1, GEMM_MR - 1, GEMM_MR, GEMM_MR + 1, 2 * GEMM_MR + 3];
        let ks = [0usize, 1, 7, GEMM_KC - 1, GEMM_KC, GEMM_KC + 1];
        let ms = [1usize, 5, GEMM_NC - 1, GEMM_NC, GEMM_NC + 1];
        for (ci, &n) in ns.iter().enumerate() {
            for (cj, &k) in ks.iter().enumerate() {
                for (cl, &m) in ms.iter().enumerate() {
                    let seed = (ci * 100 + cj * 10 + cl) as u32 + 1;
                    let x = fill(n * k, seed);
                    let w = fill(k * m, seed.wrapping_mul(31));
                    let bias = fill(m, seed.wrapping_mul(131));
                    let blocked = matmul_bias(&x, &w, &bias, n, k, m);
                    let naive = matmul_bias_naive(&x, &w, &bias, n, k, m);
                    assert_eq!(blocked, naive, "shape n={n} k={k} m={m}");
                }
            }
        }
    }

    #[test]
    fn parallel_gemm_is_bit_identical_to_serial_for_every_thread_count() {
        let (n, k, m) = (37, 65, 43);
        let x = fill(n * k, 3);
        let w = fill(k * m, 5);
        let bias = fill(m, 7);
        let serial = matmul_bias(&x, &w, &bias, n, k, m);
        assert_eq!(serial, matmul_bias_naive(&x, &w, &bias, n, k, m));
        for threads in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(threads);
            let par = matmul_bias_par(&pool, &x, &w, &bias, n, k, m);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn softmax_is_stable_and_normalized() {
        let mut row = vec![1000.0f32, 1001.0, 1002.0];
        softmax_inplace(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(row[2] > row[1] && row[1] > row[0]);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gelu_matches_known_values() {
        assert_eq!(gelu_tanh(0.0), 0.0);
        // gelu(1) ≈ 0.841192 (tanh approximation)
        assert!((gelu_tanh(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu_tanh(-1.0) + 0.158808).abs() < 1e-4);
        // large inputs saturate to identity / zero
        assert!((gelu_tanh(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_tanh(-10.0).abs() < 1e-4);
    }

    #[test]
    fn executor_rejects_bad_ranges_and_tokens() {
        use crate::model::ModelWeights;
        let weights = Arc::new(ModelWeights::synthetic(2, 8, 16, 32, 4, 2, 7));
        let spec = ModelSpec {
            task: "t",
            style: "s",
            weights,
            n_heads: 2,
            seq_len: 4,
            batch_sizes: vec![1],
            cache_batch: 1,
            manifest: None,
        };
        let exec = ReferenceExecutor::new(&spec, Arc::new(ThreadPool::new(2))).unwrap();
        let tokens = TensorI32::new(vec![1, 4], vec![0, 1, 2, 3]).unwrap();
        let h = exec.embed(&tokens).unwrap();
        assert!(exec.blocks(&h, 1, 1).is_err(), "empty range");
        assert!(exec.blocks(&h, 0, 3).is_err(), "range past L");
        assert!(exec.exit_head(&h, 2).is_err(), "head past L");
        // out-of-vocabulary token ids are a clear error, not a panic
        let bad = TensorI32::new(vec![1, 4], vec![0, 1, 2, 64]).unwrap();
        let err = exec.embed(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("vocabulary"));
    }

    #[test]
    fn head_probs_are_a_distribution() {
        use crate::model::ModelWeights;
        let weights = Arc::new(ModelWeights::synthetic(2, 8, 16, 32, 4, 3, 11));
        let spec = ModelSpec {
            task: "t",
            style: "s",
            weights,
            n_heads: 2,
            seq_len: 4,
            batch_sizes: vec![1, 2],
            cache_batch: 2,
            manifest: None,
        };
        let exec = ReferenceExecutor::new(&spec, Arc::new(ThreadPool::new(3))).unwrap();
        let tokens = TensorI32::new(vec![2, 4], vec![5, 1, 9, 3, 0, 31, 7, 2]).unwrap();
        let h0 = exec.embed(&tokens).unwrap();
        let h1 = exec.blocks(&h0, 0, 2).unwrap();
        let out = exec.exit_head(&h1, 1).unwrap();
        assert_eq!(out.probs.shape(), &[2, 3]);
        for row in out.probs.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "probs sum {sum}");
        }
        for (i, &c) in out.conf.iter().enumerate() {
            assert!(c >= 1.0 / 3.0 - 1e-4 && c <= 1.0, "conf[{i}] = {c}");
            assert!(out.ent[i] >= 0.0 && out.ent[i] <= (3f32).ln() + 1e-4);
        }
    }
}

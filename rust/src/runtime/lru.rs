//! Bounded LRU map + cache observability counters.
//!
//! Backend-agnostic on purpose: the PJRT backend uses it as the compiled-
//! executable cache, and the unit tests below run on every build (no XLA
//! library, no artifacts).  Hit/miss/eviction accounting lives *inside* the
//! map so a backend holding it behind a mutex gets consistent counters for
//! free (see [`LruMap::stats`]).

use std::collections::HashMap;
use std::hash::Hash;

/// Cache observability snapshot (hit/miss/eviction counters plus residency).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// entries currently resident
    pub resident: usize,
}

/// Minimal LRU map: a `HashMap` plus a monotonically increasing access tick.
/// Eviction scans for the smallest tick — the cache holds tens of compiled
/// modules at most, so the O(n) scan is irrelevant next to a compile and
/// keeps this dependency-free.
///
/// Counter semantics: [`LruMap::get`] counts one hit or one miss per call;
/// [`LruMap::peek`] refreshes recency without touching the counters (for
/// double-check-after-lock patterns, so a lost compile race is not counted
/// twice); [`LruMap::insert`] counts one eviction whenever an entry is
/// displaced (including every insert into a zero-capacity map, which stores
/// nothing and hands the pair straight back).
pub struct LruMap<K, V> {
    capacity: usize,
    tick: u64,
    map: HashMap<K, (u64, V)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Hash + Eq + Clone, V> LruMap<K, V> {
    /// `capacity` 0 is legal and means "cache nothing" (every insert is an
    /// immediate eviction) — useful for disabling a cache in experiments.
    pub fn new(capacity: usize) -> LruMap<K, V> {
        LruMap {
            capacity,
            tick: 0,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Hit/miss/eviction counters plus current residency.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            resident: self.map.len(),
        }
    }

    /// Look up, mark as most recently used, and count a hit or a miss.
    /// Generic over borrowed key forms (like `HashMap::get`) so a per-launch
    /// hot path can probe with `&Path` without allocating a `PathBuf`.
    pub fn get<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        match self.lookup(key) {
            Some(v) => {
                self.hits += 1;
                Some(v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Like [`LruMap::get`] but without counter updates.  Callers that probe
    /// again after taking a build lock use this so one logical miss is not
    /// recorded twice (and a lost build race is not recorded as a hit).
    pub fn peek<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.lookup(key)
    }

    fn lookup<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some(entry) => {
                entry.0 = tick;
                Some(&entry.1)
            }
            None => None,
        }
    }

    /// Insert, evicting the least-recently-used entry when at capacity.
    /// Returns the evicted `(key, value)`, if any; with capacity 0 the
    /// incoming pair itself is returned (nothing is stored).
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        self.tick += 1;
        if self.capacity == 0 {
            self.evictions += 1;
            return Some((key, value));
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            let lru_key = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = lru_key {
                evicted = self.map.remove(&k).map(|(_, v)| (k, v));
                self.evictions += 1;
            }
        }
        self.map.insert(key, (self.tick, value));
        evicted
    }

    /// Resident keys ordered least- to most-recently used — the order a warm
    /// restart must re-insert them in to reproduce this map's eviction
    /// behaviour exactly (snapshot persistence exports this list).
    pub fn keys_by_recency(&self) -> Vec<K> {
        let mut entries: Vec<(u64, &K)> = self.map.iter().map(|(k, (t, _))| (*t, k)).collect();
        entries.sort_unstable_by_key(|(t, _)| *t);
        entries.into_iter().map(|(_, k)| k.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_insert_within_capacity() {
        let mut lru: LruMap<u32, &str> = LruMap::new(3);
        assert!(lru.is_empty());
        assert!(lru.insert(1, "a").is_none());
        assert!(lru.insert(2, "b").is_none());
        assert_eq!(lru.get(&1), Some(&"a"));
        assert_eq!(lru.get(&3), None);
        assert_eq!(lru.len(), 2);
        assert_eq!(lru.capacity(), 3);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut lru: LruMap<u32, &str> = LruMap::new(2);
        lru.insert(1, "a");
        lru.insert(2, "b");
        // touch 1 so 2 becomes the LRU entry
        assert_eq!(lru.get(&1), Some(&"a"));
        let evicted = lru.insert(3, "c");
        assert_eq!(evicted, Some((2, "b")));
        assert_eq!(lru.len(), 2);
        assert!(lru.get(&2).is_none());
        assert_eq!(lru.get(&1), Some(&"a"));
        assert_eq!(lru.get(&3), Some(&"c"));
    }

    #[test]
    fn eviction_order_follows_access_history_not_insertion_order() {
        let mut lru: LruMap<u32, u32> = LruMap::new(3);
        lru.insert(1, 10);
        lru.insert(2, 20);
        lru.insert(3, 30);
        // access order now 1 < 2 < 3; touch 1 and 2 so 3 becomes LRU
        lru.get(&1);
        lru.get(&2);
        assert_eq!(lru.insert(4, 40), Some((3, 30)));
        // access order 1 < 2 < 4; next eviction must be 1
        assert_eq!(lru.insert(5, 50), Some((1, 10)));
        assert_eq!(lru.len(), 3);
        assert!(lru.peek(&2).is_some() && lru.peek(&4).is_some() && lru.peek(&5).is_some());
    }

    #[test]
    fn reinsert_existing_key_does_not_evict() {
        let mut lru: LruMap<u32, &str> = LruMap::new(2);
        lru.insert(1, "a");
        lru.insert(2, "b");
        assert!(lru.insert(1, "a2").is_none());
        assert_eq!(lru.len(), 2);
        assert_eq!(lru.get(&1), Some(&"a2"));
        assert_eq!(lru.get(&2), Some(&"b"));
        assert_eq!(lru.stats().evictions, 0);
    }

    #[test]
    fn capacity_one_cycles() {
        let mut lru: LruMap<u32, u32> = LruMap::new(1);
        for i in 0..10 {
            let evicted = lru.insert(i, i * 10);
            if i > 0 {
                assert_eq!(evicted, Some((i - 1, (i - 1) * 10)));
            }
            assert_eq!(lru.len(), 1);
        }
        assert_eq!(lru.stats().evictions, 9);
    }

    #[test]
    fn capacity_zero_stores_nothing() {
        let mut lru: LruMap<u32, &str> = LruMap::new(0);
        assert_eq!(lru.insert(1, "a"), Some((1, "a")));
        assert!(lru.is_empty());
        assert_eq!(lru.get(&1), None);
        let s = lru.stats();
        assert_eq!((s.evictions, s.misses, s.resident), (1, 1, 0));
    }

    #[test]
    fn counter_accounting_hits_misses_evictions() {
        let mut lru: LruMap<u32, u32> = LruMap::new(2);
        assert_eq!(lru.stats(), CacheStats::default());
        lru.get(&1); // miss
        lru.insert(1, 10);
        lru.get(&1); // hit
        lru.get(&2); // miss
        lru.insert(2, 20);
        lru.insert(3, 30); // evicts 1 (2's insert is more recent than 1's get)
        let s = lru.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident, 2);
    }

    #[test]
    fn keys_by_recency_orders_lru_to_mru() {
        let mut lru: LruMap<u32, u32> = LruMap::new(3);
        lru.insert(1, 10);
        lru.insert(2, 20);
        lru.insert(3, 30);
        lru.get(&1); // order now 2 < 3 < 1
        assert_eq!(lru.keys_by_recency(), vec![2, 3, 1]);
        assert!(LruMap::<u32, u32>::new(4).keys_by_recency().is_empty());
    }

    #[test]
    fn reinserting_in_recency_order_preserves_eviction_behaviour() {
        // The warm-restart contract: replaying keys_by_recency() into a fresh
        // map yields the same eviction sequence as the original map.
        let mut orig: LruMap<u32, u32> = LruMap::new(3);
        for k in [5, 9, 2, 7] {
            orig.insert(k, k * 10);
        }
        orig.get(&9);
        let order = orig.keys_by_recency();
        let mut rebuilt: LruMap<u32, u32> = LruMap::new(3);
        for &k in &order {
            rebuilt.insert(k, k * 10);
        }
        assert_eq!(rebuilt.keys_by_recency(), order);
        // subject both to the same inserts; evictions must match key-for-key
        for k in [11, 13, 17] {
            let a = orig.insert(k, k * 10).map(|(key, _)| key);
            let b = rebuilt.insert(k, k * 10).map(|(key, _)| key);
            assert_eq!(a, b, "insert {k}");
        }
    }

    #[test]
    fn peek_refreshes_recency_without_counting() {
        let mut lru: LruMap<u32, u32> = LruMap::new(2);
        lru.insert(1, 10);
        lru.insert(2, 20);
        let before = lru.stats();
        assert_eq!(lru.peek(&1), Some(&10)); // refresh 1, no counters
        assert_eq!(lru.peek(&9), None);
        assert_eq!(lru.stats().hits, before.hits);
        assert_eq!(lru.stats().misses, before.misses);
        // 2 is now the LRU entry thanks to the peek-refresh of 1
        assert_eq!(lru.insert(3, 30), Some((2, 20)));
    }
}

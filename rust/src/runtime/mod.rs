//! Pluggable compute backends: the runtime seam between the model layer and
//! whatever actually executes it.
//!
//! # The backend trait
//!
//! A [`ComputeBackend`] turns a [`ModelSpec`] (weights + geometry, plus the
//! artifact manifest when one exists) into a [`ModelExecutor`]: the object
//! that runs `embed`, fused `blocks[i..j)` ranges, exit heads and the
//! all-exits cache graph.  Between executor calls the activation travels as
//! an opaque [`Hidden`] handle owned by the backend — device-resident for
//! PJRT, a host tensor for the reference backend — and crosses to the host
//! only through [`Hidden::to_tensor`] (the split-boundary uplink payload and
//! final outputs).  Launch accounting ([`thread_launches`]) and executable-
//! cache observability ([`CacheStats`]) sit behind the same seam, so
//! `ServingMetrics` and the coordinator's coalescing logic are
//! backend-agnostic.  The [`spec`] module adds the speculative side of the
//! seam: cancellable/deferred continuation launches ([`SpecLane`] /
//! [`SpecHandle`]) that run through any executor from a dedicated worker
//! thread, with [`ModelExecutor::speculation_transparent`] deciding whether
//! their results may replace the serial-path launch bit for bit.
//!
//! # Feature matrix
//!
//! | backend     | cargo feature    | needs                                  |
//! |-------------|------------------|----------------------------------------|
//! | `reference` | always compiled  | nothing — pure Rust on host tensors    |
//! | `pjrt`      | `--features pjrt`| `xla` crate + XLA/PJRT extension lib,  |
//! |             |                  | AOT HLO artifacts (`make artifacts`)   |
//!
//! Selection is runtime-configurable: `--backend auto|reference|pjrt`
//! (see [`Backend::from_name`]; `auto` prefers PJRT when this build has it
//! and the client initializes, else falls back to `reference`).
//!
//! # Which tests run where
//!
//! * default features, no artifacts (every machine, every CI job): all unit
//!   tests, plus the full coordinator integration suite — pipeline ordering,
//!   coalescing, bandit-decision equivalence, failure injection — on a
//!   synthetic reference-backend model, plus the reference fused-vs-per-block
//!   bit-exactness property test.
//! * artifacts present, default features: the same, plus golden-fixture and
//!   layered-vs-prefix checks through the reference backend.
//! * artifacts + `--features pjrt`: everything above through PJRT, plus the
//!   chain-graph bit-exactness, executable-cache LRU and reference-vs-pjrt
//!   parity tests.
//!
//! The PJRT pattern follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` -> `execute`.

pub mod lru;
pub mod reference;
pub mod spec;

#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use lru::{CacheStats, LruMap};
pub use reference::ReferenceBackend;
pub use spec::{SpecCounters, SpecHandle, SpecLane, SpecResult, SpecSnapshot};

#[cfg(feature = "pjrt")]
pub use executable::{Arg, Client, Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use std::cell::Cell;
use std::sync::Arc;

use anyhow::Result;

use crate::config::Manifest;
use crate::model::weights::ModelWeights;
use crate::tensor::{TensorF32, TensorI32};

thread_local! {
    static THREAD_LAUNCHES: Cell<u64> = Cell::new(0);
}

/// Executable launches performed by the *calling thread* since it started.
/// Pipeline stages run on dedicated threads, so a before/after delta
/// attributes launches to one stage even while other stages are executing
/// concurrently on their own threads.  On the serving path both backends
/// count in the same units — one per graph execution (embed, one fused
/// block range, one exit head) — so launch-based `ServingMetrics` are
/// comparable across backends.  (`forward_all_exits` counts one launch per
/// all-exits sweep on the reference backend vs one per `prefix_full` chunk
/// under PJRT; it is the off-path cache builder, not a serving metric.)
pub fn thread_launches() -> u64 {
    THREAD_LAUNCHES.with(|c| c.get())
}

/// Record one executable launch on this thread (called by backends only).
pub(crate) fn count_launch() {
    THREAD_LAUNCHES.with(|c| c.set(c.get() + 1));
}

/// Backend-owned representation of an in-flight activation.
pub trait HiddenRepr: std::fmt::Debug {
    /// Host transfer: materialize as a `TensorF32` (the split-boundary copy).
    fn to_tensor(&self) -> Result<TensorF32>;
    /// Downcast hook for the owning backend.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// A hidden state held in backend-native form between partition launches.
///
/// The handle is handed straight back as the next launch's argument, so the
/// activation only crosses the host boundary where the system semantics
/// require it — at the split point (the simulated uplink payload) and at
/// final outputs.  For PJRT the repr is a raw XLA literal; for the reference
/// backend it is already a host tensor.
pub struct Hidden {
    batch: usize,
    repr: Box<dyn HiddenRepr>,
}

impl Hidden {
    pub fn new(batch: usize, repr: Box<dyn HiddenRepr>) -> Hidden {
        Hidden { batch, repr }
    }

    /// Batch dimension (a compiled batch size under PJRT).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Host transfer: backend repr -> `TensorF32` (the split-boundary copy).
    pub fn to_tensor(&self) -> Result<TensorF32> {
        self.repr.to_tensor()
    }

    /// The backend-owned representation (backends downcast via `as_any`).
    pub fn repr(&self) -> &dyn HiddenRepr {
        self.repr.as_ref()
    }
}

impl std::fmt::Debug for Hidden {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Hidden")
            .field("batch", &self.batch)
            .field("repr", &self.repr)
            .finish()
    }
}

/// Raw output of one exit head over a batch: class probabilities plus the
/// per-sample confidence / entropy the policies consume.  The model layer
/// derives predictions (argmax) and wraps this into its `ExitOutput`.
#[derive(Debug, Clone)]
pub struct HeadOut {
    /// class probabilities [B, C]
    pub probs: TensorF32,
    /// max-probability confidence per sample (the paper's C_i)
    pub conf: Vec<f32>,
    /// prediction entropy per sample in nats (DeeBERT's measure)
    pub ent: Vec<f32>,
}

/// Everything a backend needs to instantiate one trained model.
///
/// `manifest` carries the AOT artifact inventory; it is `None` for models
/// built directly from weights (synthetic tests/benches), which only the
/// artifact-free backends accept.
pub struct ModelSpec<'a> {
    pub task: &'a str,
    pub style: &'a str,
    pub weights: Arc<ModelWeights>,
    pub n_heads: usize,
    pub seq_len: usize,
    /// batch sizes the serving batcher may form (compiled sizes under PJRT)
    pub batch_sizes: Vec<usize>,
    /// batch size of the all-exits cache-builder graph
    pub cache_batch: usize,
    pub manifest: Option<&'a Manifest>,
}

/// One loaded model, executable partition by partition.
///
/// Contract: `start < end <= n_layers` and `layer < n_layers` are validated
/// by the model layer before calls reach an executor; executors may assume
/// in-range arguments but must never cause undefined behaviour on bad ones.
pub trait ModelExecutor: Send + Sync + std::fmt::Debug {
    fn backend_name(&self) -> &'static str;

    /// tokens [B, T] -> h0 [B, T, D] in backend-native form.
    fn embed(&self, tokens: &TensorI32) -> Result<Hidden>;

    /// Blocks `start..end` (0-based, end exclusive) from a backend-native
    /// hidden state — one fused launch where the backend supports it.
    fn blocks(&self, h: &Hidden, start: usize, end: usize) -> Result<Hidden>;

    /// Blocks `start..end` from a host hidden state (the offload
    /// continuation entry point).
    fn blocks_host(&self, h: &TensorF32, start: usize, end: usize) -> Result<Hidden>;

    /// Exit head after `layer` (0-based) on a backend-native hidden state.
    fn exit_head(&self, h: &Hidden, layer: usize) -> Result<HeadOut>;

    /// Exit head after `layer` on a host hidden state.
    fn exit_head_host(&self, h: &TensorF32, layer: usize) -> Result<HeadOut>;

    /// Full forward through every exit at once (the cache-builder path).
    /// tokens [B, T] with any B — batching/padding is the executor's
    /// business.  Outer index of the result = layer.
    fn forward_all_exits(&self, tokens: &TensorI32) -> Result<Vec<HeadOut>>;

    /// Ensure whatever executes blocks `start..end` at `batch` is ready
    /// (compiled), so first-use compilation never lands in a timed region.
    /// No-op for backends without a compile step.
    fn warm_range(&self, _batch: usize, _start: usize, _end: usize) -> Result<()> {
        Ok(())
    }

    /// True when every multi-block range runs as one fused launch.
    fn has_fused_ranges(&self) -> bool;

    /// True when a speculative *full-batch* continuation is decision-
    /// transparent: running blocks `[split..L)` + the final head over the
    /// whole padded batch and then reading the offloaded rows out of the
    /// result is **bit-identical** to gathering those rows first and running
    /// the continuation on the gathered chunk (the serial path).  Row-
    /// independent host math qualifies; backends that execute per-batch-size
    /// compiled graphs do not (a gathered chunk may run a different
    /// executable than the full batch, so equality only holds to float
    /// tolerance).  The coordinator consumes speculative results only when
    /// this returns true — that is what keeps bandit decisions exactly the
    /// serial-path decisions with speculation enabled.
    fn speculation_transparent(&self) -> bool {
        false
    }

    /// Executable-cache observability (all zeros for cache-less backends).
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    /// Opaque identifiers of the currently-warm compiled units, ordered
    /// least- to most-recently used (snapshot persistence exports this so a
    /// restart can re-warm the same working set).  Empty for backends
    /// without a compile cache.
    fn warm_keys(&self) -> Vec<String> {
        Vec::new()
    }

    /// Re-warm the units named by a previous [`ModelExecutor::warm_keys`]
    /// call, in the given order.  Keys that no longer resolve (stale
    /// artifacts) are skipped, not errors — warmup is an optimisation, never
    /// a correctness dependency.  No-op for cache-less backends.
    fn rewarm(&self, _keys: &[String]) -> Result<()> {
        Ok(())
    }
}

/// A compute backend: a factory for [`ModelExecutor`]s.
pub trait ComputeBackend: Send + Sync + std::fmt::Debug {
    fn name(&self) -> &'static str;
    fn load_model(&self, spec: &ModelSpec<'_>) -> Result<Box<dyn ModelExecutor>>;
}

/// Cheaply-cloneable handle to a selected compute backend.
#[derive(Clone, Debug)]
pub struct Backend {
    inner: Arc<dyn ComputeBackend>,
}

impl Backend {
    /// The pure-Rust reference backend (always available).  Models loaded
    /// through it fan kernels onto the shared process-wide kernel pool
    /// (`--ref-threads` / `SPLITEE_REF_THREADS`, default = available
    /// parallelism).
    pub fn reference() -> Backend {
        Backend { inner: Arc::new(ReferenceBackend::default()) }
    }

    /// The reference backend with a **private** kernel pool of exactly `n`
    /// threads per loaded model.  Numerics are bit-identical for every `n`
    /// (the kernels partition outputs, never reductions) — this exists so
    /// tests and benches can compare thread counts inside one process.
    pub fn reference_threads(n: usize) -> Backend {
        Backend { inner: Arc::new(ReferenceBackend::with_threads(n)) }
    }

    /// The PJRT backend over a fresh CPU client (only in `pjrt` builds).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Backend> {
        Ok(Backend { inner: Arc::new(PjrtBackend::cpu()?) })
    }

    /// Prefer PJRT when this build has it and the client initializes;
    /// otherwise the reference backend.
    pub fn auto() -> Backend {
        auto_impl()
    }

    /// Runtime selection by name: `auto`, `reference` or `pjrt`.
    pub fn from_name(name: &str) -> Result<Backend> {
        match name {
            "auto" => Ok(Backend::auto()),
            "reference" => Ok(Backend::reference()),
            "pjrt" => pjrt_by_name(),
            other => anyhow::bail!(
                "unknown backend {other:?} — expected auto, reference or pjrt"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        self.inner.name()
    }

    pub fn load_model(&self, spec: &ModelSpec<'_>) -> Result<Box<dyn ModelExecutor>> {
        self.inner.load_model(spec)
    }
}

#[cfg(feature = "pjrt")]
fn auto_impl() -> Backend {
    match Backend::pjrt() {
        Ok(b) => b,
        Err(e) => {
            log::warn!(
                "pjrt backend unavailable ({e:#}) — falling back to the reference backend"
            );
            Backend::reference()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn auto_impl() -> Backend {
    Backend::reference()
}

#[cfg(feature = "pjrt")]
fn pjrt_by_name() -> Result<Backend> {
    Backend::pjrt()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_by_name() -> Result<Backend> {
    anyhow::bail!(
        "this build has no pjrt backend — rebuild with `cargo build --features pjrt` \
         (needs the XLA/PJRT extension library), or use `--backend reference`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_launch_counter_is_per_thread() {
        let before = thread_launches();
        count_launch();
        assert_eq!(thread_launches(), before + 1);
        let other = std::thread::spawn(thread_launches).join().unwrap();
        assert_eq!(other, 0, "fresh thread starts at zero");
    }

    #[test]
    fn backend_selection_by_name() {
        assert_eq!(Backend::reference().name(), "reference");
        assert_eq!(Backend::from_name("reference").unwrap().name(), "reference");
        assert!(Backend::from_name("tpu-pod").is_err());
        // `auto` always resolves to something usable
        let auto = Backend::from_name("auto").unwrap();
        assert!(auto.name() == "reference" || auto.name() == "pjrt");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_by_name_is_a_clear_error_without_the_feature() {
        let err = Backend::from_name("pjrt").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--features pjrt"), "unhelpful error: {msg}");
    }
}

//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! This is the only module that touches the `xla` crate.  Everything above it
//! (model, coordinator, experiments) works with host [`TensorF32`]/
//! [`TensorI32`] values.  Pattern follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` -> `execute`.
//!
//! [`TensorF32`]: crate::tensor::TensorF32
//! [`TensorI32`]: crate::tensor::TensorI32

pub mod executable;
pub mod literal;

pub use executable::{thread_launches, CacheStats, Executable, LruMap, Runtime};

use std::sync::Arc;

use anyhow::Result;

/// Shared PJRT CPU client.  Creating a client is expensive (plugin init), so
/// one is shared per process.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    /// Create the process-wide CPU client.
    pub fn cpu() -> Result<Client> {
        Ok(Client { inner: Arc::new(xla::PjRtClient::cpu()?) })
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    pub(crate) fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }
}

impl std::fmt::Debug for Client {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Client")
            .field("platform", &self.platform_name())
            .field("devices", &self.device_count())
            .finish()
    }
}

//! Compiled-executable cache and typed execution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::literal::{literal_f32, literal_i32, tensor_f32};
use super::Client;
use crate::tensor::{TensorF32, TensorI32};

/// A positional argument to an executable.
///
/// `Lit` passes a pre-converted literal by reference — the weight-literal
/// cache in [`crate::model::MultiExitModel`] uses it to avoid re-converting
/// every weight tensor on every layer execution (the L3 perf pass measured
/// this at ~2x on the per-block hot path; see EXPERIMENTS.md §Perf).
#[derive(Clone)]
pub enum Arg<'a> {
    F32(&'a TensorF32),
    I32(&'a TensorI32),
    Lit(&'a xla::Literal),
}

impl std::fmt::Debug for Arg<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arg::F32(t) => write!(f, "Arg::F32{:?}", t.shape()),
            Arg::I32(t) => write!(f, "Arg::I32{:?}", t.shape()),
            Arg::Lit(_) => write!(f, "Arg::Lit"),
        }
    }
}

/// One compiled HLO module, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("name", &self.name).finish()
    }
}

// The PJRT CPU executable is internally synchronized; the wrapper is used
// behind `Arc` from the serving threads.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with positional args; returns the flattened output tuple.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so the raw
    /// output is a single tuple literal; this decomposes it.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        // Convert tensor args once; borrow pre-converted literals directly.
        let mut owned: Vec<xla::Literal> = Vec::new();
        let mut slots: Vec<Option<&xla::Literal>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(t) => {
                    owned.push(literal_f32(t).with_context(|| {
                        format!("building f32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::I32(t) => {
                    owned.push(literal_i32(t).with_context(|| {
                        format!("building i32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::Lit(l) => slots.push(Some(l)),
            }
        }
        let mut owned_it = owned.iter();
        let literals: Vec<&xla::Literal> = slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| owned_it.next().expect("owned literal")))
            .collect();
        let result = self
            .exe
            .execute::<&xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        tuple
            .decompose_tuple()
            .with_context(|| format!("decomposing result of {}", self.name))
    }

    /// Execute and convert every output to an f32 tensor.
    pub fn run_f32(&self, args: &[Arg<'_>]) -> Result<Vec<TensorF32>> {
        self.run(args)?.iter().map(tensor_f32).collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Loads HLO-text artifacts, compiles them once, and caches the result.
pub struct Runtime {
    client: Client,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn new(client: Client) -> Runtime {
        Runtime { client, cache: Mutex::new(HashMap::new()) }
    }

    /// Create with a fresh CPU client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime::new(Client::cpu()?))
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(path) {
            return Ok(hit.clone());
        }
        if !path.exists() {
            bail!("HLO artifact {path:?} not found — run `make artifacts`");
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .raw()
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        log::debug!(
            "compiled {name} in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        let arc = std::sync::Arc::new(Executable { exe, name });
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), arc.clone());
        Ok(arc)
    }

    /// Number of compiled modules held in the cache.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("client", &self.client)
            .field("cached", &self.cached_count())
            .finish()
    }
}

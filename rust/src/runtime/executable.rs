//! Compiled-executable cache and typed execution.
//!
//! The cache is a **bounded LRU**: the partition-graph subsystem loads fused
//! block-range executables lazily per `(range length, batch)` key, so the
//! resident set is the serving working set, not every module ever compiled.
//! Hit/miss/eviction counters are exposed via [`Runtime::cache_stats`] and a
//! thread-local launch counter ([`thread_launches`]) lets each pipeline
//! stage attribute executable launches to itself without cross-thread races.

use std::cell::Cell;
use std::collections::HashMap;
use std::hash::Hash;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::literal::{literal_f32, literal_i32, tensor_f32};
use super::Client;
use crate::tensor::{TensorF32, TensorI32};

thread_local! {
    static THREAD_LAUNCHES: Cell<u64> = Cell::new(0);
}

/// Executable launches performed by the *calling thread* since it started.
/// Pipeline stages run on dedicated threads, so a before/after delta
/// attributes launches to one stage even while other stages are executing
/// concurrently on their own threads.
pub fn thread_launches() -> u64 {
    THREAD_LAUNCHES.with(|c| c.get())
}

/// A positional argument to an executable.
///
/// `Lit` passes a pre-converted literal by reference — the weight-literal
/// cache in [`crate::model::MultiExitModel`] uses it to avoid re-converting
/// every weight tensor on every layer execution (the L3 perf pass measured
/// this at ~2x on the per-block hot path; see EXPERIMENTS.md §Perf), and the
/// partition hot path uses it to hand one launch's output straight to the
/// next launch without a host `TensorF32` round trip.
#[derive(Clone)]
pub enum Arg<'a> {
    F32(&'a TensorF32),
    I32(&'a TensorI32),
    Lit(&'a xla::Literal),
}

impl std::fmt::Debug for Arg<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arg::F32(t) => write!(f, "Arg::F32{:?}", t.shape()),
            Arg::I32(t) => write!(f, "Arg::I32{:?}", t.shape()),
            Arg::Lit(_) => write!(f, "Arg::Lit"),
        }
    }
}

/// One compiled HLO module, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("name", &self.name).finish()
    }
}

// The PJRT CPU executable is internally synchronized; the wrapper is used
// behind `Arc` from the serving threads.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with positional args; returns the flattened output tuple.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so the raw
    /// output is a single tuple literal; this decomposes it.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        // Convert tensor args once; borrow pre-converted literals directly.
        let mut owned: Vec<xla::Literal> = Vec::new();
        let mut slots: Vec<Option<&xla::Literal>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(t) => {
                    owned.push(literal_f32(t).with_context(|| {
                        format!("building f32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::I32(t) => {
                    owned.push(literal_i32(t).with_context(|| {
                        format!("building i32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::Lit(l) => slots.push(Some(l)),
            }
        }
        let mut owned_it = owned.iter();
        let literals: Vec<&xla::Literal> = slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| owned_it.next().expect("owned literal")))
            .collect();
        let result = self
            .exe
            .execute::<&xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        THREAD_LAUNCHES.with(|c| c.set(c.get() + 1));
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        tuple
            .decompose_tuple()
            .with_context(|| format!("decomposing result of {}", self.name))
    }

    /// Execute and convert every output to an f32 tensor.
    pub fn run_f32(&self, args: &[Arg<'_>]) -> Result<Vec<TensorF32>> {
        self.run(args)?.iter().map(tensor_f32).collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Minimal LRU map: a `HashMap` plus a monotonically increasing access tick.
/// Eviction scans for the smallest tick — the cache holds tens of compiled
/// modules at most, so the O(n) scan is irrelevant next to a compile and
/// keeps this dependency-free.
pub struct LruMap<K, V> {
    capacity: usize,
    tick: u64,
    map: HashMap<K, (u64, V)>,
}

impl<K: Hash + Eq + Clone, V> LruMap<K, V> {
    pub fn new(capacity: usize) -> LruMap<K, V> {
        assert!(capacity > 0, "LRU capacity must be positive");
        LruMap { capacity, tick: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up and mark as most recently used.  Generic over borrowed key
    /// forms (like `HashMap::get`) so the per-launch hot path can probe
    /// with `&Path` without allocating a `PathBuf`.
    pub fn get<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some(entry) => {
                entry.0 = tick;
                Some(&entry.1)
            }
            None => None,
        }
    }

    /// Insert, evicting the least-recently-used entry when at capacity.
    /// Returns the evicted `(key, value)`, if any.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        self.tick += 1;
        let mut evicted = None;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            let lru_key = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = lru_key {
                evicted = self.map.remove(&k).map(|(_, v)| (k, v));
            }
        }
        self.map.insert(key, (self.tick, value));
        evicted
    }
}

/// Cache observability snapshot (see [`Runtime::cache_stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// compiled modules currently resident
    pub resident: usize,
}

struct RuntimeInner {
    client: Client,
    cache: Mutex<LruMap<PathBuf, Arc<Executable>>>,
    /// serializes compilation (the thread-affine PJRT wrapper wants one
    /// compiling thread at a time) without blocking cache-hit probes, which
    /// only ever take the short `cache` lock
    compile_lock: Mutex<()>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// Loads HLO-text artifacts, compiles them once, and caches the result in a
/// bounded LRU.  Cheaply cloneable: clones share one client and one cache,
/// which is what lets [`crate::model::MultiExitModel`] keep a handle for
/// lazy per-range compilation.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

/// Default executable-cache capacity.  Sized to hold the full serving
/// working set (embed/block/head per batch size, `prefix_full`, and every
/// `chain{n}` range graph) with headroom; override with
/// `SPLITEE_EXEC_CACHE_CAP` for eviction experiments.
///
/// Note that eviction only reclaims modules nothing else holds: the model
/// pins its embed/block/head executables via `Arc` for its lifetime, so
/// only the lazily-loaded `chain{n}` range graphs are really reclaimable —
/// and an undersized cap makes every fused launch recompile its chain
/// module.  The env override is therefore floored at
/// [`MIN_CACHE_CAPACITY`] to keep a misconfigured cap from silently
/// turning the hot path into compile thrash.
const DEFAULT_CACHE_CAPACITY: usize = 64;

/// Floor for the `SPLITEE_EXEC_CACHE_CAP` override (tests exercising
/// eviction use [`Runtime::with_capacity`] directly, which is unfloored).
const MIN_CACHE_CAPACITY: usize = 8;

fn default_cache_capacity() -> usize {
    let Ok(raw) = std::env::var("SPLITEE_EXEC_CACHE_CAP") else {
        return DEFAULT_CACHE_CAPACITY;
    };
    let Ok(requested) = raw.parse::<usize>() else {
        log::warn!(
            "SPLITEE_EXEC_CACHE_CAP={raw:?} is not a number — \
             using the default capacity {DEFAULT_CACHE_CAPACITY}"
        );
        return DEFAULT_CACHE_CAPACITY;
    };
    if requested < MIN_CACHE_CAPACITY {
        log::warn!(
            "SPLITEE_EXEC_CACHE_CAP={requested} floored to {MIN_CACHE_CAPACITY} \
             (use Runtime::with_capacity for smaller experimental bounds)"
        );
    }
    requested.max(MIN_CACHE_CAPACITY)
}

impl Runtime {
    pub fn new(client: Client) -> Runtime {
        Runtime::with_capacity(client, default_cache_capacity())
    }

    /// Create with an explicit cache bound (tests use tiny capacities to
    /// exercise eviction).
    pub fn with_capacity(client: Client, capacity: usize) -> Runtime {
        Runtime {
            inner: Arc::new(RuntimeInner {
                client,
                cache: Mutex::new(LruMap::new(capacity)),
                compile_lock: Mutex::new(()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                evictions: AtomicU64::new(0),
            }),
        }
    }

    /// Create with a fresh CPU client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime::new(Client::cpu()?))
    }

    pub fn client(&self) -> &Client {
        &self.inner.client
    }

    /// Load + compile an HLO text file (LRU-cached by path).
    ///
    /// Compilation happens *outside* the cache lock — a hundreds-of-ms
    /// compile on one pipeline stage's thread must never stall the other
    /// stage's per-launch hit probe.  The dedicated compile lock still
    /// keeps client-side compilation single-threaded (the thread-affine
    /// PJRT wrapper wants that), with a double-check after acquiring it so
    /// racing threads compile each module once.
    pub fn load(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(hit) = self.inner.cache.lock().unwrap().get(path) {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit.clone());
        }
        let _compiling = self.inner.compile_lock.lock().unwrap();
        // another thread may have compiled this module while we waited
        if let Some(hit) = self.inner.cache.lock().unwrap().get(path) {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit.clone());
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        if !path.exists() {
            bail!("HLO artifact {path:?} not found — run `make artifacts`");
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .raw()
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        log::debug!(
            "compiled {name} in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        let arc = Arc::new(Executable { exe, name });
        if let Some((evicted, _)) =
            self.inner.cache.lock().unwrap().insert(path.to_path_buf(), arc.clone())
        {
            self.inner.evictions.fetch_add(1, Ordering::Relaxed);
            log::debug!("evicted {evicted:?} from the executable cache");
        }
        Ok(arc)
    }

    /// Number of compiled modules held in the cache.
    pub fn cached_count(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// Hit/miss/eviction counters since this runtime was created.
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            evictions: self.inner.evictions.load(Ordering::Relaxed),
            resident: self.cached_count(),
        }
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("client", &self.inner.client)
            .field("cached", &self.cached_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure LRU behaviour — no PJRT client needed.

    #[test]
    fn lru_get_and_insert_within_capacity() {
        let mut lru: LruMap<u32, &str> = LruMap::new(3);
        assert!(lru.is_empty());
        assert!(lru.insert(1, "a").is_none());
        assert!(lru.insert(2, "b").is_none());
        assert_eq!(lru.get(&1), Some(&"a"));
        assert_eq!(lru.get(&3), None);
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru: LruMap<u32, &str> = LruMap::new(2);
        lru.insert(1, "a");
        lru.insert(2, "b");
        // touch 1 so 2 becomes the LRU entry
        assert_eq!(lru.get(&1), Some(&"a"));
        let evicted = lru.insert(3, "c");
        assert_eq!(evicted, Some((2, "b")));
        assert_eq!(lru.len(), 2);
        assert!(lru.get(&2).is_none());
        assert_eq!(lru.get(&1), Some(&"a"));
        assert_eq!(lru.get(&3), Some(&"c"));
    }

    #[test]
    fn lru_reinsert_existing_key_does_not_evict() {
        let mut lru: LruMap<u32, &str> = LruMap::new(2);
        lru.insert(1, "a");
        lru.insert(2, "b");
        assert!(lru.insert(1, "a2").is_none());
        assert_eq!(lru.len(), 2);
        assert_eq!(lru.get(&1), Some(&"a2"));
        assert_eq!(lru.get(&2), Some(&"b"));
    }

    #[test]
    fn lru_capacity_one_cycles() {
        let mut lru: LruMap<u32, u32> = LruMap::new(1);
        for i in 0..10 {
            let evicted = lru.insert(i, i * 10);
            if i > 0 {
                assert_eq!(evicted, Some((i - 1, (i - 1) * 10)));
            }
            assert_eq!(lru.len(), 1);
        }
    }

    #[test]
    fn thread_launch_counter_is_per_thread() {
        // Only the thread-isolation semantics are testable without a PJRT
        // client; the increment in `Executable::run` and the per-stage
        // delta attribution are covered by the artifact-gated integration
        // tests (launch-count assertions in tests/integration.rs).
        let before = thread_launches();
        assert_eq!(thread_launches(), before);
        let other = std::thread::spawn(thread_launches).join().unwrap();
        assert_eq!(other, 0, "fresh thread starts at zero");
    }
}

//! PJRT client, compiled-executable cache and typed execution (the `pjrt`
//! feature's half of the runtime; see the module docs in `runtime/mod.rs`).
//!
//! The cache is a **bounded LRU** ([`LruMap`]): the partition-graph
//! subsystem loads fused block-range executables lazily per `(range length,
//! batch)` key, so the resident set is the serving working set, not every
//! module ever compiled.  Hit/miss/eviction counters are exposed via
//! [`Runtime::cache_stats`] and every execution bumps the backend-agnostic
//! thread-local launch counter ([`crate::runtime::thread_launches`]).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::literal::{literal_f32, literal_i32, tensor_f32};
use super::lru::{CacheStats, LruMap};
use crate::tensor::{TensorF32, TensorI32};

/// Shared PJRT CPU client.  Creating a client is expensive (plugin init), so
/// one is shared per process.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    /// Create the process-wide CPU client.
    pub fn cpu() -> Result<Client> {
        let raw = xla::PjRtClient::cpu().context(
            "creating the PJRT CPU client — the pjrt backend needs the XLA/PJRT \
             extension library at runtime; on machines without it, use \
             `--backend reference` (or a default-features build)",
        )?;
        Ok(Client { inner: Arc::new(raw) })
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    pub(crate) fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }
}

impl std::fmt::Debug for Client {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Client")
            .field("platform", &self.platform_name())
            .field("devices", &self.device_count())
            .finish()
    }
}

/// A positional argument to an executable.
///
/// `Lit` passes a pre-converted literal by reference — the weight-literal
/// cache in the pjrt executor uses it to avoid re-converting every weight
/// tensor on every layer execution (the L3 perf pass measured this at ~2x on
/// the per-block hot path; see EXPERIMENTS.md §Perf), and the partition hot
/// path uses it to hand one launch's output straight to the next launch
/// without a host `TensorF32` round trip.
#[derive(Clone)]
pub enum Arg<'a> {
    F32(&'a TensorF32),
    I32(&'a TensorI32),
    Lit(&'a xla::Literal),
}

impl std::fmt::Debug for Arg<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arg::F32(t) => write!(f, "Arg::F32{:?}", t.shape()),
            Arg::I32(t) => write!(f, "Arg::I32{:?}", t.shape()),
            Arg::Lit(_) => write!(f, "Arg::Lit"),
        }
    }
}

/// One compiled HLO module, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("name", &self.name).finish()
    }
}

// The PJRT CPU executable is internally synchronized; the wrapper is used
// behind `Arc` from the serving threads.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with positional args; returns the flattened output tuple.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so the raw
    /// output is a single tuple literal; this decomposes it.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        // Convert tensor args once; borrow pre-converted literals directly.
        let mut owned: Vec<xla::Literal> = Vec::new();
        let mut slots: Vec<Option<&xla::Literal>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(t) => {
                    owned.push(literal_f32(t).with_context(|| {
                        format!("building f32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::I32(t) => {
                    owned.push(literal_i32(t).with_context(|| {
                        format!("building i32 arg for {}", self.name)
                    })?);
                    slots.push(None);
                }
                Arg::Lit(l) => slots.push(Some(l)),
            }
        }
        let mut owned_it = owned.iter();
        let literals: Vec<&xla::Literal> = slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| owned_it.next().expect("owned literal")))
            .collect();
        let result = self
            .exe
            .execute::<&xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        super::count_launch();
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        tuple
            .decompose_tuple()
            .with_context(|| format!("decomposing result of {}", self.name))
    }

    /// Execute and convert every output to an f32 tensor.
    pub fn run_f32(&self, args: &[Arg<'_>]) -> Result<Vec<TensorF32>> {
        self.run(args)?.iter().map(tensor_f32).collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

struct RuntimeInner {
    client: Client,
    cache: Mutex<LruMap<PathBuf, Arc<Executable>>>,
    /// serializes compilation (the thread-affine PJRT wrapper wants one
    /// compiling thread at a time) without blocking cache-hit probes, which
    /// only ever take the short `cache` lock
    compile_lock: Mutex<()>,
}

/// Loads HLO-text artifacts, compiles them once, and caches the result in a
/// bounded LRU.  Cheaply cloneable: clones share one client and one cache,
/// which is what lets the pjrt executor keep a handle for lazy per-range
/// compilation.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

/// Default executable-cache capacity.  Sized to hold the full serving
/// working set (embed/block/head per batch size, `prefix_full`, and every
/// `chain{n}` range graph) with headroom; override with
/// `SPLITEE_EXEC_CACHE_CAP` for eviction experiments.
///
/// Note that eviction only reclaims modules nothing else holds: the model
/// pins its embed/block/head executables via `Arc` for its lifetime, so
/// only the lazily-loaded `chain{n}` range graphs are really reclaimable —
/// and an undersized cap makes every fused launch recompile its chain
/// module.  The env override is therefore floored at
/// [`MIN_CACHE_CAPACITY`] to keep a misconfigured cap from silently
/// turning the hot path into compile thrash.
const DEFAULT_CACHE_CAPACITY: usize = 64;

/// Floor for the `SPLITEE_EXEC_CACHE_CAP` override (tests exercising
/// eviction use [`Runtime::with_capacity`] directly, which is unfloored).
const MIN_CACHE_CAPACITY: usize = 8;

fn default_cache_capacity() -> usize {
    let Ok(raw) = std::env::var("SPLITEE_EXEC_CACHE_CAP") else {
        return DEFAULT_CACHE_CAPACITY;
    };
    let Ok(requested) = raw.parse::<usize>() else {
        log::warn!(
            "SPLITEE_EXEC_CACHE_CAP={raw:?} is not a number — \
             using the default capacity {DEFAULT_CACHE_CAPACITY}"
        );
        return DEFAULT_CACHE_CAPACITY;
    };
    if requested < MIN_CACHE_CAPACITY {
        log::warn!(
            "SPLITEE_EXEC_CACHE_CAP={requested} floored to {MIN_CACHE_CAPACITY} \
             (use Runtime::with_capacity for smaller experimental bounds)"
        );
    }
    requested.max(MIN_CACHE_CAPACITY)
}

impl Runtime {
    pub fn new(client: Client) -> Runtime {
        Runtime::with_capacity(client, default_cache_capacity())
    }

    /// Create with an explicit cache bound (tests use tiny capacities to
    /// exercise eviction).
    pub fn with_capacity(client: Client, capacity: usize) -> Runtime {
        Runtime {
            inner: Arc::new(RuntimeInner {
                client,
                cache: Mutex::new(LruMap::new(capacity)),
                compile_lock: Mutex::new(()),
            }),
        }
    }

    /// Create with a fresh CPU client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime::new(Client::cpu()?))
    }

    pub fn client(&self) -> &Client {
        &self.inner.client
    }

    /// Load + compile an HLO text file (LRU-cached by path).
    ///
    /// Compilation happens *outside* the cache lock — a hundreds-of-ms
    /// compile on one pipeline stage's thread must never stall the other
    /// stage's per-launch hit probe.  The dedicated compile lock still
    /// keeps client-side compilation single-threaded (the thread-affine
    /// PJRT wrapper wants that), with a counter-free double-check
    /// ([`LruMap::peek`]) after acquiring it so racing threads compile each
    /// module once and a lost race is accounted as the single miss it was.
    pub fn load(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(hit) = self.inner.cache.lock().unwrap().get(path) {
            return Ok(hit.clone());
        }
        let _compiling = self.inner.compile_lock.lock().unwrap();
        // another thread may have compiled this module while we waited
        if let Some(hit) = self.inner.cache.lock().unwrap().peek(path) {
            return Ok(hit.clone());
        }
        if !path.exists() {
            bail!(
                "HLO artifact {path:?} not found — run `make artifacts` to generate \
                 it, or point --artifacts / SPLITEE_ARTIFACTS at a directory that \
                 has it"
            );
        }
        let t0 = Instant::now();
        let compiled: Result<Arc<Executable>> = (|| {
            let proto = xla::HloModuleProto::from_text_file(path).context("parsing HLO text")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.inner.client.raw().compile(&comp).context("compiling")?;
            let name = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.display().to_string());
            Ok(Arc::new(Executable { exe, name }))
        })();
        let arc = compiled.with_context(|| {
            let stats = self.cache_stats();
            format!(
                "loading HLO artifact {path:?} (executable cache: {}/{} modules \
                 resident; capacity set by SPLITEE_EXEC_CACHE_CAP)",
                stats.resident,
                self.inner.cache.lock().unwrap().capacity()
            )
        })?;
        log::debug!(
            "compiled {} in {:.1} ms",
            arc.name(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        if let Some((evicted, _)) =
            self.inner.cache.lock().unwrap().insert(path.to_path_buf(), arc.clone())
        {
            log::debug!("evicted {evicted:?} from the executable cache");
        }
        Ok(arc)
    }

    /// Number of compiled modules held in the cache.
    pub fn cached_count(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// Hit/miss/eviction counters since this runtime was created.  Miss
    /// accounting: one miss per cold load, counted at the pre-lock probe
    /// (a lost compile race therefore counts one miss and no extra hit).
    pub fn cache_stats(&self) -> CacheStats {
        self.inner.cache.lock().unwrap().stats()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("client", &self.inner.client)
            .field("cached", &self.cached_count())
            .finish()
    }
}

//! Speculative launches: cancellable, possibly-deferred execution of the
//! post-split continuation while the exit-head verdict is in flight.
//!
//! SplitEE's edge stage serializes the exit-head verdict before any
//! post-split work begins — the "idle-while-deciding" gap Matsubara et al.
//! identify as the main latency tax of early-exit split computing.  This
//! module closes it at the runtime seam: [`SpecLane`] owns a dedicated
//! worker thread on which a [`ModelExecutor`] runs the continuation
//! (`blocks[split..L)` + final exit head) *concurrently* with whatever the
//! issuing thread does next, and hands back a [`SpecHandle`] that resolves
//! to exactly one of
//!
//! * **used** — [`SpecHandle::take`] returned the result and the caller
//!   consumed it, or
//! * **wasted** — [`SpecHandle::kill`] (kill-on-exit), a drop on an error
//!   path, or a worker failure discarded it.
//!
//! The seam is backend-agnostic: the job executes through the
//! `blocks_host` / `exit_head` trait methods, so the reference and pjrt
//! executors both run speculative launches without backend-specific code.
//! When no worker is reachable the handle degrades to a **deferred** launch
//! that runs inline at `take()` — still cancellable, never lost.
//!
//! # Accounting invariants
//!
//! * Speculative launches execute on the lane thread, so the per-thread
//!   launch counters ([`thread_launches`]) of the serving stages never see
//!   them; a *used* result carries its own launch count for the consumer to
//!   attribute, a *wasted* one is attributed nowhere.
//! * Every issued handle resolves exactly once:
//!   `used + wasted == issued` once all handles are dropped, and — because
//!   [`SpecCounters::snapshot`] reads `used`/`wasted` *before* `issued` —
//!   a mid-flight snapshot can never show `used + wasted > issued`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{thread_launches, HeadOut, ModelExecutor};
use crate::tensor::TensorF32;

/// Lifecycle counters for speculative launches, shared across the pipeline
/// stages that issue (edge) and resolve (cloud) handles.
#[derive(Debug, Default)]
pub struct SpecCounters {
    issued: AtomicU64,
    used: AtomicU64,
    wasted: AtomicU64,
}

/// A consistent point-in-time view of [`SpecCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpecSnapshot {
    /// speculative launches issued (handles created)
    pub issued: u64,
    /// handles whose result was consumed by the pipeline
    pub used: u64,
    /// handles killed, dropped, or failed — their work is attributed nowhere
    pub wasted: u64,
}

impl SpecSnapshot {
    /// Fraction of issued launches whose result was consumed.
    pub fn hit_rate(&self) -> f64 {
        if self.issued == 0 {
            0.0
        } else {
            self.used as f64 / self.issued as f64
        }
    }

    /// Handles issued but not yet resolved at snapshot time.
    pub fn in_flight(&self) -> u64 {
        self.issued - self.used - self.wasted
    }
}

impl SpecCounters {
    /// A fresh, shareable counter set.
    pub fn new() -> Arc<SpecCounters> {
        Arc::new(SpecCounters::default())
    }

    fn issue(&self) {
        self.issued.fetch_add(1, Ordering::SeqCst);
    }

    fn resolve_used(&self) {
        self.used.fetch_add(1, Ordering::SeqCst);
    }

    fn resolve_wasted(&self) {
        self.wasted.fetch_add(1, Ordering::SeqCst);
    }

    /// One consistent struct read.  `used` and `wasted` are loaded *before*
    /// `issued`: every resolution is preceded (in its handle's program
    /// order) by its issue, so any resolution visible to the first two loads
    /// has its issue visible to the third — a mid-flight snapshot therefore
    /// always satisfies `used + wasted <= issued`, whatever the stages are
    /// doing concurrently.  (Reading `issued` first would admit snapshots
    /// with `used > issued`: a handle issued *and* used between the two
    /// loads would be counted by the second but not the first.)
    pub fn snapshot(&self) -> SpecSnapshot {
        let used = self.used.load(Ordering::SeqCst);
        let wasted = self.wasted.load(Ordering::SeqCst);
        let issued = self.issued.load(Ordering::SeqCst);
        SpecSnapshot { issued, used, wasted }
    }
}

/// The payload a resolved speculative launch hands back.
pub struct SpecResult {
    /// final-exit head output over the *full* (padded) batch the launch was
    /// issued for — consumers gather the rows they need
    pub head: HeadOut,
    /// executable launches the speculative job performed (on the lane
    /// thread; the consumer attributes them iff the result is used)
    pub launches: u64,
    /// real host time of the continuation compute (ms) — the cloud
    /// simulator's input when the result is used
    pub host_ms: f64,
}

struct SpecJob {
    exec: Arc<dyn ModelExecutor>,
    /// shared with the edge stage's `EdgeWork.h` — issuing a speculative
    /// launch never copies the activation buffer
    h: Arc<TensorF32>,
    from_layer: usize,
    n_layers: usize,
    cancel: Arc<AtomicBool>,
    out: Sender<Result<SpecResult>>,
}

/// The continuation itself: blocks `from_layer+1..L` then the final exit
/// head — the exact operation sequence of the non-speculative cloud path
/// (`MultiExitModel::forward_rest_exit`), so a used result is the same math
/// on the same rows.  `cancel` is re-checked between the two launches: a
/// kill-on-exit landing mid-range still bounds wasted compute to the range
/// already in flight (a fused range launch itself cannot be interrupted
/// without changing the launch-count semantics of a used result).  Returns
/// `None` only when cancelled between launches.
fn run_continuation(
    exec: &dyn ModelExecutor,
    h: &TensorF32,
    from_layer: usize,
    n_layers: usize,
    cancel: Option<&AtomicBool>,
) -> Option<Result<SpecResult>> {
    let launches0 = thread_launches();
    let t0 = Instant::now();
    let head = if from_layer + 1 == n_layers {
        exec.exit_head_host(h, n_layers - 1)
    } else {
        match exec.blocks_host(h, from_layer + 1, n_layers) {
            Ok(hid) => {
                if cancel.is_some_and(|c| c.load(Ordering::SeqCst)) {
                    return None; // killed mid-range: skip the head launch
                }
                exec.exit_head(&hid, n_layers - 1)
            }
            Err(e) => Err(e),
        }
    };
    Some(head.map(|head| SpecResult {
        head,
        launches: thread_launches() - launches0,
        host_ms: t0.elapsed().as_secs_f64() * 1e3,
    }))
}

fn worker_loop(rx: Receiver<SpecJob>) {
    while let Ok(job) = rx.recv() {
        // killed before starting: skip the compute entirely (the fast
        // kill-on-exit path when the whole batch exits at the split)
        if job.cancel.load(Ordering::SeqCst) {
            continue;
        }
        if let Some(res) =
            run_continuation(job.exec.as_ref(), &job.h, job.from_layer, job.n_layers, Some(&job.cancel))
        {
            // the receiver may already be gone (killed mid-compute) — discard
            let _ = job.out.send(res);
        }
    }
}

struct LaneGuard {
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for LaneGuard {
    fn drop(&mut self) {
        // By the time the last lane clone drops, every sender is gone, so
        // the worker drains its queue and exits — the join is bounded by
        // the in-flight compute, never indefinite.
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A dedicated speculation worker thread plus the sending half used to
/// issue launches on it.  Cheap to clone (each pipeline stage owns its own
/// sender); the worker exits when the last clone drops.
#[derive(Clone)]
pub struct SpecLane {
    tx: Sender<SpecJob>,
    _guard: Arc<LaneGuard>,
}

impl std::fmt::Debug for SpecLane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("SpecLane")
    }
}

impl Default for SpecLane {
    fn default() -> Self {
        SpecLane::new()
    }
}

impl SpecLane {
    /// Spawn the speculation worker thread.
    pub fn new() -> SpecLane {
        let (tx, rx) = std::sync::mpsc::channel::<SpecJob>();
        let handle = std::thread::Builder::new()
            .name("splitee-spec".into())
            .spawn(move || worker_loop(rx))
            .expect("spawn speculation worker");
        SpecLane { tx, _guard: Arc::new(LaneGuard { handle: Some(handle) }) }
    }

    /// Issue blocks `from_layer+1..L` + the final exit head over `h` as a
    /// speculative launch, returning immediately.  Counts `issued` now; the
    /// handle resolves to exactly one of used/wasted.  If the worker is
    /// unreachable the handle degrades to a deferred launch.
    pub fn speculate_rest_exit(
        &self,
        exec: Arc<dyn ModelExecutor>,
        h: Arc<TensorF32>,
        from_layer: usize,
        n_layers: usize,
        counters: &Arc<SpecCounters>,
    ) -> SpecHandle {
        counters.issue();
        let cancel = Arc::new(AtomicBool::new(false));
        let (out_tx, out_rx) = std::sync::mpsc::channel();
        let job = SpecJob {
            exec,
            h,
            from_layer,
            n_layers,
            cancel: Arc::clone(&cancel),
            out: out_tx,
        };
        match self.tx.send(job) {
            Ok(()) => SpecHandle {
                state: Some(HandleState::InFlight { rx: out_rx, cancel }),
                counters: Arc::clone(counters),
            },
            Err(err) => {
                // worker died: keep the launch as a deferred computation so
                // the consumer still gets a correct (if unoverlapped) result
                let SpecJob { exec, h, from_layer, n_layers, .. } = err.0;
                SpecHandle {
                    state: Some(HandleState::Deferred { exec, h, from_layer, n_layers }),
                    counters: Arc::clone(counters),
                }
            }
        }
    }
}

enum HandleState {
    /// queued on / running on the lane worker
    InFlight {
        rx: Receiver<Result<SpecResult>>,
        cancel: Arc<AtomicBool>,
    },
    /// no worker: the compute runs on the caller's thread at `take()`
    Deferred {
        exec: Arc<dyn ModelExecutor>,
        h: Arc<TensorF32>,
        from_layer: usize,
        n_layers: usize,
    },
}

/// A cancellable speculative launch.  Consumed by exactly one of
/// [`SpecHandle::take`] or [`SpecHandle::kill`]; dropping an unresolved
/// handle counts it wasted, so `used + wasted == issued` holds on every
/// path, including error shutdowns with launches still in flight.
pub struct SpecHandle {
    state: Option<HandleState>,
    counters: Arc<SpecCounters>,
}

impl std::fmt::Debug for SpecHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.state.is_some() { "SpecHandle(pending)" } else { "SpecHandle(resolved)" })
    }
}

impl SpecHandle {
    /// A deferred launch with no worker involved: nothing runs unless
    /// `take()` is called (tests and the lane's fallback path use this).
    pub fn deferred(
        exec: Arc<dyn ModelExecutor>,
        h: Arc<TensorF32>,
        from_layer: usize,
        n_layers: usize,
        counters: &Arc<SpecCounters>,
    ) -> SpecHandle {
        counters.issue();
        SpecHandle {
            state: Some(HandleState::Deferred { exec, h, from_layer, n_layers }),
            counters: Arc::clone(counters),
        }
    }

    /// Kill the launch (kill-on-exit): counts it wasted and never blocks.
    /// A job not yet started is skipped by the worker; one mid-compute
    /// finishes on the lane and its result is discarded.
    pub fn kill(mut self) {
        self.discard();
    }

    fn discard(&mut self) {
        if let Some(state) = self.state.take() {
            if let HandleState::InFlight { cancel, .. } = &state {
                cancel.store(true, Ordering::SeqCst);
            }
            self.counters.resolve_wasted();
        }
    }

    /// Wait for (or, deferred, run) the speculative result.  `Ok` counts
    /// the handle used; `Err` (worker died mid-launch) counts it wasted and
    /// the caller recomputes through the normal path.
    pub fn take(mut self) -> Result<SpecResult> {
        let state = self.state.take().expect("take/kill consume the handle");
        let res = match state {
            HandleState::InFlight { rx, .. } => match rx.recv() {
                Ok(res) => res,
                Err(_) => Err(anyhow!("speculation worker dropped the launch")),
            },
            HandleState::Deferred { exec, h, from_layer, n_layers } => {
                run_continuation(exec.as_ref(), &h, from_layer, n_layers, None)
                    .expect("a deferred launch cannot be cancelled mid-run")
            }
        };
        match res {
            Ok(r) => {
                self.counters.resolve_used();
                Ok(r)
            }
            Err(e) => {
                self.counters.resolve_wasted();
                Err(e)
            }
        }
    }
}

impl Drop for SpecHandle {
    fn drop(&mut self) {
        self.discard();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::ModelWeights;
    use crate::runtime::{ComputeBackend, ModelSpec, ReferenceBackend};
    use crate::tensor::TensorI32;

    const L: usize = 3;

    fn executor() -> Arc<dyn ModelExecutor> {
        let weights = Arc::new(ModelWeights::synthetic(L, 16, 32, 64, 8, 2, 0x51EC));
        let spec = ModelSpec {
            task: "t",
            style: "s",
            weights,
            n_heads: 2,
            seq_len: 8,
            batch_sizes: vec![1, 4],
            cache_batch: 4,
            manifest: None,
        };
        Arc::from(ReferenceBackend::default().load_model(&spec).expect("reference executor"))
    }

    fn hidden(exec: &Arc<dyn ModelExecutor>, b: usize) -> Arc<TensorF32> {
        let tokens = TensorI32::new(
            vec![b, 8],
            (0..(b * 8) as i32).map(|i| (i * 5 + 3) % 64).collect(),
        )
        .unwrap();
        let h0 = exec.embed(&tokens).unwrap();
        Arc::new(exec.blocks(&h0, 0, 1).unwrap().to_tensor().unwrap())
    }

    /// Direct (non-speculative) continuation for comparison.
    fn direct(exec: &Arc<dyn ModelExecutor>, h: &TensorF32, from_layer: usize) -> HeadOut {
        let hid = exec.blocks_host(h, from_layer + 1, L).unwrap();
        exec.exit_head(&hid, L - 1).unwrap()
    }

    #[test]
    fn taken_launch_matches_direct_execution_bitexact() {
        let exec = executor();
        let h = hidden(&exec, 4);
        let counters = SpecCounters::new();
        let lane = SpecLane::new();
        let handle = lane.speculate_rest_exit(Arc::clone(&exec), h.clone(), 0, L, &counters);
        let want = direct(&exec, &h, 0);
        let got = handle.take().expect("speculative result");
        assert_eq!(got.launches, 2, "one range launch + one head launch");
        assert!(got.host_ms >= 0.0);
        for (a, b) in got.head.probs.data().iter().zip(want.probs.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "speculative probs must be bit-exact");
        }
        for (a, b) in got.head.conf.iter().zip(&want.conf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let s = counters.snapshot();
        assert_eq!((s.issued, s.used, s.wasted), (1, 1, 0));
        assert!((s.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn last_layer_speculation_is_head_only() {
        let exec = executor();
        let h = hidden(&exec, 1);
        let counters = SpecCounters::new();
        let lane = SpecLane::new();
        let got = lane
            .speculate_rest_exit(Arc::clone(&exec), h.clone(), L - 1, L, &counters)
            .take()
            .unwrap();
        assert_eq!(got.launches, 1, "from L-1 the continuation is the head alone");
        let want = exec.exit_head_host(&h, L - 1).unwrap();
        assert_eq!(got.head.conf[0].to_bits(), want.conf[0].to_bits());
    }

    #[test]
    fn killed_launch_counts_wasted_and_never_blocks() {
        let exec = executor();
        let counters = SpecCounters::new();
        let lane = SpecLane::new();
        for i in 0..8 {
            let h = hidden(&exec, 1 + (i % 2));
            let handle = lane.speculate_rest_exit(Arc::clone(&exec), h, 0, L, &counters);
            handle.kill();
        }
        drop(lane); // joins the worker: no deadlock with killed jobs queued
        let s = counters.snapshot();
        assert_eq!((s.issued, s.used, s.wasted), (8, 0, 8));
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn dropped_handle_resolves_wasted_exactly_once() {
        let exec = executor();
        let counters = SpecCounters::new();
        let lane = SpecLane::new();
        {
            let h = hidden(&exec, 1);
            let _handle = lane.speculate_rest_exit(Arc::clone(&exec), h, 0, L, &counters);
            // dropped unresolved (the error-shutdown path)
        }
        drop(lane);
        let s = counters.snapshot();
        assert_eq!((s.issued, s.used, s.wasted), (1, 0, 1));
    }

    #[test]
    fn deferred_handle_runs_inline_and_is_cancellable() {
        let exec = executor();
        let h = hidden(&exec, 2);
        let counters = SpecCounters::new();
        // used path: computes at take() on this thread, bit-exact
        let handle = SpecHandle::deferred(Arc::clone(&exec), h.clone(), 0, L, &counters);
        let launches0 = thread_launches();
        let got = handle.take().unwrap();
        assert_eq!(
            thread_launches() - launches0,
            got.launches,
            "deferred launches run on the calling thread"
        );
        let want = direct(&exec, &h, 0);
        assert_eq!(got.head.conf[0].to_bits(), want.conf[0].to_bits());
        // killed path: nothing ever runs
        let launches1 = thread_launches();
        SpecHandle::deferred(Arc::clone(&exec), h, 0, L, &counters).kill();
        assert_eq!(thread_launches(), launches1, "killed deferred launch must not execute");
        let s = counters.snapshot();
        assert_eq!((s.issued, s.used, s.wasted), (2, 1, 1));
    }

    #[test]
    fn lane_worker_launches_never_pollute_the_issuing_thread() {
        let exec = executor();
        let h = hidden(&exec, 1);
        let counters = SpecCounters::new();
        let lane = SpecLane::new();
        let launches0 = thread_launches();
        let handle = lane.speculate_rest_exit(Arc::clone(&exec), h, 0, L, &counters);
        let got = handle.take().unwrap();
        assert_eq!(
            thread_launches(),
            launches0,
            "speculative launches must land on the lane thread only"
        );
        assert_eq!(got.launches, 2);
    }

    #[test]
    fn mid_flight_snapshot_never_shows_used_exceeding_issued() {
        // Hammer the counters from several writer threads (each following
        // the issue -> resolve lifecycle) while a reader snapshots
        // concurrently: the read order inside snapshot() must make
        // `used + wasted <= issued` (hence `used <= issued`) hold in every
        // observable interleaving.
        let counters = SpecCounters::new();
        let stop = Arc::new(AtomicBool::new(false));
        let mut writers = Vec::new();
        for w in 0..4 {
            let c = Arc::clone(&counters);
            writers.push(std::thread::spawn(move || {
                for i in 0..20_000u64 {
                    c.issue();
                    if (i + w) % 3 == 0 {
                        c.resolve_wasted();
                    } else {
                        c.resolve_used();
                    }
                }
            }));
        }
        let reader = {
            let c = Arc::clone(&counters);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut seen = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let s = c.snapshot();
                    assert!(
                        s.used + s.wasted <= s.issued,
                        "inconsistent mid-flight snapshot: {s:?}"
                    );
                    seen += 1;
                }
                seen
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        assert!(reader.join().unwrap() > 0, "reader must have raced the writers");
        let s = counters.snapshot();
        assert_eq!(s.issued, 80_000);
        assert_eq!(s.used + s.wasted, 80_000, "every lifecycle resolved exactly once");
    }
}

//! The policy zoo: SplitEE, SplitEE-S and every baseline the paper compares
//! against (section 5.3), plus the future-work extensions (section 7).
//!
//! A policy consumes one sample at a time — the paper's online, unsupervised
//! setting.  It sees only per-exit **confidence/entropy** observations (never
//! labels) through [`SampleView`], decides where to split and whether to
//! exit or offload, and returns an [`Outcome`] with the layer whose
//! prediction is used plus the accumulated cost in lambda units.
//!
//! The [`contextual`] module extends the zoo past the paper's stationary
//! setting: [`ContextualSplitPolicy`] keeps independent per-link-context arm
//! statistics for the serving path's time-varying uplink scenarios
//! (`--link markov|trace:<path>`; see [`crate::sim::link`]).  It is a
//! serving-path policy (it needs the coordinator's link context), so unlike
//! the rest of the zoo it does not implement the offline [`Policy`] trait.

pub mod adaptive;
pub mod baselines;
pub mod contextual;
pub mod splitee;

pub use adaptive::{AdaptiveThresholdPolicy, PerSamplePolicy};
pub use baselines::{DeeBertPolicy, ElasticBertPolicy, FinalExitPolicy, FixedSplitPolicy,
                    RandomExitPolicy};
pub use contextual::ContextualSplitPolicy;
pub use splitee::{SplitEePolicy, SplitEeSPolicy};

use crate::cost::CostModel;

/// Per-sample observation surface: what the exits *would* report at each
/// layer.  Policies may only read the entries their cost accounting pays for
/// (SplitEE reads one layer; cascades read a prefix) — the experiment driver
/// hands the full profile and trusts the policy's declared cost, exactly as
/// the paper's released evaluation does with precomputed logits.
#[derive(Debug, Clone, Copy)]
pub struct SampleView<'a> {
    /// max-probability confidence per layer [L]
    pub conf: &'a [f32],
    /// prediction entropy per layer [L]
    pub ent: &'a [f32],
}

impl<'a> SampleView<'a> {
    pub fn n_layers(&self) -> usize {
        self.conf.len()
    }
}

/// What happened to one sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    /// 1-based layer chosen as the split point (== exit layer for cascades)
    pub split: usize,
    /// 1-based layer whose prediction is the final answer
    /// (== split when exited on-device, == L when offloaded)
    pub infer_layer: usize,
    /// whether the sample was offloaded to the cloud
    pub offloaded: bool,
    /// total cost accumulated, lambda units (computation + offload)
    pub cost: f64,
    /// the paper's reward (eq. 1) for the split decision
    pub reward: f64,
}

/// An online split/exit policy.
pub trait Policy: Send {
    /// Display name (matches the paper's table rows).
    fn name(&self) -> String;

    /// Process one sample.
    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome;

    /// Forget all learned state (new repetition).
    fn reset(&mut self);

    /// Whether the variant pays the per-exit inference cost at every layer
    /// up to the split (SplitEE-S, cascades) or only at the split (SplitEE).
    fn uses_side_info(&self) -> bool {
        false
    }

    /// Clone into a boxed trait object.  Lets the experiment harness run
    /// repetitions of one configured policy in parallel: each repetition
    /// gets its own clone (then `reset()`), exactly the state a serial
    /// `reset()`-per-rep loop would start from.
    fn clone_box(&self) -> Box<dyn Policy>;
}

/// Compute the paper's reward (eq. 1) for splitting at `layer` (1-based)
/// given the sample's confidence profile — shared by policies and by the
/// experiment harness (oracle/regret computation).
pub fn reward_for_split(
    s: &SampleView<'_>,
    cm: &CostModel,
    layer: usize,
    alpha: f64,
    side_info: bool,
) -> f64 {
    let l = s.n_layers();
    let conf_i = s.conf[layer - 1] as f64;
    if conf_i >= alpha || layer == l {
        cm.reward_exit(layer, conf_i, side_info)
    } else {
        cm.reward_offload(layer, s.conf[l - 1] as f64, side_info)
    }
}

/// The expected-optimal split layer over a set of samples: evaluates
/// `mean r(i)` for every arm and returns the (1-based) argmax.  Used by the
/// experiment harness to compute regret against the oracle (paper eq. 2/3).
pub fn oracle_split(
    profiles: &[(Vec<f32>, Vec<f32>)],
    cm: &CostModel,
    alpha: f64,
    side_info: bool,
) -> (usize, Vec<f64>) {
    let l = profiles
        .first()
        .map(|(c, _)| c.len())
        .expect("oracle needs at least one sample");
    let mut means = vec![0.0f64; l];
    for (conf, ent) in profiles {
        let view = SampleView { conf, ent };
        for layer in 1..=l {
            means[layer - 1] += reward_for_split(&view, cm, layer, alpha, side_info);
        }
    }
    for m in &mut means {
        *m /= profiles.len() as f64;
    }
    let best = means
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i + 1)
        .unwrap();
    (best, means)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    #[test]
    fn reward_exit_branch_when_confident() {
        let conf = vec![0.9f32; 12];
        let ent = vec![0.1f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let r = reward_for_split(&s, &cm(), 3, 0.8, false);
        assert!((r - cm().reward_exit(3, 0.9f32 as f64, false)).abs() < 1e-9);
    }

    #[test]
    fn reward_offload_branch_when_unsure() {
        let mut conf = vec![0.6f32; 12];
        conf[11] = 0.95;
        let ent = vec![0.5f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let r = reward_for_split(&s, &cm(), 3, 0.8, false);
        assert!((r - cm().reward_offload(3, 0.95f32 as f64, false)).abs() < 1e-9);
    }

    #[test]
    fn last_layer_always_exits() {
        let conf = vec![0.5f32; 12];
        let ent = vec![0.5f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let r = reward_for_split(&s, &cm(), 12, 0.9, false);
        assert!((r - cm().reward_exit(12, 0.5f32 as f64, false)).abs() < 1e-9);
    }

    #[test]
    fn oracle_prefers_cheap_confident_layer() {
        // all layers confident -> earliest layer has the best reward
        let profiles: Vec<(Vec<f32>, Vec<f32>)> =
            (0..50).map(|_| (vec![0.95f32; 12], vec![0.1f32; 12])).collect();
        let (best, means) = oracle_split(&profiles, &cm(), 0.8, false);
        assert_eq!(best, 1);
        assert!(means[0] > means[11]);
    }

    #[test]
    fn oracle_offloads_from_shallow_when_never_confident_early() {
        // Shallow exits never clear the threshold, so every split below the
        // confident region offloads and reaches C_L; the cheapest such split
        // is the shallowest (gamma grows with depth while the offload price
        // is flat) — the oracle must pick layer 1, not burn compute.
        let profiles: Vec<(Vec<f32>, Vec<f32>)> = (0..50)
            .map(|_| {
                let mut c = vec![0.55f32; 12];
                for l in 7..12 {
                    c[l] = 0.97;
                }
                (c, vec![0.3f32; 12])
            })
            .collect();
        let (best, means) = oracle_split(&profiles, &cm(), 0.9, false);
        assert_eq!(best, 1, "means {means:?}");
        // and exiting deep is strictly worse than offloading from layer 1
        assert!(means[0] > means[11]);
    }
}

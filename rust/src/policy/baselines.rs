//! Baselines from paper section 5.3: DeeBERT, ElasticBERT, Random selection,
//! Final exit — plus Fixed-split (the oracle arm replayed, used for regret).

use super::{Outcome, Policy, SampleView};
use crate::cost::CostModel;
use crate::util::rng::Rng;

/// DeeBERT: entropy-threshold cascade.  Processes layer by layer, exits at
/// the first layer whose prediction entropy is `<= tau`; never offloads
/// (the model runs fully on-device), so a never-confident sample pays the
/// whole `lambda * L`.
#[derive(Debug, Clone)]
pub struct DeeBertPolicy {
    /// entropy threshold (calibrated on source validation data)
    pub tau: f64,
}

impl DeeBertPolicy {
    pub fn new(tau: f64) -> DeeBertPolicy {
        DeeBertPolicy { tau }
    }
}

impl Policy for DeeBertPolicy {
    fn name(&self) -> String {
        "DeeBERT".into()
    }

    fn uses_side_info(&self) -> bool {
        true // evaluates every exit on the way up
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let exit = (1..=l)
            .find(|&i| (s.ent[i - 1] as f64) <= self.tau)
            .unwrap_or(l);
        Outcome {
            split: exit,
            infer_layer: exit,
            offloaded: false,
            cost: cm.compute_cost_cascade(exit),
            reward: 0.0, // not a bandit; reward not defined by the paper here
        }
    }

    fn reset(&mut self) {}

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// ElasticBERT: confidence-threshold cascade (max-prob `>= alpha`), again
/// fully on-device with no offload option.
#[derive(Debug, Clone)]
pub struct ElasticBertPolicy {
    pub alpha: f64,
}

impl ElasticBertPolicy {
    pub fn new(alpha: f64) -> ElasticBertPolicy {
        ElasticBertPolicy { alpha }
    }
}

impl Policy for ElasticBertPolicy {
    fn name(&self) -> String {
        "ElasticBERT".into()
    }

    fn uses_side_info(&self) -> bool {
        true
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let exit = (1..=l)
            .find(|&i| (s.conf[i - 1] as f64) >= self.alpha)
            .unwrap_or(l);
        Outcome {
            split: exit,
            infer_layer: exit,
            offloaded: false,
            cost: cm.compute_cost_cascade(exit),
            reward: 0.0,
        }
    }

    fn reset(&mut self) {}

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// Random selection (paper 5.3): uniform random split layer, then the same
/// exit-or-offload rule as SplitEE.
#[derive(Debug, Clone)]
pub struct RandomExitPolicy {
    pub alpha: f64,
    rng: Rng,
    seed: u64,
}

impl RandomExitPolicy {
    pub fn new(alpha: f64, seed: u64) -> RandomExitPolicy {
        RandomExitPolicy { alpha, rng: Rng::new(seed), seed }
    }
}

impl Policy for RandomExitPolicy {
    fn name(&self) -> String {
        "Random-exit".into()
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let split = 1 + self.rng.below(l as u64) as usize;
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= self.alpha || split == l;
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, false))
        } else {
            (l, true, cm.reward_offload(split, s.conf[l - 1] as f64, false))
        };
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, false),
            reward,
        }
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// Final exit: every sample through all L layers (the benchmark row all
/// deltas in Table 2 are relative to).
#[derive(Debug, Clone, Default)]
pub struct FinalExitPolicy;

impl Policy for FinalExitPolicy {
    fn name(&self) -> String {
        "Final-exit".into()
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        Outcome {
            split: l,
            infer_layer: l,
            offloaded: false,
            cost: cm.final_exit_cost(),
            reward: cm.reward_exit(l, s.conf[l - 1] as f64, false),
        }
    }

    fn reset(&mut self) {}

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// Fixed split layer with SplitEE's exit-or-offload rule.  With the oracle
/// arm this is the policy regret is measured against (paper eq. 3); it also
/// backs the `--fixed-split` serving mode.
#[derive(Debug, Clone)]
pub struct FixedSplitPolicy {
    /// 1-based split layer
    pub split: usize,
    pub alpha: f64,
    pub side_info: bool,
}

impl FixedSplitPolicy {
    pub fn new(split: usize, alpha: f64) -> FixedSplitPolicy {
        FixedSplitPolicy { split, alpha, side_info: false }
    }
}

impl Policy for FixedSplitPolicy {
    fn name(&self) -> String {
        format!("Fixed-split({})", self.split)
    }

    fn uses_side_info(&self) -> bool {
        self.side_info
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let split = self.split.min(l);
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= self.alpha || split == l;
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, self.side_info))
        } else {
            (
                l,
                true,
                cm.reward_offload(split, s.conf[l - 1] as f64, self.side_info),
            )
        };
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, self.side_info),
            reward,
        }
    }

    fn reset(&mut self) {}

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    fn view<'a>(conf: &'a [f32], ent: &'a [f32]) -> SampleView<'a> {
        SampleView { conf, ent }
    }

    #[test]
    fn deebert_exits_at_first_low_entropy() {
        let conf = vec![0.6f32; 12];
        let mut ent = vec![0.6f32; 12];
        ent[4] = 0.1;
        let mut p = DeeBertPolicy::new(0.2);
        let o = p.decide(&view(&conf, &ent), &cm());
        assert_eq!(o.infer_layer, 5);
        assert!(!o.offloaded);
        assert!((o.cost - cm().compute_cost_cascade(5)).abs() < 1e-12);
    }

    #[test]
    fn deebert_never_confident_pays_full_depth() {
        let conf = vec![0.6f32; 12];
        let ent = vec![0.69f32; 12];
        let mut p = DeeBertPolicy::new(0.2);
        let o = p.decide(&view(&conf, &ent), &cm());
        assert_eq!(o.infer_layer, 12);
        assert!((o.cost - 12.0).abs() < 1e-12);
    }

    #[test]
    fn elasticbert_exits_at_first_confident() {
        let mut conf = vec![0.6f32; 12];
        conf[2] = 0.95;
        let ent = vec![0.3f32; 12];
        let mut p = ElasticBertPolicy::new(0.9);
        let o = p.decide(&view(&conf, &ent), &cm());
        assert_eq!(o.infer_layer, 3);
        assert!((o.cost - 3.0).abs() < 1e-12);
    }

    #[test]
    fn random_exit_spans_layers_and_is_seed_deterministic() {
        let conf = vec![0.95f32; 12];
        let ent = vec![0.1f32; 12];
        let c = cm();
        let mut p1 = RandomExitPolicy::new(0.9, 7);
        let mut p2 = RandomExitPolicy::new(0.9, 7);
        let s1: Vec<usize> = (0..100).map(|_| p1.decide(&view(&conf, &ent), &c).split).collect();
        let s2: Vec<usize> = (0..100).map(|_| p2.decide(&view(&conf, &ent), &c).split).collect();
        assert_eq!(s1, s2);
        let distinct: std::collections::BTreeSet<_> = s1.iter().collect();
        assert!(distinct.len() >= 8, "random policy too narrow: {distinct:?}");
    }

    #[test]
    fn final_exit_constant_cost() {
        let conf = vec![0.7f32; 12];
        let ent = vec![0.3f32; 12];
        let mut p = FinalExitPolicy;
        let o = p.decide(&view(&conf, &ent), &cm());
        assert_eq!(o.infer_layer, 12);
        assert!((o.cost - 12.0).abs() < 1e-12);
    }

    #[test]
    fn fixed_split_offloads_when_unsure() {
        let mut conf = vec![0.5f32; 12];
        conf[11] = 0.98;
        let ent = vec![0.3f32; 12];
        let mut p = FixedSplitPolicy::new(4, 0.9);
        let o = p.decide(&view(&conf, &ent), &cm());
        assert_eq!(o.split, 4);
        assert!(o.offloaded);
        assert_eq!(o.infer_layer, 12);
        assert!((o.cost - (cm().compute_cost_splitee(4) + 5.0)).abs() < 1e-12);
    }

    #[test]
    fn random_reset_replays_sequence() {
        let conf = vec![0.95f32; 12];
        let ent = vec![0.1f32; 12];
        let c = cm();
        let mut p = RandomExitPolicy::new(0.9, 3);
        let a: Vec<usize> = (0..20).map(|_| p.decide(&view(&conf, &ent), &c).split).collect();
        p.reset();
        let b: Vec<usize> = (0..20).map(|_| p.decide(&view(&conf, &ent), &c).split).collect();
        assert_eq!(a, b);
    }
}

//! Context-aware split selection: independent UCB bandits per link context.
//!
//! SplitEE's bandit assumes a stationary environment; when the uplink is
//! time-varying the optimal split moves with it (I-SplitEE, Bajpai et al.
//! 2024; Dynamic Split Computing, Bakhtiarnia et al. 2022).  The serving
//! coordinator discretizes the instantaneous link condition into a small
//! **context** id ([`crate::sim::link::LinkState::context`]) and this policy
//! keeps one [`Ucb`] per context: the split is chosen from the bandit of the
//! context observed *at decision time*, and the realised reward is credited
//! back to that same context — never to whatever state the link drifted to
//! meanwhile.  That keying rule is what keeps the pipelined serving path
//! decision-identical to serial replay of the same link trace.

use crate::bandit::Ucb;

/// UCB-over-splits, one independent bandit per link context
/// (`PolicyKind::Contextual` on the serving path).
///
/// With a single context (the static link scenario) this degenerates to
/// exactly [`crate::policy::SplitEePolicy`]'s arm dynamics.
#[derive(Debug, Clone)]
pub struct ContextualSplitPolicy {
    /// one bandit per context, each over the L split-layer arms
    ucbs: Vec<Ucb>,
    /// exit threshold alpha (calibrated on source validation data)
    pub alpha: f64,
}

impl ContextualSplitPolicy {
    /// `n_contexts` comes from the configured link scenario
    /// (`LinkScenario::n_contexts`); zero is clamped to one so a degenerate
    /// scenario still yields a usable policy.
    pub fn new(n_layers: usize, n_contexts: usize, alpha: f64, beta: f64) -> ContextualSplitPolicy {
        let n_contexts = n_contexts.max(1);
        ContextualSplitPolicy {
            ucbs: (0..n_contexts).map(|_| Ucb::new(n_layers, beta)).collect(),
            alpha,
        }
    }

    pub fn n_contexts(&self) -> usize {
        self.ucbs.len()
    }

    /// The bandit for one context (convergence reporting, tests).
    pub fn ucb(&self, context: usize) -> &Ucb {
        &self.ucbs[context.min(self.ucbs.len() - 1)]
    }

    /// Serving-path API: pick the next split layer (1-based) for the context
    /// observed at decision time.
    pub fn choose_split(&mut self, context: usize) -> usize {
        let i = context.min(self.ucbs.len() - 1);
        self.ucbs[i].choose() + 1
    }

    /// Serving-path API: credit the realised reward to the (context, split)
    /// pair observed at decision time.
    pub fn record(&mut self, context: usize, split_1based: usize, reward: f64) {
        let i = context.min(self.ucbs.len() - 1);
        self.ucbs[i].update(split_1based - 1, reward);
    }

    /// Per-context arm statistics `(pulls, mean reward)` — outer index is
    /// the context id.
    pub fn per_context_arms(&self) -> Vec<Vec<(u64, f64)>> {
        self.ucbs
            .iter()
            .map(|u| (0..u.k()).map(|i| (u.arm(i).n, u.arm(i).q)).collect())
            .collect()
    }

    /// Context-aggregated summary in the shape `Service::bandit_summary`
    /// reports: per arm, total pulls across contexts and the pull-weighted
    /// mean reward, plus the 1-based arm with the most total pulls (the
    /// "best" split has no single answer under a shifting context — modal
    /// play is the honest aggregate).
    pub fn aggregate_summary(&self) -> (usize, Vec<(u64, f64)>) {
        let k = self.ucbs[0].k();
        let mut arms = vec![(0u64, 0.0f64); k];
        for u in &self.ucbs {
            for (i, arm) in arms.iter_mut().enumerate() {
                let a = u.arm(i);
                arm.0 += a.n;
                arm.1 += a.q * a.n as f64;
            }
        }
        for arm in &mut arms {
            if arm.0 > 0 {
                arm.1 /= arm.0 as f64;
            }
        }
        let modal = arms
            .iter()
            .enumerate()
            .max_by_key(|(_, (n, _))| *n)
            .map(|(i, _)| i + 1)
            .unwrap_or(1);
        (modal, arms)
    }

    /// Forget all learned state, every context.
    pub fn reset(&mut self) {
        for u in &mut self.ucbs {
            u.reset();
        }
    }

    /// Learned state for snapshot persistence: every context's bandit table.
    pub fn export_state(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![(
            "contexts",
            crate::util::json::Json::Arr(self.ucbs.iter().map(|u| u.export_state()).collect()),
        )])
    }

    /// Restore state exported by [`ContextualSplitPolicy::export_state`].
    /// The context count must match — a snapshot from a different link
    /// scenario is a configuration mismatch.
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        let contexts = v.get("contexts")?.as_arr()?;
        if contexts.len() != self.ucbs.len() {
            anyhow::bail!(
                "contextual state has {} contexts, this policy has {}",
                contexts.len(),
                self.ucbs.len()
            );
        }
        // validate every context before mutating any, so a bad snapshot
        // cannot leave the policy half-restored
        let mut staged = self.ucbs.clone();
        for (u, state) in staged.iter_mut().zip(contexts) {
            u.import_state(state)?;
        }
        self.ucbs = staged;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contexts_keep_independent_arm_statistics() {
        let mut p = ContextualSplitPolicy::new(4, 2, 0.8, 1.0);
        // pull and reward only in context 0
        for _ in 0..8 {
            let s = p.choose_split(0);
            p.record(0, s, 0.5);
        }
        assert_eq!(p.ucb(0).t, 8);
        assert_eq!(p.ucb(1).t, 0, "context 1 must be untouched");
        for i in 0..4 {
            assert_eq!(p.ucb(1).arm(i).n, 0);
        }
        // context 1 still warm-starts from arm 1 in layer order
        assert_eq!(p.choose_split(1), 1);
    }

    #[test]
    fn per_context_argmax_separates_with_scripted_rewards() {
        // Deterministic reward tables with different argmaxes per context:
        // the policy must converge to each context's own best split.
        let rewards = [
            [0.9f64, 0.5, 0.4, 0.3], // context 0: split 1 optimal
            [0.3, 0.4, 0.5, 0.9],    // context 1: split 4 optimal
        ];
        let mut p = ContextualSplitPolicy::new(4, 2, 0.8, 0.5);
        let mut counts = [[0u64; 4]; 2];
        for round in 0..400 {
            let ctx = round % 2;
            let s = p.choose_split(ctx);
            counts[ctx][s - 1] += 1;
            p.record(ctx, s, rewards[ctx][s - 1]);
        }
        let modal0 = counts[0].iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0 + 1;
        let modal1 = counts[1].iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0 + 1;
        assert_eq!(modal0, 1, "counts {counts:?}");
        assert_eq!(modal1, 4, "counts {counts:?}");
        let (_, arms) = p.aggregate_summary();
        let total: u64 = arms.iter().map(|(n, _)| n).sum();
        assert_eq!(total, 400, "one update per round across contexts");
    }

    #[test]
    fn single_context_matches_plain_splitee_dynamics() {
        use crate::policy::SplitEePolicy;
        let mut a = ContextualSplitPolicy::new(6, 1, 0.8, 1.0);
        let mut b = SplitEePolicy::new(6, 0.8, 1.0);
        for round in 0..100 {
            let sa = a.choose_split(0);
            let sb = b.choose_split();
            assert_eq!(sa, sb, "round {round}");
            let r = ((round * 7) % 10) as f64 / 10.0;
            a.record(0, sa, r);
            b.record(sb, r);
        }
    }

    #[test]
    fn out_of_range_context_clamps_instead_of_panicking() {
        let mut p = ContextualSplitPolicy::new(3, 2, 0.8, 1.0);
        let s = p.choose_split(99);
        p.record(99, s, 0.1);
        assert_eq!(p.ucb(1).t, 1, "clamped to the last context");
    }

    #[test]
    fn zero_contexts_clamps_to_one() {
        let p = ContextualSplitPolicy::new(3, 0, 0.8, 1.0);
        assert_eq!(p.n_contexts(), 1);
    }

    #[test]
    fn state_round_trip_restores_every_context_bit_exactly() {
        let mut p = ContextualSplitPolicy::new(4, 3, 0.8, 1.0);
        for round in 0..60 {
            let ctx = round % 3;
            let s = p.choose_split(ctx);
            p.record(ctx, s, (round as f64) * 0.01 - 0.2);
        }
        let state = p.export_state();
        let mut restored = ContextualSplitPolicy::new(4, 3, 0.8, 1.0);
        restored.import_state(&state).unwrap();
        for ctx in 0..3 {
            assert_eq!(restored.ucb(ctx).t, p.ucb(ctx).t);
            for i in 0..4 {
                assert_eq!(restored.ucb(ctx).arm(i).n, p.ucb(ctx).arm(i).n);
                assert_eq!(
                    restored.ucb(ctx).arm(i).q.to_bits(),
                    p.ucb(ctx).arm(i).q.to_bits()
                );
            }
        }
        // and the continued choices match
        for ctx in 0..3 {
            assert_eq!(restored.choose_split(ctx), p.choose_split(ctx));
        }
    }

    #[test]
    fn import_rejects_context_mismatch_without_partial_restore() {
        let mut p = ContextualSplitPolicy::new(4, 2, 0.8, 1.0);
        for _ in 0..10 {
            let s = p.choose_split(0);
            p.record(0, s, 0.5);
        }
        let state = p.export_state();
        let mut wrong = ContextualSplitPolicy::new(4, 3, 0.8, 1.0);
        assert!(wrong.import_state(&state).is_err());
        for ctx in 0..3 {
            assert_eq!(wrong.ucb(ctx).t, 0, "context {ctx} must stay cold");
        }
        // a valid envelope with one corrupted context also leaves no trace
        let mut corrupt = state.clone();
        if let crate::util::json::Json::Obj(o) = &mut corrupt {
            if let Some(crate::util::json::Json::Arr(cs)) = o.get_mut("contexts") {
                cs[1] = crate::util::json::Json::Str("garbage".into());
            }
        }
        let mut target = ContextualSplitPolicy::new(4, 2, 0.8, 1.0);
        assert!(target.import_state(&corrupt).is_err());
        assert_eq!(target.ucb(0).t, 0, "no half-restored state");
    }

    #[test]
    fn reset_clears_every_context() {
        let mut p = ContextualSplitPolicy::new(3, 2, 0.8, 1.0);
        for ctx in 0..2 {
            let s = p.choose_split(ctx);
            p.record(ctx, s, 1.0);
        }
        p.reset();
        for ctx in 0..2 {
            assert_eq!(p.ucb(ctx).t, 0);
            assert_eq!(p.ucb(ctx).arm(0).n, 0);
        }
    }
}

//! Paper section 7 (future work) extensions, implemented as first-class
//! policies:
//!
//! * [`AdaptiveThresholdPolicy`] — the exit threshold `alpha` is *learned*
//!   online instead of fixed by offline validation: a small grid of candidate
//!   thresholds forms a second bandit layered over the split-layer bandit.
//! * [`PerSamplePolicy`] — the split is adapted *per sample*: a cheap
//!   difficulty probe (confidence at the first exit) buckets samples, and an
//!   independent UCB learns the best split per bucket.

use super::{Outcome, Policy, SampleView};
use crate::bandit::Ucb;
use crate::cost::CostModel;

/// SplitEE with an online-learned exit threshold (future-work extension 1).
///
/// Two-level bandit: an outer UCB picks `alpha` from a grid, the inner UCB
/// picks the split layer; both update from the same realised reward.
#[derive(Debug, Clone)]
pub struct AdaptiveThresholdPolicy {
    layer_ucb: Ucb,
    alpha_ucb: Ucb,
    alphas: Vec<f64>,
    last_alpha_arm: usize,
}

impl AdaptiveThresholdPolicy {
    pub fn new(n_layers: usize, beta: f64) -> AdaptiveThresholdPolicy {
        let alphas = vec![0.70, 0.80, 0.85, 0.90, 0.95];
        AdaptiveThresholdPolicy {
            layer_ucb: Ucb::new(n_layers, beta),
            alpha_ucb: Ucb::new(alphas.len(), beta),
            alphas,
            last_alpha_arm: 0,
        }
    }

    pub fn current_alpha(&self) -> f64 {
        self.alphas[self.last_alpha_arm]
    }
}

impl Policy for AdaptiveThresholdPolicy {
    fn name(&self) -> String {
        "SplitEE-AT".into()
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let alpha_arm = self.alpha_ucb.choose();
        self.last_alpha_arm = alpha_arm;
        let alpha = self.alphas[alpha_arm];
        let split = self.layer_ucb.choose() + 1;
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= alpha || split == l;
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, false))
        } else {
            (l, true, cm.reward_offload(split, s.conf[l - 1] as f64, false))
        };
        self.layer_ucb.update(split - 1, reward);
        self.alpha_ucb.update(alpha_arm, reward);
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, false),
            reward,
        }
    }

    fn reset(&mut self) {
        self.layer_ucb.reset();
        self.alpha_ucb.reset();
        self.last_alpha_arm = 0;
    }

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// Per-sample adaptive split (future-work extension 2).
///
/// The confidence of the *first* exit is observed for every sample anyway
/// (its head is the cheapest probe: `lambda1 + lambda2`).  Samples are
/// bucketed by that probe confidence, and an independent split-layer UCB is
/// learned per bucket, so "easy-looking" samples can take shallow splits
/// while "hard-looking" samples go deep or offload.
#[derive(Debug, Clone)]
pub struct PerSamplePolicy {
    buckets: Vec<Ucb>,
    /// probe-confidence bucket edges
    edges: Vec<f64>,
    pub alpha: f64,
}

impl PerSamplePolicy {
    pub fn new(n_layers: usize, alpha: f64, beta: f64) -> PerSamplePolicy {
        let edges = vec![0.6, 0.75, 0.9];
        PerSamplePolicy {
            buckets: (0..edges.len() + 1).map(|_| Ucb::new(n_layers, beta)).collect(),
            edges,
            alpha,
        }
    }

    fn bucket_of(&self, probe_conf: f64) -> usize {
        self.edges.iter().take_while(|&&e| probe_conf >= e).count()
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }
}

impl Policy for PerSamplePolicy {
    fn name(&self) -> String {
        "SplitEE-PS".into()
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let probe = s.conf[0] as f64; // layer-1 head is the probe
        let b = self.bucket_of(probe);
        let split = (self.buckets[b].choose() + 1).max(1);
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= self.alpha || split == l;
        // The probe head is an extra lambda2 unless the split *is* layer 1.
        let probe_extra = if split == 1 { 0.0 } else { cm.lambda2 };
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, false) - cm.mu * probe_extra)
        } else {
            (
                l,
                true,
                cm.reward_offload(split, s.conf[l - 1] as f64, false) - cm.mu * probe_extra,
            )
        };
        self.buckets[b].update(split - 1, reward);
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, false) + probe_extra,
            reward,
        }
    }

    fn reset(&mut self) {
        for b in &mut self.buckets {
            b.reset();
        }
    }

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthMix, SynthProfile};
    use crate::util::rng::Rng;

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    fn run<P: Policy>(p: &mut P, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Rng::new(seed);
        let profile = SynthProfile::generate(n, 12, SynthMix::default(), &mut rng);
        let ent = vec![0.0f32; 12];
        let c = cm();
        let mut cost = 0.0;
        let mut acc = 0.0;
        for i in 0..profile.len() {
            let s = SampleView { conf: &profile.conf[i], ent: &ent };
            let o = p.decide(&s, &c);
            cost += o.cost;
            if profile.correct[i][o.infer_layer - 1] {
                acc += 1.0;
            }
        }
        (acc / n as f64, cost / n as f64)
    }

    #[test]
    fn adaptive_threshold_runs_and_learns() {
        let mut p = AdaptiveThresholdPolicy::new(12, 1.0);
        let (acc, cost) = run(&mut p, 4000, 11);
        assert!(acc > 0.7, "acc {acc}");
        assert!(cost < 12.0, "cost {cost}");
        assert!((0.5..=1.0).contains(&p.current_alpha()));
    }

    #[test]
    fn per_sample_buckets_split_independently() {
        let mut p = PerSamplePolicy::new(12, 0.85, 1.0);
        assert_eq!(p.n_buckets(), 4);
        assert_eq!(p.bucket_of(0.5), 0);
        assert_eq!(p.bucket_of(0.65), 1);
        assert_eq!(p.bucket_of(0.8), 2);
        assert_eq!(p.bucket_of(0.95), 3);
        let (acc, cost) = run(&mut p, 4000, 13);
        assert!(acc > 0.7, "acc {acc}");
        assert!(cost < 12.0, "cost {cost}");
    }

    #[test]
    fn per_sample_cheaper_than_final_exit_on_easy_heavy_mix() {
        let mut rng = Rng::new(17);
        let profile = SynthProfile::generate(
            3000,
            12,
            SynthMix { easy: 0.8, medium: 0.1, hard: 0.05, trap: 0.05 },
            &mut rng,
        );
        let ent = vec![0.0f32; 12];
        let c = cm();
        let mut p = PerSamplePolicy::new(12, 0.85, 1.0);
        let mut cost = 0.0;
        for i in 0..profile.len() {
            let s = SampleView { conf: &profile.conf[i], ent: &ent };
            cost += p.decide(&s, &c).cost;
        }
        let mean = cost / profile.len() as f64;
        assert!(mean < 0.6 * c.final_exit_cost(), "mean cost {mean}");
    }

    #[test]
    fn reset_clears_all_buckets() {
        let mut p = PerSamplePolicy::new(12, 0.85, 1.0);
        let conf = vec![0.9f32; 12];
        let ent = vec![0.0f32; 12];
        let c = cm();
        for _ in 0..30 {
            p.decide(&SampleView { conf: &conf, ent: &ent }, &c);
        }
        p.reset();
        for b in 0..p.n_buckets() {
            assert_eq!(p.buckets[b].t, 0);
        }
    }
}

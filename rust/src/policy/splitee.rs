//! SplitEE (Algorithm 1) and SplitEE-S (section 4.2): UCB over split layers
//! with the exit-or-offload rule at the chosen layer.

use super::{Outcome, Policy, SampleView};
use crate::bandit::Ucb;
use crate::cost::CostModel;

/// SplitEE: inference only at the chosen split layer (cost `lambda1*i +
/// lambda2`); one arm updated per sample.
#[derive(Debug, Clone)]
pub struct SplitEePolicy {
    ucb: Ucb,
    /// exit threshold alpha (calibrated on source validation data)
    pub alpha: f64,
}

impl SplitEePolicy {
    pub fn new(n_layers: usize, alpha: f64, beta: f64) -> SplitEePolicy {
        SplitEePolicy { ucb: Ucb::new(n_layers, beta), alpha }
    }

    /// Access to the bandit state (used by the live serving coordinator and
    /// by convergence reporting).
    pub fn ucb(&self) -> &Ucb {
        &self.ucb
    }

    /// Serving-path API: pick the next split layer (1-based).
    pub fn choose_split(&mut self) -> usize {
        self.ucb.choose() + 1
    }

    /// Serving-path API: record the realised reward for a split layer.
    pub fn record(&mut self, split_1based: usize, reward: f64) {
        self.ucb.update(split_1based - 1, reward);
    }

    /// Learned state for snapshot persistence: the bandit table.
    pub fn export_state(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj(vec![("ucb", self.ucb.export_state())])
    }

    /// Restore state exported by [`SplitEePolicy::export_state`].
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        self.ucb.import_state(v.get("ucb")?)
    }
}

impl Policy for SplitEePolicy {
    fn name(&self) -> String {
        "SplitEE".into()
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let split = self.ucb.choose() + 1; // 1-based
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= self.alpha || split == l;
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, false))
        } else {
            let conf_l = s.conf[l - 1] as f64;
            (l, true, cm.reward_offload(split, conf_l, false))
        };
        self.ucb.update(split - 1, reward);
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, false),
            reward,
        }
    }

    fn reset(&mut self) {
        self.ucb.reset();
    }

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

/// SplitEE-S: evaluates every exit head up to the chosen split layer and
/// updates all those arms from side observations (cost `lambda*i`).
#[derive(Debug, Clone)]
pub struct SplitEeSPolicy {
    ucb: Ucb,
    pub alpha: f64,
    /// running mean of observed final-layer confidence — used to impute
    /// C_L for side-arm updates when the actual sample exited on-device and
    /// the final layer was therefore never computed.  With cached profiles
    /// (the paper's offline-logit evaluation) the true C_L is always
    /// available and this estimate is unused.
    mean_conf_final: f64,
    n_conf_final: u64,
}

impl SplitEeSPolicy {
    pub fn new(n_layers: usize, alpha: f64, beta: f64) -> SplitEeSPolicy {
        SplitEeSPolicy { ucb: Ucb::new(n_layers, beta), alpha, mean_conf_final: 0.9, n_conf_final: 0 }
    }

    pub fn ucb(&self) -> &Ucb {
        &self.ucb
    }

    pub fn choose_split(&mut self) -> usize {
        self.ucb.choose() + 1
    }

    /// Serving-path update: confidences for layers `1..=split` plus the
    /// final-layer confidence if it was observed (offload happened).
    pub fn record_prefix(
        &mut self,
        cm: &CostModel,
        conf_prefix: &[f32],
        conf_final: Option<f64>,
    ) {
        if let Some(cl) = conf_final {
            self.n_conf_final += 1;
            self.mean_conf_final += (cl - self.mean_conf_final) / self.n_conf_final as f64;
        }
        let l = self.ucb.k();
        for (j0, &cj) in conf_prefix.iter().enumerate() {
            let layer = j0 + 1;
            let cj = cj as f64;
            let r = if cj >= self.alpha || layer == l {
                cm.reward_exit(layer, cj, true)
            } else {
                let cl = conf_final.unwrap_or(self.mean_conf_final);
                cm.reward_offload(layer, cl, true)
            };
            self.ucb.update(j0, r);
        }
    }

    /// Learned state for snapshot persistence: the bandit table plus the
    /// imputed-C_L running mean (a cost-model running statistic — losing it
    /// would bias every post-restart side-arm update).
    pub fn export_state(&self) -> crate::util::json::Json {
        use crate::persist::{f64_hex, u64_hex};
        crate::util::json::Json::obj(vec![
            ("ucb", self.ucb.export_state()),
            ("mean_conf_final", f64_hex(self.mean_conf_final)),
            ("n_conf_final", u64_hex(self.n_conf_final)),
        ])
    }

    /// Restore state exported by [`SplitEeSPolicy::export_state`].
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        use crate::persist::{f64_from_hex, u64_from_hex};
        let mean = f64_from_hex(v.get("mean_conf_final")?)?;
        let n = u64_from_hex(v.get("n_conf_final")?)?;
        self.ucb.import_state(v.get("ucb")?)?;
        self.mean_conf_final = mean;
        self.n_conf_final = n;
        Ok(())
    }
}

impl Policy for SplitEeSPolicy {
    fn name(&self) -> String {
        "SplitEE-S".into()
    }

    fn uses_side_info(&self) -> bool {
        true
    }

    fn decide(&mut self, s: &SampleView<'_>, cm: &CostModel) -> Outcome {
        let l = s.n_layers();
        let split = self.ucb.choose() + 1;
        let conf_i = s.conf[split - 1] as f64;
        let exited = conf_i >= self.alpha || split == l;
        let conf_l = s.conf[l - 1] as f64;
        let (infer_layer, offloaded, reward) = if exited {
            (split, false, cm.reward_exit(split, conf_i, true))
        } else {
            (l, true, cm.reward_offload(split, conf_l, true))
        };
        // Side observations: cached profiles expose the true C_L, matching
        // the paper's offline-logit evaluation.
        self.record_prefix(cm, &s.conf[..split], Some(conf_l));
        Outcome {
            split,
            infer_layer,
            offloaded,
            cost: cm.total_cost(split, offloaded, true),
            reward,
        }
    }

    fn reset(&mut self) {
        self.ucb.reset();
        self.mean_conf_final = 0.9;
        self.n_conf_final = 0;
    }

    fn clone_box(&self) -> Box<dyn Policy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthMix, SynthProfile};
    use crate::policy::oracle_split;
    use crate::util::rng::Rng;

    fn cm() -> CostModel {
        CostModel::paper(5.0, 0.1, 12)
    }

    fn run_policy<P: Policy>(p: &mut P, profile: &SynthProfile, cm: &CostModel) -> Vec<Outcome> {
        let ent_dummy = vec![0.0f32; profile.n_layers];
        (0..profile.len())
            .map(|i| {
                let s = SampleView { conf: &profile.conf[i], ent: &ent_dummy };
                p.decide(&s, cm)
            })
            .collect()
    }

    #[test]
    fn splitee_explores_all_arms_then_converges() {
        let mut rng = Rng::new(1);
        let profile = SynthProfile::generate(4000, 12, SynthMix::default(), &mut rng);
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        let outcomes = run_policy(&mut p, &profile, &cm());
        // warm start: first 12 samples hit each layer once
        let mut first: Vec<usize> = outcomes[..12].iter().map(|o| o.split).collect();
        first.sort_unstable();
        assert_eq!(first, (1..=12).collect::<Vec<_>>());
        // convergence: the modal split over the last quarter dominates
        let last = &outcomes[3000..];
        let mut counts = [0usize; 13];
        for o in last {
            counts[o.split] += 1;
        }
        // the top-2 arms must dominate the last quarter of the stream
        let mut sorted: Vec<usize> = counts.to_vec();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert!(sorted[0] + sorted[1] > last.len() / 2, "counts {counts:?}");
    }

    #[test]
    fn splitee_converges_near_oracle() {
        let mut rng = Rng::new(5);
        let profile = SynthProfile::generate(8000, 12, SynthMix::default(), &mut rng);
        let profiles: Vec<(Vec<f32>, Vec<f32>)> = profile
            .conf
            .iter()
            .map(|c| (c.clone(), vec![0.0f32; 12]))
            .collect();
        let c = cm();
        let (oracle, means) = oracle_split(&profiles, &c, 0.85, false);
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        let outcomes = run_policy(&mut p, &profile, &c);
        let last = &outcomes[6000..];
        let mut counts = vec![0usize; 13];
        for o in last {
            counts[o.split] += 1;
        }
        let modal = counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
        // the modal arm's mean reward must be within a small gap of optimal
        let gap = means[oracle - 1] - means[modal - 1];
        assert!(gap < 0.05, "oracle {oracle} modal {modal} gap {gap}");
    }

    #[test]
    fn splitee_s_updates_prefix_arms() {
        let mut p = SplitEeSPolicy::new(12, 0.85, 1.0);
        let conf: Vec<f32> = (0..12).map(|i| 0.5 + 0.04 * i as f32).collect();
        let ent = vec![0.0f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let o = p.decide(&s, &cm());
        // every arm <= split has one update
        for j in 0..o.split {
            assert_eq!(p.ucb().arm(j).n, 1, "arm {j}");
        }
        for j in o.split..12 {
            assert_eq!(p.ucb().arm(j).n, 0, "arm {j}");
        }
    }

    #[test]
    fn splitee_s_converges_faster_than_splitee() {
        // The paper's figure-7 claim: side info accelerates convergence.
        // Proxy: after the same number of samples, SplitEE-S has more total
        // arm updates and its modal choice stabilises at least as well.
        let mut rng = Rng::new(9);
        let profile = SynthProfile::generate(1500, 12, SynthMix::default(), &mut rng);
        let c = cm();
        let mut a = SplitEePolicy::new(12, 0.85, 1.0);
        let mut b = SplitEeSPolicy::new(12, 0.85, 1.0);
        run_policy(&mut a, &profile, &c);
        run_policy(&mut b, &profile, &c);
        let updates_a: u64 = (0..12).map(|i| a.ucb().arm(i).n).sum();
        let updates_b: u64 = (0..12).map(|i| b.ucb().arm(i).n).sum();
        assert!(updates_b > updates_a * 2, "a={updates_a} b={updates_b}");
    }

    #[test]
    fn cost_accounting_matches_variant() {
        let c = cm();
        let conf = vec![0.95f32; 12];
        let ent = vec![0.0f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let mut a = SplitEePolicy::new(12, 0.85, 1.0);
        let mut b = SplitEeSPolicy::new(12, 0.85, 1.0);
        let oa = a.decide(&s, &c);
        let ob = b.decide(&s, &c);
        assert!((oa.cost - c.total_cost(oa.split, false, false)).abs() < 1e-12);
        assert!((ob.cost - c.total_cost(ob.split, false, true)).abs() < 1e-12);
    }

    #[test]
    fn offload_outcome_uses_final_layer() {
        let c = cm();
        let mut conf = vec![0.5f32; 12];
        conf[11] = 0.99;
        let ent = vec![0.0f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let mut p = SplitEePolicy::new(12, 0.9, 1.0);
        // first choice is layer 1 (warm start) -> conf 0.5 < alpha -> offload
        let o = p.decide(&s, &c);
        assert_eq!(o.split, 1);
        assert!(o.offloaded);
        assert_eq!(o.infer_layer, 12);
    }

    #[test]
    fn splitee_state_round_trip_continues_identically() {
        let mut rng = Rng::new(21);
        let profile = SynthProfile::generate(200, 12, SynthMix::default(), &mut rng);
        let c = cm();
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        run_policy(&mut p, &profile, &c);
        let mut restored = SplitEePolicy::new(12, 0.85, 1.0);
        restored.import_state(&p.export_state()).unwrap();
        // the continued decision streams must be bit-identical
        let tail = SynthProfile::generate(50, 12, SynthMix::default(), &mut rng);
        let a = run_policy(&mut p, &tail, &c);
        let b = run_policy(&mut restored, &tail, &c);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.split, x.offloaded), (y.split, y.offloaded));
            assert_eq!(x.reward.to_bits(), y.reward.to_bits());
        }
    }

    #[test]
    fn splitee_s_state_round_trip_preserves_imputed_mean() {
        let mut p = SplitEeSPolicy::new(12, 0.85, 1.0);
        let c = cm();
        p.record_prefix(&c, &[0.5, 0.6, 0.7], Some(0.971));
        p.record_prefix(&c, &[0.4], None);
        let state = p.export_state();
        let mut restored = SplitEeSPolicy::new(12, 0.85, 1.0);
        restored.import_state(&state).unwrap();
        assert_eq!(restored.n_conf_final, p.n_conf_final);
        assert_eq!(restored.mean_conf_final.to_bits(), p.mean_conf_final.to_bits());
        for j in 0..12 {
            assert_eq!(restored.ucb().arm(j).n, p.ucb().arm(j).n);
            assert_eq!(restored.ucb().arm(j).q.to_bits(), p.ucb().arm(j).q.to_bits());
        }
        // forward compat: unknown fields in the state blob are ignored
        let mut extended = state.clone();
        if let crate::util::json::Json::Obj(o) = &mut extended {
            o.insert("future".into(), crate::util::json::Json::Bool(true));
        }
        assert!(restored.import_state(&extended).is_ok());
        // mismatched arm count is rejected without mutating the target
        let mut wrong = SplitEeSPolicy::new(5, 0.85, 1.0);
        assert!(wrong.import_state(&state).is_err());
        assert_eq!(wrong.ucb().t, 0);
    }

    #[test]
    fn reset_restores_warm_start() {
        let mut p = SplitEePolicy::new(12, 0.85, 1.0);
        let conf = vec![0.9f32; 12];
        let ent = vec![0.0f32; 12];
        let s = SampleView { conf: &conf, ent: &ent };
        let c = cm();
        for _ in 0..20 {
            p.decide(&s, &c);
        }
        p.reset();
        assert_eq!(p.ucb().t, 0);
        let o = p.decide(&s, &c);
        assert_eq!(o.split, 1); // warm start restarts at layer 1
    }
}

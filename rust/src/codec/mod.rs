//! Split-boundary payload codecs: shrink the offload uplink.
//!
//! The offload cost `o` dominates the accuracy/compute/communication
//! tradeoff the split policy optimizes over, and the uplink payload is by
//! default a raw f32 copy of the hidden state at the split layer.  This
//! module provides the **codec seam** that sits exactly at that boundary:
//! the cloud stage encodes each offloaded row before "transmission", the
//! link simulator charges the transfer from the *encoded* bytes, and the
//! replica decodes before running the continuation — so the cloud model
//! consumes exactly what the (possibly lossy) uplink delivered.
//!
//! Codecs (`--codecs`, [`CodecSpec::from_name`]):
//!
//! * `identity` — raw little-endian f32; **bit-transparent** end to end
//!   (the decoded row is bit-identical to the input), so the default menu
//!   `[identity]` reproduces the pre-codec service exactly;
//! * `f16` — IEEE 754 binary16 truncation (round-to-nearest-even), 2 bytes
//!   per element;
//! * `i8` — per-row absmax quantization: one f32 scale (the row's max
//!   absolute value) plus one signed byte per element;
//! * `topk:<k>` — magnitude sparsification: the `k` largest-|x| entries
//!   per row (ties broken toward the lowest index) stored exactly as
//!   `(u32 index, f32 value)` pairs, the rest reconstructed as zero;
//! * `dedup:<inner>` — a content-addressed chunk cache layered over any of
//!   the above: the inner encoding is cut into fixed [`DEDUP_CHUNK`]-byte
//!   chunks, each chunk hashed (FNV-1a 64), and a chunk already in the
//!   shared store ships as a 9-byte reference instead of its bytes
//!   ([`DedupCache`], hit/miss/byte counters).
//!
//! A "row" is one sample's flattened `[seq_len * d_model]` hidden-state
//! slice — quantization scales are per sample, never shared across a
//! batch, so batch composition cannot change any row's numerics.
//!
//! The bandit policies learn over a `(split, codec)` action menu
//! ([`CodecMenu`]); with the default single-entry menu the arm space — and
//! therefore every decision — is identical to the codec-less service.
//! Because non-identity codecs perturb the numerics, every codec is pinned
//! by round-trip property tests (`tests/codec.rs`) and evaluated by the
//! accuracy-drift harness (`splitee codec-drift`,
//! [`crate::experiments::codec_drift`]) before the bandits may learn over
//! it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

/// Fixed per-transfer framing overhead the link simulator adds on top of
/// the payload (matches `LinkSim::activation_payload`'s `+ 64`).  Codec
/// byte accounting (and the `codec_*_uplink_ratio` bench keys) is defined
/// on the payload *excluding* this header; the transfer itself is charged
/// with it.
pub const FRAME_OVERHEAD: usize = 64;

/// Dedup chunk size in bytes.  Small enough that repeated rows (and
/// repeated zero runs from sparsified payloads) dedup well, large enough
/// that a 9-byte chunk reference is a real saving.
pub const DEDUP_CHUNK: usize = 64;

/// One encoded row: the wire bytes plus the codec-output size *before*
/// dedup (equal to `bytes.len()` for non-dedup codecs).  Metrics account
/// `encoded_bytes` from `encoded_len` (pure codec compression — this is
/// what the `encoded_bytes <= raw_bytes` invariant is stated over) and
/// `deduped_bytes` from `encoded_len - bytes.len()` (chunk-cache savings,
/// which depend on traffic history and may be zero).
#[derive(Debug, Clone)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    pub encoded_len: usize,
}

/// A split-boundary payload codec.  Implementations must be deterministic:
/// the same row always encodes to the same bytes (the dedup layer's
/// *savings* depend on cache history, but its decode is bit-identical to
/// the inner codec's for any history — pinned by `tests/codec.rs`).
pub trait PayloadCodec: Send + Sync {
    /// Stable name; round-trips through [`CodecSpec::from_name`].
    fn name(&self) -> String;

    /// Encode one sample row (the flattened `[seq_len * d_model]` slice).
    fn encode(&self, row: &[f32]) -> Encoded;

    /// Decode back to exactly `n` f32 values.
    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>>;

    /// Deterministic encoded payload size for a row of `n` f32s, before
    /// dedup (dedup savings are traffic-dependent and deliberately do not
    /// enter the reward — see [`PayloadCodec::nominal_ratio`]).
    fn nominal_encoded_len(&self, n: usize) -> usize;

    /// Deterministic raw/encoded payload ratio for a row of `n` f32s.
    /// This — not the measured wire bytes — scales the offload cost `o`
    /// in the reward, so rewards stay a pure function of the decision
    /// sequence and pipelined serving remains decision-identical to
    /// serial replay.  Exactly `1.0` for the identity codec.
    fn nominal_ratio(&self, n: usize) -> f64 {
        if n == 0 {
            return 1.0;
        }
        (4 * n) as f64 / self.nominal_encoded_len(n).max(1) as f64
    }

    /// True when decode(encode(row)) is bit-identical to `row` for every
    /// input.  Only bit-transparent codecs may adopt speculative cloud
    /// results (speculation runs on the *unencoded* activation; see
    /// `coordinator::replicas`).
    fn bit_transparent(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// identity

/// Raw little-endian f32 passthrough — the bit-transparent reference codec.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl PayloadCodec for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn encode(&self, row: &[f32]) -> Encoded {
        let mut bytes = Vec::with_capacity(4 * row.len());
        for &x in row {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let encoded_len = bytes.len();
        Encoded { bytes, encoded_len }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        if bytes.len() != 4 * n {
            bail!("identity payload is {} bytes, want {}", bytes.len(), 4 * n);
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn nominal_encoded_len(&self, n: usize) -> usize {
        4 * n
    }

    fn bit_transparent(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// f16

/// Convert f32 to IEEE 754 binary16 bits, round-to-nearest-even.  NaN maps
/// to a canonical quiet NaN; overflow rounds to infinity per the standard.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs > 0x7f80_0000 {
        return sign | 0x7e00; // NaN (canonical quiet payload)
    }
    if abs >= 0x4780_0000 {
        // |x| >= 65536: past the largest finite f16 even after rounding
        return sign | 0x7c00;
    }
    if abs >= 0x3880_0000 {
        // normal range (|x| >= 2^-14); f16 exponent lands in 1..=30
        let exp = ((abs >> 23) as i32) - 127 + 15;
        let mant = abs & 0x007f_ffff;
        let mut h = ((exp as u32) << 10) | (mant >> 13);
        let round = mant & 0x1fff;
        if round > 0x1000 || (round == 0x1000 && (h & 1) == 1) {
            h += 1; // mantissa carry may bump the exponent — that IS the
                    // correct rounding, up to and including overflow to inf
        }
        return sign | h as u16;
    }
    if abs < 0x3300_0000 {
        // |x| < 2^-25: underflows to (signed) zero under RNE
        return sign;
    }
    // subnormal: value = mant' * 2^(exp-150), f16 subnormal unit is 2^-24
    let exp = (abs >> 23) as i32;
    let mant = (abs & 0x007f_ffff) | 0x0080_0000;
    let shift = 126 - exp; // 14..=24 in this branch
    let mut h = mant >> shift;
    let dropped = mant & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if dropped > half || (dropped == half && (h & 1) == 1) {
        h += 1; // may carry into the smallest normal — still well-formed
    }
    sign | h as u16
}

/// Convert IEEE 754 binary16 bits to f32 (exact — every f16 value is
/// representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    if exp == 0 {
        // subnormal (or zero): mant * 2^-24, exact in f32
        let v = mant as f32 * f32::from_bits(0x3380_0000);
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (mant << 13))
}

/// IEEE 754 binary16 truncation: 2 bytes per element.  Relative error is
/// bounded by 2^-11 for values in the f16 normal range.
#[derive(Debug, Clone, Copy, Default)]
pub struct F16;

impl PayloadCodec for F16 {
    fn name(&self) -> String {
        "f16".into()
    }

    fn encode(&self, row: &[f32]) -> Encoded {
        let mut bytes = Vec::with_capacity(2 * row.len());
        for &x in row {
            bytes.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
        let encoded_len = bytes.len();
        Encoded { bytes, encoded_len }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        if bytes.len() != 2 * n {
            bail!("f16 payload is {} bytes, want {}", bytes.len(), 2 * n);
        }
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect())
    }

    fn nominal_encoded_len(&self, n: usize) -> usize {
        2 * n
    }
}

// ---------------------------------------------------------------------------
// i8

/// Per-row absmax quantization: one f32 scale (the row's max |x|) plus one
/// signed byte per element.  Absolute error is bounded by `absmax / 127`
/// per element (half a quantization step plus float rounding).
#[derive(Debug, Clone, Copy, Default)]
pub struct I8;

impl PayloadCodec for I8 {
    fn name(&self) -> String {
        "i8".into()
    }

    fn encode(&self, row: &[f32]) -> Encoded {
        let absmax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut bytes = Vec::with_capacity(4 + row.len());
        bytes.extend_from_slice(&absmax.to_le_bytes());
        if absmax > 0.0 {
            let inv = 127.0 / absmax;
            for &x in row {
                let q = (x * inv).round().clamp(-127.0, 127.0) as i8;
                bytes.push(q as u8);
            }
        } else {
            bytes.resize(4 + row.len(), 0);
        }
        let encoded_len = bytes.len();
        Encoded { bytes, encoded_len }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        if bytes.len() != 4 + n {
            bail!("i8 payload is {} bytes, want {}", bytes.len(), 4 + n);
        }
        let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        let step = scale / 127.0;
        Ok(bytes[4..].iter().map(|&b| (b as i8) as f32 * step).collect())
    }

    fn nominal_encoded_len(&self, n: usize) -> usize {
        4 + n
    }
}

// ---------------------------------------------------------------------------
// top-k

/// Magnitude sparsification: keep the `k` largest-|x| entries of the row
/// (ties broken toward the lowest index), stored exactly as
/// `(u32 index, f32 value)` pairs; everything else reconstructs as zero.
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub k: usize,
}

impl PayloadCodec for TopK {
    fn name(&self) -> String {
        format!("topk:{}", self.k)
    }

    fn encode(&self, row: &[f32]) -> Encoded {
        let m = self.k.min(row.len());
        let mut order: Vec<usize> = (0..row.len()).collect();
        // total order: |x| descending, index ascending on ties — fully
        // deterministic, independent of the sort algorithm
        order.sort_by(|&a, &b| {
            row[b].abs().total_cmp(&row[a].abs()).then(a.cmp(&b))
        });
        let mut kept: Vec<usize> = order[..m].to_vec();
        kept.sort_unstable(); // canonical wire order
        let mut bytes = Vec::with_capacity(4 + 8 * m);
        bytes.extend_from_slice(&(m as u32).to_le_bytes());
        for &i in &kept {
            bytes.extend_from_slice(&(i as u32).to_le_bytes());
            bytes.extend_from_slice(&row[i].to_le_bytes());
        }
        let encoded_len = bytes.len();
        Encoded { bytes, encoded_len }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        if bytes.len() < 4 {
            bail!("topk payload too short ({} bytes)", bytes.len());
        }
        let m = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        if bytes.len() != 4 + 8 * m {
            bail!("topk payload is {} bytes, want {} for {m} entries", bytes.len(), 4 + 8 * m);
        }
        let mut out = vec![0.0f32; n];
        for e in bytes[4..].chunks_exact(8) {
            let i = u32::from_le_bytes([e[0], e[1], e[2], e[3]]) as usize;
            if i >= n {
                bail!("topk entry index {i} out of range (row has {n} values)");
            }
            out[i] = f32::from_le_bytes([e[4], e[5], e[6], e[7]]);
        }
        Ok(out)
    }

    fn nominal_encoded_len(&self, n: usize) -> usize {
        4 + 8 * self.k.min(n)
    }
}

// ---------------------------------------------------------------------------
// content-addressed dedup layer

/// Shared dedup lifecycle counters (atomics — the pool's `PoolCounters`
/// pattern): one instance is shared between the cache and
/// `ServingMetrics`, so the report survives the cache.  The structural
/// invariant `hits + misses == chunks` holds at every instant.
#[derive(Debug, Default)]
pub struct DedupCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub chunks: AtomicU64,
    /// payload bytes replaced by chunk references (gross savings, before
    /// the 9-byte reference overhead — net wire savings are what
    /// `ServingMetrics::deduped_bytes` accounts)
    pub hit_bytes: AtomicU64,
}

impl DedupCounters {
    pub fn new() -> Arc<DedupCounters> {
        Arc::new(DedupCounters::default())
    }

    /// Consistent-enough snapshot `(hits, misses, chunks, hit_bytes)`:
    /// hits and misses are loaded before chunks, so a mid-encode read can
    /// never show `hits + misses > chunks`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        let hits = self.hits.load(Ordering::Acquire);
        let misses = self.misses.load(Ordering::Acquire);
        let chunks = self.chunks.load(Ordering::Acquire);
        let hit_bytes = self.hit_bytes.load(Ordering::Acquire);
        (hits, misses, chunks, hit_bytes.min(u64::MAX))
    }
}

/// Content-addressed chunk store shared by every `dedup:*` codec built
/// from one [`CodecMenu::build`] call (and by encode/decode sides — a
/// reference is only ever emitted for a chunk the store already holds, so
/// decode always resolves).
#[derive(Clone)]
pub struct DedupCache {
    store: Arc<Mutex<HashMap<u64, Vec<u8>>>>,
    pub counters: Arc<DedupCounters>,
}

impl Default for DedupCache {
    fn default() -> Self {
        DedupCache::new()
    }
}

impl DedupCache {
    pub fn new() -> DedupCache {
        DedupCache {
            store: Arc::new(Mutex::new(HashMap::new())),
            counters: DedupCounters::new(),
        }
    }

    /// Chunks currently resident in the store.
    pub fn resident(&self) -> usize {
        self.store.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

/// FNV-1a 64-bit hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const DEDUP_TAG_LITERAL: u8 = 0;
const DEDUP_TAG_REF: u8 = 1;

/// The dedup layer: wraps any inner codec, cutting its output into
/// [`DEDUP_CHUNK`]-byte chunks and shipping repeats as 9-byte references.
/// Wire format: `u32 inner_len` then, per chunk in order, either
/// `0x00 + chunk bytes` (literal; length implied by position) or
/// `0x01 + u64 hash` (reference into the shared store).
pub struct DedupCodec {
    pub inner: Arc<dyn PayloadCodec>,
    pub cache: DedupCache,
}

impl PayloadCodec for DedupCodec {
    fn name(&self) -> String {
        format!("dedup:{}", self.inner.name())
    }

    fn encode(&self, row: &[f32]) -> Encoded {
        let inner = self.inner.encode(row);
        let encoded_len = inner.encoded_len;
        let payload = inner.bytes;
        let mut bytes = Vec::with_capacity(4 + payload.len() + payload.len() / DEDUP_CHUNK + 1);
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let mut store = self.cache.store.lock().unwrap_or_else(|p| p.into_inner());
        let c = &self.cache.counters;
        for chunk in payload.chunks(DEDUP_CHUNK) {
            c.chunks.fetch_add(1, Ordering::AcqRel);
            let h = fnv1a64(chunk);
            match store.get(&h) {
                // a hash collision (same hash, different bytes) degrades
                // to a literal — correctness never rests on the hash
                Some(stored) if stored == chunk => {
                    c.hits.fetch_add(1, Ordering::AcqRel);
                    c.hit_bytes.fetch_add(chunk.len() as u64, Ordering::AcqRel);
                    bytes.push(DEDUP_TAG_REF);
                    bytes.extend_from_slice(&h.to_le_bytes());
                }
                _ => {
                    c.misses.fetch_add(1, Ordering::AcqRel);
                    if !store.contains_key(&h) {
                        store.insert(h, chunk.to_vec());
                    }
                    bytes.push(DEDUP_TAG_LITERAL);
                    bytes.extend_from_slice(chunk);
                }
            }
        }
        Encoded { bytes, encoded_len }
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        if bytes.len() < 4 {
            bail!("dedup payload too short ({} bytes)", bytes.len());
        }
        let inner_len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        let mut payload = Vec::with_capacity(inner_len);
        let mut pos = 4usize;
        let store = self.cache.store.lock().unwrap_or_else(|p| p.into_inner());
        while payload.len() < inner_len {
            let chunk_len = DEDUP_CHUNK.min(inner_len - payload.len());
            let tag = *bytes
                .get(pos)
                .ok_or_else(|| anyhow::anyhow!("dedup payload truncated at chunk tag"))?;
            pos += 1;
            match tag {
                DEDUP_TAG_LITERAL => {
                    let chunk = bytes
                        .get(pos..pos + chunk_len)
                        .ok_or_else(|| anyhow::anyhow!("dedup literal chunk truncated"))?;
                    payload.extend_from_slice(chunk);
                    pos += chunk_len;
                }
                DEDUP_TAG_REF => {
                    let hb = bytes
                        .get(pos..pos + 8)
                        .ok_or_else(|| anyhow::anyhow!("dedup chunk reference truncated"))?;
                    pos += 8;
                    let h = u64::from_le_bytes([
                        hb[0], hb[1], hb[2], hb[3], hb[4], hb[5], hb[6], hb[7],
                    ]);
                    let chunk = store
                        .get(&h)
                        .ok_or_else(|| anyhow::anyhow!("dedup chunk {h:#x} not in store"))?;
                    if chunk.len() != chunk_len {
                        bail!(
                            "dedup chunk {h:#x} is {} bytes, want {chunk_len}",
                            chunk.len()
                        );
                    }
                    payload.extend_from_slice(chunk);
                }
                other => bail!("dedup payload has unknown chunk tag {other}"),
            }
        }
        if pos != bytes.len() {
            bail!("dedup payload has {} trailing bytes", bytes.len() - pos);
        }
        drop(store);
        self.inner.decode(&payload, n)
    }

    fn nominal_encoded_len(&self, n: usize) -> usize {
        // dedup savings are traffic-dependent; the deterministic size (and
        // therefore the reward) is the inner codec's
        self.inner.nominal_encoded_len(n)
    }

    fn bit_transparent(&self) -> bool {
        // decode is bit-identical to the inner codec for any cache history
        self.inner.bit_transparent()
    }
}

// ---------------------------------------------------------------------------
// spec + menu

/// Parsed codec name — the `--codecs` grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecSpec {
    Identity,
    F16,
    I8,
    TopK(usize),
    Dedup(Box<CodecSpec>),
}

impl CodecSpec {
    /// Parse one codec name: `identity | f16 | i8 | topk:<k> |
    /// dedup:<inner>` (dedup does not nest).
    pub fn from_name(name: &str) -> Result<CodecSpec> {
        match name {
            "identity" => Ok(CodecSpec::Identity),
            "f16" => Ok(CodecSpec::F16),
            "i8" => Ok(CodecSpec::I8),
            other => {
                if let Some(k) = other.strip_prefix("topk:") {
                    let k: usize = k.parse().map_err(|_| {
                        anyhow::anyhow!("topk wants a positive entry count, got {other:?}")
                    })?;
                    if k == 0 {
                        bail!("topk:0 would drop every entry — use a positive k");
                    }
                    return Ok(CodecSpec::TopK(k));
                }
                if let Some(inner) = other.strip_prefix("dedup:") {
                    if inner.starts_with("dedup:") {
                        bail!("dedup does not nest ({other:?})");
                    }
                    return Ok(CodecSpec::Dedup(Box::new(CodecSpec::from_name(inner)?)));
                }
                bail!(
                    "unknown codec {other:?} — accepted: identity, f16, i8, topk:<k>, \
                     dedup:<inner>"
                )
            }
        }
    }

    /// Stable name; `CodecSpec::from_name(&s.name()).unwrap() == s`.
    pub fn name(&self) -> String {
        match self {
            CodecSpec::Identity => "identity".into(),
            CodecSpec::F16 => "f16".into(),
            CodecSpec::I8 => "i8".into(),
            CodecSpec::TopK(k) => format!("topk:{k}"),
            CodecSpec::Dedup(inner) => format!("dedup:{}", inner.name()),
        }
    }

    /// Instantiate the codec.  Every `dedup:*` spec built from the same
    /// `cache` shares one chunk store and one counter set.
    pub fn build(&self, cache: &DedupCache) -> Arc<dyn PayloadCodec> {
        match self {
            CodecSpec::Identity => Arc::new(Identity),
            CodecSpec::F16 => Arc::new(F16),
            CodecSpec::I8 => Arc::new(I8),
            CodecSpec::TopK(k) => Arc::new(TopK { k: *k }),
            CodecSpec::Dedup(inner) => Arc::new(DedupCodec {
                inner: inner.build(cache),
                cache: cache.clone(),
            }),
        }
    }
}

/// The `(split, codec)` action menu's codec axis: an ordered list of codec
/// specs the policy may choose between.  The `Default` — `[identity]` —
/// yields an arm space (and a byte stream) identical to the codec-less
/// service, bit for bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecMenu {
    pub specs: Vec<CodecSpec>,
}

impl Default for CodecMenu {
    fn default() -> Self {
        CodecMenu { specs: vec![CodecSpec::Identity] }
    }
}

impl CodecMenu {
    /// Parse a `--codecs` comma-separated list, e.g.
    /// `identity,f16,i8,topk:64`.  Duplicate entries are rejected — they
    /// would split one action's statistics across two arms.
    pub fn from_list(csv: &str) -> Result<CodecMenu> {
        let mut specs = Vec::new();
        for name in csv.split(',') {
            let name = name.trim();
            if name.is_empty() {
                bail!("--codecs wants a comma-separated codec list, got {csv:?}");
            }
            let spec = CodecSpec::from_name(name)?;
            if specs.contains(&spec) {
                bail!("--codecs lists {name:?} twice");
            }
            specs.push(spec);
        }
        if specs.is_empty() {
            bail!("--codecs wants at least one codec");
        }
        Ok(CodecMenu { specs })
    }

    /// Test-matrix hook: `SPLITEE_CODECS=<csv>` (default `identity` when
    /// unset).  An unparseable value panics — naming the variable, the
    /// rejected value and the accepted grammar — rather than silently
    /// running the identity path under a codec job label.
    pub fn from_env() -> CodecMenu {
        match std::env::var("SPLITEE_CODECS") {
            Ok(v) => match CodecMenu::from_list(&v) {
                Ok(m) => m,
                Err(e) => panic!(
                    "SPLITEE_CODECS={v:?} is invalid ({e:#}) — accepted: a comma-separated \
                     list of identity, f16, i8, topk:<k>, dedup:<inner>"
                ),
            },
            Err(_) => CodecMenu::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Comma-joined names (the fingerprint / report form).
    pub fn names(&self) -> String {
        self.specs.iter().map(|s| s.name()).collect::<Vec<_>>().join(",")
    }

    /// Instantiate every codec in menu order, sharing one dedup chunk
    /// store (returned so its counters can be wired into the metrics even
    /// when no `dedup:*` codec is listed — they simply stay zero).
    pub fn build(&self) -> (Vec<Arc<dyn PayloadCodec>>, DedupCache) {
        let cache = DedupCache::new();
        let codecs = self.specs.iter().map(|s| s.build(&cache)).collect();
        (codecs, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_bits_round_trip_every_finite_half() {
        // every non-NaN f16 value must survive f16 -> f32 -> f16 exactly
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let mant = h & 0x3ff;
            if exp == 0x1f && mant != 0 {
                continue; // NaN payloads canonicalize; skip
            }
            let x = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(x), h, "h={h:#06x} x={x:?}");
        }
    }

    #[test]
    fn f16_conversion_edge_cases() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff, "largest finite f16");
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00, "rounds to +inf");
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00, "overflow to +inf");
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        let nan = f32_to_f16_bits(f32::NAN);
        assert_eq!(nan & 0x7c00, 0x7c00);
        assert_ne!(nan & 0x03ff, 0);
        // smallest subnormal and the underflow edge
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000, "underflow");
        // RNE at the exact halfway point between 1.0 and the next f16
        let half_ulp = f32::from_bits(0x3f80_1000);
        assert_eq!(f32_to_f16_bits(half_ulp), 0x3c00, "ties to even");
    }

    #[test]
    fn i8_zero_row_and_scale() {
        let c = I8;
        let row = vec![0.0f32; 9];
        let e = c.encode(&row);
        assert_eq!(e.bytes.len(), 13);
        assert_eq!(c.decode(&e.bytes, 9).unwrap(), row);
        let row = vec![1.0, -2.0, 0.5];
        let e = c.encode(&row);
        let back = c.decode(&e.bytes, 3).unwrap();
        assert_eq!(back[1], -2.0, "absmax element is exact");
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= 2.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn topk_ties_break_toward_lowest_index() {
        let c = TopK { k: 2 };
        let row = vec![1.0f32, -1.0, 1.0, 0.5];
        let e = c.encode(&row);
        let back = c.decode(&e.bytes, 4).unwrap();
        assert_eq!(back, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_k_larger_than_row_keeps_everything() {
        let c = TopK { k: 10 };
        let row = vec![3.0f32, -4.0];
        let e = c.encode(&row);
        assert_eq!(e.bytes.len(), 4 + 8 * 2);
        assert_eq!(c.decode(&e.bytes, 2).unwrap(), row);
    }

    #[test]
    fn dedup_counters_and_collision_free_reuse() {
        let cache = DedupCache::new();
        let codec = DedupCodec { inner: Arc::new(Identity), cache: cache.clone() };
        let row = vec![1.5f32; 32]; // 128 payload bytes = 2 chunks
        let e1 = codec.encode(&row);
        let e2 = codec.encode(&row);
        let (hits, misses, chunks, hit_bytes) = cache.counters.snapshot();
        assert_eq!((hits, misses, chunks), (2, 2, 4));
        assert_eq!(hit_bytes, 128);
        assert!(e2.bytes.len() < e1.bytes.len(), "second encode ships references");
        assert_eq!(codec.decode(&e1.bytes, 32).unwrap(), row);
        assert_eq!(codec.decode(&e2.bytes, 32).unwrap(), row);
        assert_eq!(cache.resident(), 2);
    }

    #[test]
    fn dedup_rejects_garbage() {
        let codec = DedupCodec { inner: Arc::new(Identity), cache: DedupCache::new() };
        assert!(codec.decode(&[], 4).is_err());
        assert!(codec.decode(&[16, 0, 0, 0, 7], 4).is_err(), "unknown tag");
        assert!(codec.decode(&[16, 0, 0, 0, 1, 1, 2], 4).is_err(), "truncated ref");
    }

    #[test]
    fn spec_names_round_trip_and_reject_garbage() {
        for name in ["identity", "f16", "i8", "topk:64", "dedup:i8", "dedup:topk:8"] {
            let spec = CodecSpec::from_name(name).unwrap();
            assert_eq!(spec.name(), name);
            assert_eq!(CodecSpec::from_name(&spec.name()).unwrap(), spec);
        }
        for bad in ["", "f32", "topk:", "topk:0", "topk:x", "dedup:", "dedup:dedup:i8"] {
            assert!(CodecSpec::from_name(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn menu_parses_validates_and_defaults() {
        let m = CodecMenu::default();
        assert_eq!((m.len(), m.names().as_str()), (1, "identity"));
        let m = CodecMenu::from_list("identity, f16 ,i8,topk:64").unwrap();
        assert_eq!(m.names(), "identity,f16,i8,topk:64");
        assert!(CodecMenu::from_list("").is_err());
        assert!(CodecMenu::from_list("identity,,i8").is_err());
        assert!(CodecMenu::from_list("i8,i8").is_err(), "duplicates rejected");
        let (codecs, _cache) = m.build();
        assert_eq!(codecs.len(), 4);
        assert!(codecs[0].bit_transparent());
        assert!(!codecs[2].bit_transparent());
    }

    #[test]
    fn nominal_ratios_match_the_wire() {
        // the reward-side ratio must equal the actual raw/encoded byte
        // ratio for every deterministic codec
        let row: Vec<f32> = (0..512).map(|i| (i as f32).sin()).collect();
        for spec in ["identity", "f16", "i8", "topk:64"] {
            let codec = CodecSpec::from_name(spec).unwrap().build(&DedupCache::new());
            let e = codec.encode(&row);
            assert_eq!(e.bytes.len(), codec.nominal_encoded_len(row.len()), "{spec}");
            let measured = (4 * row.len()) as f64 / e.bytes.len() as f64;
            assert!((codec.nominal_ratio(row.len()) - measured).abs() < 1e-12, "{spec}");
        }
        // the acceptance target: i8 on the bench model's 512-value rows
        let i8 = CodecSpec::I8.build(&DedupCache::new());
        assert!(i8.nominal_ratio(512) >= 3.9, "ratio {}", i8.nominal_ratio(512));
        let id = CodecSpec::Identity.build(&DedupCache::new());
        assert_eq!(id.nominal_ratio(512), 1.0);
    }
}

//! Dynamic batcher: groups router requests into batches matched to the
//! compiled PJRT batch sizes.
//!
//! Policy: wait up to `max_wait` for the preferred (largest compiled) batch
//! to fill; on timeout, emit whatever is queued using the best-fitting
//! compiled size (padding the tail).  Order is preserved; padding rows are
//! marked so replies are only sent for real requests.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::router::{Request, Router};
use crate::model::plan_batches;
use crate::tensor::TensorI32;

/// Batcher parameters.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// compiled batch sizes (from the manifest)
    pub batch_sizes: Vec<usize>,
    /// how long to wait for a full preferred batch
    pub max_wait: Duration,
}

impl BatcherConfig {
    /// The preferred (largest compiled) batch size.  Panic-free: an empty
    /// size menu — rejected by [`Batcher::new`], but representable in a
    /// hand-built config — degrades to single-row batches rather than
    /// panicking inside the batcher thread.
    pub fn preferred(&self) -> usize {
        self.batch_sizes.iter().max().copied().unwrap_or(1)
    }
}

/// A formed batch: the padded token tensor plus the real requests.
#[derive(Debug)]
pub struct Batch {
    /// [B, T] where B is a compiled batch size (>= requests.len())
    pub tokens: TensorI32,
    /// the real requests, in arrival order (len <= B)
    pub requests: Vec<Request>,
    /// compiled batch size used
    pub padded_to: usize,
    pub formed_at: Instant,
}

impl Batch {
    pub fn real_len(&self) -> usize {
        self.requests.len()
    }
}

/// Pulls from the router and forms batches.
pub struct Batcher {
    router: Arc<Router>,
    config: BatcherConfig,
    /// batches already formed but not yet handed out (form_all can yield
    /// several batches from one router pull)
    pending: std::collections::VecDeque<Batch>,
}

impl Batcher {
    pub fn new(router: Arc<Router>, config: BatcherConfig) -> Batcher {
        assert!(!config.batch_sizes.is_empty());
        Batcher { router, config, pending: std::collections::VecDeque::new() }
    }

    /// Form the next batch.  Returns None when the router is shut down and
    /// drained.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if let Some(b) = self.pending.pop_front() {
            return Some(b);
        }
        let preferred = self.config.preferred();

        // Block for the first request (or shutdown).
        let mut got = self.router.pull(preferred);
        if got.is_empty() {
            return None; // shut down and drained
        }
        // Top up until the preferred size or the deadline.  The deadline
        // starts when the first request is in hand — an idle stretch before
        // it must not eat the top-up window (or sparse arrivals would each
        // ship as padded single-row batches).  `pull_deadline` parks on the
        // router's condvar instead of sleep-polling: arrivals wake it
        // immediately, and a partial batch is emitted exactly at the
        // deadline rather than up to a poll interval late.
        let deadline = Instant::now() + self.config.max_wait;
        while got.len() < preferred && Instant::now() < deadline {
            if !self.router.is_accepting() && self.router.queued() == 0 {
                break;
            }
            let more = self.router.pull_deadline(preferred - got.len(), deadline);
            if more.is_empty() {
                break; // deadline passed (or shut down and drained)
            }
            got.extend(more);
        }
        self.pending = Self::form_all(got, &self.config.batch_sizes).into();
        self.pending.pop_front()
    }

    /// Deterministic batch formation covering *every* request (exposed for
    /// tests and for the experiment harness): follows [`plan_batches`] so
    /// each produced batch uses a compiled size, padding only the tail.
    pub fn form_all(requests: Vec<Request>, batch_sizes: &[usize]) -> Vec<Batch> {
        assert!(!requests.is_empty());
        let n = requests.len();
        let plan = plan_batches(n, batch_sizes);
        let mut out = Vec::with_capacity(plan.len());
        let mut rest = requests;
        for (bsz, real) in plan {
            let tail = rest.split_off(real.min(rest.len()));
            let head = std::mem::replace(&mut rest, tail);
            let rows: Vec<&TensorI32> = head.iter().map(|r| &r.tokens).collect();
            let tokens = TensorI32::concat_rows(&rows).expect("batch concat");
            let tokens = tokens.pad_rows_to(bsz).expect("batch pad");
            out.push(Batch {
                tokens,
                requests: head,
                padded_to: bsz,
                formed_at: Instant::now(),
            });
        }
        debug_assert!(rest.is_empty());
        debug_assert_eq!(out.iter().map(|b| b.real_len()).sum::<usize>(), n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::RouterConfig;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn request(id_marker: i32) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id: id_marker as u64,
            tokens: TensorI32::new(vec![1, 4], vec![id_marker; 4]).unwrap(),
            submitted_at: Instant::now(),
            reply: tx,
            tag: None,
        }
    }

    #[test]
    fn form_exact_batch() {
        let reqs: Vec<Request> = (0..8).map(request).collect();
        let bs = Batcher::form_all(reqs, &[1, 8]);
        assert_eq!(bs.len(), 1);
        let b = &bs[0];
        assert_eq!(b.padded_to, 8);
        assert_eq!(b.real_len(), 8);
        assert_eq!(b.tokens.shape(), &[8, 4]);
        for (i, r) in b.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64); // order preserved
        }
    }

    #[test]
    fn form_pads_small_batch() {
        let reqs: Vec<Request> = (0..3).map(request).collect();
        let bs = Batcher::form_all(reqs, &[8]);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].padded_to, 8);
        assert_eq!(bs[0].real_len(), 3);
        assert_eq!(bs[0].tokens.shape(), &[8, 4]);
        // padding repeats the last real row
        assert_eq!(bs[0].tokens.at(&[7, 0]).unwrap(), 2);
    }

    #[test]
    fn form_splits_overflow_into_multiple_batches() {
        let reqs: Vec<Request> = (0..11).map(request).collect();
        let bs = Batcher::form_all(reqs, &[1, 8]);
        let total: usize = bs.iter().map(|b| b.real_len()).sum();
        assert_eq!(total, 11);
        assert_eq!(bs[0].padded_to, 8);
        // ids across batches: 0..11 in order
        let ids: Vec<u64> = bs.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..11).collect::<Vec<u64>>());
    }

    #[test]
    fn form_single() {
        let bs = Batcher::form_all(vec![request(9)], &[1, 8]);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].padded_to, 1);
        assert_eq!(bs[0].tokens.shape(), &[1, 4]);
    }

    #[test]
    fn batcher_drains_router_end_to_end() {
        let router = Router::new(RouterConfig::default());
        let mut batcher = Batcher::new(
            Arc::clone(&router),
            BatcherConfig { batch_sizes: vec![1, 8], max_wait: Duration::from_millis(5) },
        );
        let (tx, _rx) = mpsc::channel();
        for _ in 0..20 {
            router.submit(TensorI32::zeros(vec![1, 4]), tx.clone());
        }
        router.shutdown();
        let mut total = 0;
        let mut ids = Vec::new();
        while let Some(b) = batcher.next_batch() {
            total += b.real_len();
            ids.extend(b.requests.iter().map(|r| r.id));
            assert!(b.padded_to == 1 || b.padded_to == 8);
        }
        assert_eq!(total, 20);
        // every id exactly once, in order
        assert_eq!(ids, (0..20).collect::<Vec<u64>>());
    }

    #[test]
    fn partial_batch_emitted_at_deadline() {
        // With the router still accepting and fewer requests than the
        // preferred size, the batcher must emit the partial batch at the
        // `max_wait` deadline (condvar deadline wait, not a sleep-poll).
        let router = Router::new(RouterConfig::default());
        let mut batcher = Batcher::new(
            Arc::clone(&router),
            BatcherConfig { batch_sizes: vec![8], max_wait: Duration::from_millis(40) },
        );
        let (tx, _rx) = mpsc::channel();
        for i in 0..3 {
            router.submit(TensorI32::new(vec![1, 4], vec![i; 4]).unwrap(), tx.clone());
        }
        let t0 = Instant::now();
        let b = batcher.next_batch().expect("partial batch at deadline");
        let waited = t0.elapsed();
        assert_eq!(b.real_len(), 3);
        assert_eq!(b.padded_to, 8);
        assert!(waited >= Duration::from_millis(30), "emitted too early: {waited:?}");
        assert!(waited < Duration::from_millis(400), "emitted too late: {waited:?}");
        router.shutdown();
    }

    #[test]
    fn property_batching_preserves_every_request() {
        // property test: arbitrary request counts and batch-size menus
        crate::util::prop::quickcheck(
            |rng: &mut Rng, size| {
                let n = 1 + rng.below(size as u64 * 2 + 1) as usize;
                let menu = match rng.below(3) {
                    0 => vec![1, 8],
                    1 => vec![4],
                    _ => vec![2, 16],
                };
                (n, menu)
            },
            |(n, menu)| {
                let reqs: Vec<Request> = (0..*n as i32).map(request).collect();
                let bs = Batcher::form_all(reqs, menu);
                let mut seen = Vec::new();
                for b in &bs {
                    if b.requests.is_empty() {
                        return Err("empty batch".into());
                    }
                    if b.tokens.shape()[0] != b.padded_to {
                        return Err(format!(
                            "padded shape {:?} != {}",
                            b.tokens.shape(),
                            b.padded_to
                        ));
                    }
                    if !menu.contains(&b.padded_to) {
                        return Err(format!("{} not a compiled size", b.padded_to));
                    }
                    // padded rows replicate the last real row's tokens
                    let last_real = b.requests.len() - 1;
                    for pad_row in b.requests.len()..b.padded_to {
                        if b.tokens.at(&[pad_row, 0]).unwrap()
                            != b.tokens.at(&[last_real, 0]).unwrap()
                        {
                            return Err("padding does not replicate last row".into());
                        }
                    }
                    seen.extend(b.requests.iter().map(|r| r.id));
                }
                let expected: Vec<u64> = (0..*n as u64).collect();
                if seen != expected {
                    return Err(format!("seen {seen:?} expected {expected:?}"));
                }
                Ok(())
            },
        );
    }
}

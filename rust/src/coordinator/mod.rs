//! The serving coordinator — the system around the paper's algorithm.
//!
//! Request flow:
//!
//! ```text
//!     client -> Router (admission, backpressure)
//!            -> Batcher (dynamic batching to compiled batch sizes)
//!            -> Service (policy decides split; edge/cloud pipeline runs it)
//!            -> reply channels
//! ```
//!
//! The split-layer decision is *distribution-level* (one bandit per
//! deployment, as in the paper), so a whole batch shares the chosen split;
//! the exit-or-offload decision is per sample.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::ServingMetrics;
pub use router::{Request, Response, Router, RouterConfig};
pub use service::{Service, ServiceConfig};

//! The serving coordinator — the system around the paper's algorithm.
//!
//! Request flow (each `->` below is a pipeline stage boundary with a bounded
//! channel; stages run concurrently, see `service` module docs):
//!
//! ```text
//!     client -> Router (admission, backpressure)
//!            -> Batcher (dynamic batching to compiled batch sizes;
//!               condvar deadline wait, no sleep-polling)
//!            -> edge stage (embed + blocks to the split + exit head)
//!            -> cloud stage (replica pool: continuation for offloaded
//!               rows on one of N fault-injectable cloud lanes, with
//!               deadline/retry, circuit breakers and edge-only
//!               degradation; see `replicas`)
//!            -> reply stage (link sim, bandit updates, metrics, replies)
//! ```
//!
//! The split-layer decision is *distribution-level* (one bandit per
//! deployment, as in the paper), so a whole batch shares the chosen split;
//! the exit-or-offload decision is per sample.  All bandit state lives in
//! the reply stage and is updated in batch order, so the pipeline's
//! decisions are identical to serial execution for a fixed arrival order —
//! including with speculative edge continuation enabled (the edge stage
//! overlaps the post-split continuation with the exit-head verdict,
//! kill-on-exit; see `service` module docs and `tests/speculation.rs`), and
//! including under a time-varying uplink (the link scenario is stepped once
//! per batch in the reply stage; see
//! [`crate::sim::link::LinkScenario`] and the `service` module docs).

pub mod batcher;
pub mod metrics;
pub mod replicas;
pub mod router;
pub mod service;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::{PoolStat, ServingMetrics};
pub use replicas::{DispatchPolicy, ReplicaConfig, ReplicaPool};
pub use router::{Request, Response, Router, RouterConfig};
pub use service::{CoalesceConfig, Service, ServiceConfig, SpeculateMode};

//! Serving metrics: latency, throughput, exit-layer distribution, offload
//! rate, cost accounting — everything `splitee serve` reports.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::codec::DedupCounters;
use crate::coordinator::router::ClientTag;
use crate::runtime::SpecCounters;
use crate::util::stats::{LatencyHistogram, Welford};

/// Per-replica dispatch accounting for the fault-tolerant cloud tier
/// ([`crate::coordinator::replicas`]).  Shared atomics: the pool (on the
/// cloud-stage thread) records; reporting threads snapshot.  All ordering
/// is `Relaxed` — a single dispatcher writes, and readers only consume
/// totals after the serve loop has joined.
#[derive(Debug, Default)]
pub struct ReplicaCounters {
    /// dispatch attempts routed to this replica (probes included)
    pub dispatched: AtomicU64,
    /// attempts that returned a deadline-respecting result
    pub completed: AtomicU64,
    /// failed attempts whose group was re-dispatched to another attempt
    pub rerouted: AtomicU64,
    /// failed attempts that exhausted the retry budget, degrading the group
    /// to on-device final-exit inference
    pub fallback: AtomicU64,
    /// attempts that exceeded the offload deadline (subset of the failures)
    pub timeouts: AtomicU64,
    /// circuit-breaker transitions into the open state (a failed half-open
    /// probe re-opening the breaker counts again)
    pub breaker_opens: AtomicU64,
    /// half-open probe dispatches admitted after the breaker cooldown
    pub probes: AtomicU64,
    /// simulated busy microseconds attributed to this replica's completions
    busy_us: AtomicU64,
    /// successor of the last completed dispatch sequence (0 = none yet):
    /// the per-replica reply-ordering invariance check
    last_seq: AtomicU64,
    /// completions observed out of per-replica dispatch order (the weaker
    /// determinism contract requires this to stay 0)
    pub order_violations: AtomicU64,
}

impl ReplicaCounters {
    /// Attribute simulated busy time to this replica.
    pub fn add_busy_ms(&self, ms: f64) {
        self.busy_us.fetch_add((ms * 1e3) as u64, Ordering::Relaxed);
    }

    /// Record a completed dispatch and check per-replica order invariance:
    /// completions must land in the same order the replica was dispatched.
    pub fn record_completion(&self, seq: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let prev = self.last_seq.swap(seq + 1, Ordering::Relaxed);
        if prev > seq {
            self.order_violations.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn snapshot(&self) -> ReplicaStat {
        ReplicaStat {
            dispatched: self.dispatched.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rerouted: self.rerouted.load(Ordering::Relaxed),
            fallback: self.fallback.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            busy_ms: self.busy_us.load(Ordering::Relaxed) as f64 / 1e3,
            order_violations: self.order_violations.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one replica's counters (see [`ReplicaCounters`]
/// for field semantics).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaStat {
    /// dispatch attempts routed to this replica
    pub dispatched: u64,
    /// attempts that completed
    pub completed: u64,
    /// failed attempts that re-routed elsewhere
    pub rerouted: u64,
    /// failed attempts that degraded their group to the edge
    pub fallback: u64,
    /// deadline timeouts among the failures
    pub timeouts: u64,
    /// breaker open transitions
    pub breaker_opens: u64,
    /// half-open probes admitted
    pub probes: u64,
    /// simulated busy milliseconds
    pub busy_ms: f64,
    /// per-replica completion-order violations (must stay 0)
    pub order_violations: u64,
}

/// Pool-wide dispatch accounting for the replica tier, plus the per-replica
/// breakdown.  Created by the service with the pool and shared into
/// [`ServingMetrics::pool`].
#[derive(Debug)]
pub struct PoolCounters {
    replicas: Vec<ReplicaCounters>,
    retries: AtomicU64,
    fallback_groups: AtomicU64,
    fallback_rows: AtomicU64,
    breaker_open_rejections: AtomicU64,
    backoff_us: AtomicU64,
}

impl PoolCounters {
    /// Counters for a pool of `n` replicas.
    pub fn new(n: usize) -> Arc<PoolCounters> {
        Arc::new(PoolCounters {
            replicas: (0..n).map(|_| ReplicaCounters::default()).collect(),
            retries: AtomicU64::new(0),
            fallback_groups: AtomicU64::new(0),
            fallback_rows: AtomicU64::new(0),
            breaker_open_rejections: AtomicU64::new(0),
            backoff_us: AtomicU64::new(0),
        })
    }

    /// Number of replicas these counters cover.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// One replica's counters.  Panics on an out-of-range id — the pool
    /// sizes the counters, so that is a bug, not an operational state.
    pub fn replica(&self, i: usize) -> &ReplicaCounters {
        &self.replicas[i]
    }

    /// Record a retry (a failed attempt followed by another dispatch).
    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a group degraded to on-device final-exit inference.
    pub fn note_fallback_group(&self, rows: u64) {
        self.fallback_groups.fetch_add(1, Ordering::Relaxed);
        self.fallback_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Record a group that could not dispatch at all because every
    /// replica's breaker was open (edge-only service).
    pub fn note_breaker_open_rejection(&self) {
        self.breaker_open_rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// Accumulate simulated backoff wait time.
    pub fn add_backoff_ms(&self, ms: f64) {
        self.backoff_us.fetch_add((ms * 1e3) as u64, Ordering::Relaxed);
    }

    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> PoolStat {
        PoolStat {
            replicas: self.replicas.iter().map(ReplicaCounters::snapshot).collect(),
            retries: self.retries.load(Ordering::Relaxed),
            fallback_groups: self.fallback_groups.load(Ordering::Relaxed),
            fallback_rows: self.fallback_rows.load(Ordering::Relaxed),
            breaker_open_rejections: self.breaker_open_rejections.load(Ordering::Relaxed),
            backoff_ms: self.backoff_us.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

/// Point-in-time copy of a pool's counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStat {
    /// per-replica breakdown, indexed by replica id
    pub replicas: Vec<ReplicaStat>,
    /// failed attempts that were re-dispatched (equals the rerouted total)
    pub retries: u64,
    /// groups degraded to on-device final-exit inference
    pub fallback_groups: u64,
    /// offloaded rows served by that degradation
    pub fallback_rows: u64,
    /// groups rejected outright because every breaker was open
    pub breaker_open_rejections: u64,
    /// accumulated simulated backoff wait (ms)
    pub backoff_ms: f64,
}

impl PoolStat {
    /// Total dispatch attempts across replicas.
    pub fn dispatched(&self) -> u64 {
        self.replicas.iter().map(|r| r.dispatched).sum()
    }

    /// Total completed attempts across replicas.
    pub fn completed(&self) -> u64 {
        self.replicas.iter().map(|r| r.completed).sum()
    }

    /// Total re-routed attempts across replicas.
    pub fn rerouted(&self) -> u64 {
        self.replicas.iter().map(|r| r.rerouted).sum()
    }

    /// Total retry-budget-exhausting attempts across replicas.
    pub fn fallback(&self) -> u64 {
        self.replicas.iter().map(|r| r.fallback).sum()
    }

    /// Total breaker open transitions across replicas.
    pub fn breaker_opens(&self) -> u64 {
        self.replicas.iter().map(|r| r.breaker_opens).sum()
    }

    /// Total per-replica completion-order violations (must stay 0).
    pub fn order_violations(&self) -> u64 {
        self.replicas.iter().map(|r| r.order_violations).sum()
    }

    /// The accounting identity the robustness tests pin: every dispatch
    /// attempt resolves exactly once as completed, re-routed, or fallback.
    pub fn balanced(&self) -> bool {
        self.dispatched() == self.completed() + self.rerouted() + self.fallback()
    }
}

/// Per-link-state serving accounting: how much traffic each instantaneous
/// link condition saw and which splits the policy chose under it.  Keyed by
/// the [`crate::sim::link::LinkState::label`]; the static scenario keeps
/// everything under one `"static"` entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkStateStat {
    /// batches served while the link was in this state
    pub batches: u64,
    /// requests served while the link was in this state
    pub served: u64,
    pub offloaded: u64,
    pub outage_fallbacks: u64,
    /// wall-clock milliseconds attributed to this state (per-state req/s in
    /// the serving bench = `served / wall_ms`)
    pub wall_ms: f64,
    /// chosen split layer (1-based) -> batches decided that way in this
    /// state — the per-state split histogram the contextual policy is
    /// expected to shift across states
    pub split_hist: BTreeMap<usize, u64>,
}

impl LinkStateStat {
    /// The most frequently chosen split in this state (1-based), if any
    /// batch was served.
    pub fn modal_split(&self) -> Option<usize> {
        self.split_hist.iter().max_by_key(|(_, &c)| c).map(|(&s, _)| s)
    }
}

/// Per-cohort serving accounting for the network front end: one row per
/// registered client identity (`client:<name>`) and one per link profile
/// (`link:<profile>`).  Attribution-only — cohort rows never feed back into
/// the decision path, so tagged and untagged traffic make identical
/// split/exit choices.
#[derive(Debug, Clone, Default)]
pub struct CohortStat {
    /// requests served to this cohort
    pub served: u64,
    /// served requests that offloaded to the cloud tier
    pub offloaded: u64,
    /// end-to-end latency of this cohort's requests
    pub latency: LatencyHistogram,
    /// raw (pre-codec) uplink payload bytes this cohort's delivered
    /// offloads would have shipped uncompressed
    pub raw_bytes: u64,
    /// encoded uplink payload bytes those offloads actually cost (the
    /// codec output; `<= raw_bytes` always)
    pub enc_bytes: u64,
}

impl CohortStat {
    /// Offloaded fraction of this cohort's served requests.
    pub fn offload_rate(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.offloaded as f64 / self.served as f64
        }
    }

    /// Raw/encoded uplink byte ratio for this cohort (1.0 when it never
    /// offloaded).
    pub fn uplink_ratio(&self) -> f64 {
        if self.enc_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.enc_bytes as f64
        }
    }
}

/// Aggregated metrics for a serving session.
#[derive(Debug)]
pub struct ServingMetrics {
    started: Instant,
    pub latency: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub cost_lambda: Welford,
    pub energy: Welford,
    /// requests answered at each (1-based) layer
    pub per_layer: Vec<u64>,
    pub served: u64,
    pub offloaded: u64,
    pub outage_fallbacks: u64,
    pub batches: u64,
    pub padded_rows: u64,
    /// accumulated simulated busy time of the pipeline's edge stage (ms)
    pub edge_busy_ms: f64,
    /// accumulated simulated busy time of the pipeline's cloud stage (ms)
    pub cloud_busy_ms: f64,
    /// executable launches performed by the edge stage (embed + fused
    /// block-range + exit head per batch when the chain artifacts exist)
    pub edge_launches: u64,
    /// executable launches performed by the cloud stage
    pub cloud_launches: u64,
    /// cloud-stage offload groups that launched a continuation
    pub cloud_groups: u64,
    /// offload-contributing batches merged into a coalesced group beyond
    /// the first — each one is a batch whose offloads rode along in another
    /// batch's launch (passively absorbed zero-offload batches don't count)
    pub coalesced_batches: u64,
    /// speculative-launch lifecycle counters (issued / used / wasted).
    /// Shared atomics: the edge stage issues and kills-on-exit, the cloud
    /// stage consumes — read them through [`SpecCounters::snapshot`], which
    /// is ordered so a mid-flight read never shows `used + wasted > issued`
    /// (field-by-field loads in the wrong order would).
    pub spec: Arc<SpecCounters>,
    /// replica-pool dispatch/retry/breaker counters, shared with the
    /// service's [`crate::coordinator::replicas::ReplicaPool`].  Sized 0
    /// by [`ServingMetrics::new`]; the service swaps in the pool's counters
    /// at construction.
    pub pool: Arc<PoolCounters>,
    /// per-link-state traffic and split-choice accounting (dynamic-link
    /// scenarios; one `"static"` entry under a fixed link)
    pub link_states: BTreeMap<String, LinkStateStat>,
    /// per-client / per-link-cohort rows for TCP traffic that announced an
    /// identity via the `hello` line (keys `client:<name>` and
    /// `link:<profile>`); empty for anonymous or in-process traffic
    pub cohorts: BTreeMap<String, CohortStat>,
    /// raw (pre-codec) uplink payload bytes across all delivered offload
    /// transfers — what the uncompressed uplink would have shipped
    pub raw_bytes: u64,
    /// encoded uplink payload bytes (the codec output before dedup).
    /// Invariant: `encoded_bytes <= raw_bytes` — every codec's per-row
    /// output is bounded by the raw row (asserted under load by
    /// `tests/integration.rs`)
    pub encoded_bytes: u64,
    /// wire bytes saved by the content-addressed dedup layer on top of the
    /// codec output (0 without a `dedup:*` codec in the menu)
    pub deduped_bytes: u64,
    /// dedup chunk-cache lifecycle counters (hits / misses / chunks),
    /// shared with the service's [`crate::codec::DedupCache`].  Sized
    /// empty by [`ServingMetrics::new`]; the service swaps in the cache's
    /// counters at construction, exactly like [`ServingMetrics::pool`].
    pub dedup: Arc<DedupCounters>,
    /// wall-clock mark of the previous batch's reply: the inter-reply
    /// interval is attributed to the *completing* batch's link state.
    /// `None` until the first batch, so service setup time is charged to no
    /// state.  (Under closed-loop replay — the serving bench — inter-reply
    /// time is serving time, so per-state req/s is meaningful; under an
    /// open-loop workload arrival idle lands on the next completing batch.)
    last_link_mark: Option<Instant>,
    /// durable-state snapshots written this session (periodic + shutdown)
    pub snapshots_written: u64,
}

impl ServingMetrics {
    pub fn new(n_layers: usize) -> ServingMetrics {
        ServingMetrics {
            started: Instant::now(),
            latency: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            cost_lambda: Welford::new(),
            energy: Welford::new(),
            per_layer: vec![0; n_layers + 1], // index 1..=L
            served: 0,
            offloaded: 0,
            outage_fallbacks: 0,
            batches: 0,
            padded_rows: 0,
            edge_busy_ms: 0.0,
            cloud_busy_ms: 0.0,
            edge_launches: 0,
            cloud_launches: 0,
            cloud_groups: 0,
            coalesced_batches: 0,
            spec: SpecCounters::new(),
            pool: PoolCounters::new(0),
            link_states: BTreeMap::new(),
            cohorts: BTreeMap::new(),
            raw_bytes: 0,
            encoded_bytes: 0,
            deduped_bytes: 0,
            dedup: DedupCounters::new(),
            last_link_mark: None,
            snapshots_written: 0,
        }
    }

    /// Record one durable-state snapshot written to disk.
    pub fn record_snapshot(&mut self) {
        self.snapshots_written += 1;
    }

    pub fn record_request(
        &mut self,
        infer_layer: usize,
        offloaded: bool,
        outage: bool,
        latency_ms: f64,
        queue_wait_ms: f64,
        cost: f64,
        energy: f64,
    ) {
        self.served += 1;
        if offloaded {
            self.offloaded += 1;
        }
        if outage {
            self.outage_fallbacks += 1;
        }
        if infer_layer < self.per_layer.len() {
            self.per_layer[infer_layer] += 1;
        }
        self.latency.record_us(latency_ms * 1e3);
        self.queue_wait.record_us(queue_wait_ms * 1e3);
        self.cost_lambda.push(cost);
        self.energy.push(energy);
    }

    pub fn record_batch(&mut self, real: usize, padded_to: usize) {
        self.batches += 1;
        self.padded_rows += (padded_to - real) as u64;
    }

    /// Record one batch's per-stage busy time.  The ratio of the smaller
    /// total to the larger bounds how much the edge/cloud overlap of the
    /// pipelined serve loop can hide.
    pub fn record_stage_ms(&mut self, edge_ms: f64, cloud_ms: f64) {
        self.edge_busy_ms += edge_ms;
        self.cloud_busy_ms += cloud_ms;
    }

    /// Record one batch's per-stage executable-launch counts (cloud
    /// launches are attributed to the head batch of a coalesced group).
    pub fn record_launches(&mut self, edge: u64, cloud: u64) {
        self.edge_launches += edge;
        self.cloud_launches += cloud;
    }

    /// Record one batch against the link state it was served under: traffic
    /// counts, the chosen split (the per-state split histogram) and the
    /// wall-clock time since the previous batch (per-state req/s).
    pub fn record_link_state(
        &mut self,
        label: &str,
        split: usize,
        served: usize,
        offloaded: u64,
        outage_fallbacks: u64,
    ) {
        let now = Instant::now();
        let dt_ms = self
            .last_link_mark
            .map(|m| now.duration_since(m).as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        self.last_link_mark = Some(now);
        // allocate the key only on the first sighting of a label — this runs
        // once per batch on the reply path
        if !self.link_states.contains_key(label) {
            self.link_states.insert(label.to_string(), LinkStateStat::default());
        }
        let s = self.link_states.get_mut(label).expect("entry just ensured");
        s.batches += 1;
        s.served += served as u64;
        s.offloaded += offloaded;
        s.outage_fallbacks += outage_fallbacks;
        s.wall_ms += dt_ms;
        *s.split_hist.entry(split).or_insert(0) += 1;
    }

    /// Accumulate one batch's delivered uplink payload bytes: raw
    /// (pre-codec), encoded (codec output), and the wire bytes the dedup
    /// layer saved on top.  Called once per batch from the reply stage.
    pub fn record_uplink_bytes(&mut self, raw: u64, encoded: u64, dedup_saved: u64) {
        self.raw_bytes += raw;
        self.encoded_bytes += encoded;
        self.deduped_bytes += dedup_saved;
    }

    /// Attribute one served request to its connection's cohorts: the named
    /// client row and the link-profile row both advance, including the
    /// request's delivered uplink payload bytes (`raw`/`enc` are 0 for
    /// rows that exited on-device or fell back).  Called from the reply
    /// stage only for requests that carried a [`ClientTag`]; anonymous
    /// traffic leaves `cohorts` empty.
    pub fn record_cohort(
        &mut self,
        tag: &ClientTag,
        offloaded: bool,
        latency_ms: f64,
        raw_bytes: u64,
        enc_bytes: u64,
    ) {
        for key in [format!("client:{}", tag.client), format!("link:{}", tag.link)] {
            let c = self.cohorts.entry(key).or_default();
            c.served += 1;
            if offloaded {
                c.offloaded += 1;
            }
            c.latency.record_us(latency_ms * 1e3);
            c.raw_bytes += raw_bytes;
            c.enc_bytes += enc_bytes;
        }
    }

    /// Record one cloud-stage group by how many offload-contributing
    /// batches it merged — zero means the group had no offloaded rows and
    /// launched nothing.
    pub fn record_coalesce(&mut self, contributing_batches: usize) {
        if contributing_batches > 0 {
            self.cloud_groups += 1;
        }
        self.coalesced_batches += contributing_batches.saturating_sub(1) as u64;
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.served as f64 / secs
        } else {
            0.0
        }
    }

    pub fn offload_rate(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.offloaded as f64 / self.served as f64
        }
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} requests in {} batches ({:.1} req/s, {:.1}% padding)\n",
            self.served,
            self.batches,
            self.throughput_rps(),
            100.0 * self.padded_rows as f64
                / ((self.served + self.padded_rows).max(1)) as f64,
        ));
        out.push_str(&format!(
            "latency  p50 {:.2} ms   p99 {:.2} ms   mean {:.2} ms   max {:.2} ms\n",
            self.latency.percentile_us(50.0) / 1e3,
            self.latency.percentile_us(99.0) / 1e3,
            self.latency.mean_us() / 1e3,
            self.latency.max_us() / 1e3,
        ));
        out.push_str(&format!(
            "queue    p50 {:.2} ms   p99 {:.2} ms\n",
            self.queue_wait.percentile_us(50.0) / 1e3,
            self.queue_wait.percentile_us(99.0) / 1e3,
        ));
        out.push_str(&format!(
            "cost     mean {:.3} lambda/request   energy mean {:.3}\n",
            self.cost_lambda.mean(),
            self.energy.mean(),
        ));
        out.push_str(&format!(
            "offload  {:.1}%   outage fallbacks {}\n",
            100.0 * self.offload_rate(),
            self.outage_fallbacks,
        ));
        out.push_str(&format!(
            "stages   edge busy {:.1} ms   cloud busy {:.1} ms\n",
            self.edge_busy_ms, self.cloud_busy_ms,
        ));
        out.push_str(&format!(
            "launches edge {} ({:.1}/batch)   cloud {} in {} groups   coalesced {} batches\n",
            self.edge_launches,
            self.edge_launches as f64 / self.batches.max(1) as f64,
            self.cloud_launches,
            self.cloud_groups,
            self.coalesced_batches,
        ));
        let spec = self.spec.snapshot();
        out.push_str(&format!(
            "spec     issued {}   used {}   wasted {}   (hit-rate {:.1}%)\n",
            spec.issued,
            spec.used,
            spec.wasted,
            100.0 * spec.hit_rate(),
        ));
        // uplink byte accounting appears once a codec shipped anything
        if self.raw_bytes > 0 {
            out.push_str(&format!(
                "uplink   raw {} B   encoded {} B ({:.2}x)   dedup saved {} B\n",
                self.raw_bytes,
                self.encoded_bytes,
                self.raw_bytes as f64 / self.encoded_bytes.max(1) as f64,
                self.deduped_bytes,
            ));
        }
        let (hits, misses, chunks, hit_bytes) = self.dedup.snapshot();
        if chunks > 0 {
            out.push_str(&format!(
                "dedup    chunks {chunks}   hits {hits}   misses {misses}   \
                 (hit-rate {:.1}%, {hit_bytes} B referenced)\n",
                100.0 * hits as f64 / chunks.max(1) as f64,
            ));
        }
        let pool = self.pool.snapshot();
        // a single healthy replica is the classic cloud stage — only print
        // the pool breakdown when there is a pool story to tell
        if pool.replicas.len() > 1
            || pool.retries > 0
            || pool.fallback_groups > 0
            || pool.breaker_open_rejections > 0
        {
            out.push_str(&format!(
                "pool     dispatched {}   completed {}   rerouted {}   fallback {} \
                 ({} groups, {} rows)   retries {}   backoff {:.1} ms   breaker-open \
                 rejections {}\n",
                pool.dispatched(),
                pool.completed(),
                pool.rerouted(),
                pool.fallback(),
                pool.fallback_groups,
                pool.fallback_rows,
                pool.retries,
                pool.backoff_ms,
                pool.breaker_open_rejections,
            ));
            for (i, r) in pool.replicas.iter().enumerate() {
                out.push_str(&format!(
                    "replica[{i}]  dispatched {}  completed {}  rerouted {}  fallback {}  \
                     timeouts {}  breaker opens {}  probes {}  busy {:.1} ms\n",
                    r.dispatched,
                    r.completed,
                    r.rerouted,
                    r.fallback,
                    r.timeouts,
                    r.breaker_opens,
                    r.probes,
                    r.busy_ms,
                ));
            }
        }
        if !self.link_states.is_empty()
            && (self.link_states.len() > 1 || !self.link_states.contains_key("static"))
        {
            for (label, s) in &self.link_states {
                let hist: Vec<String> =
                    s.split_hist.iter().map(|(l, c)| format!("L{l}:{c}")).collect();
                out.push_str(&format!(
                    "link[{label}]  {} batches  {} req  offload {:.1}%  outages {}  splits {}\n",
                    s.batches,
                    s.served,
                    100.0 * s.offloaded as f64 / s.served.max(1) as f64,
                    s.outage_fallbacks,
                    hist.join(" "),
                ));
            }
        }
        if !self.cohorts.is_empty() {
            // link rows are always few (4 profiles); client rows can be a
            // whole fleet — print the busiest handful and summarize the rest
            const MAX_CLIENT_ROWS: usize = 8;
            for (key, c) in self.cohorts.iter().filter(|(k, _)| k.starts_with("link:")) {
                // per-link uplink bytes: which link cohorts pay for the
                // offload traffic, and at what codec compression
                let up = if c.enc_bytes > 0 {
                    format!("  up {}/{} B ({:.2}x)", c.raw_bytes, c.enc_bytes, c.uplink_ratio())
                } else {
                    String::new()
                };
                out.push_str(&format!(
                    "cohort[{key}]  {} req  offload {:.1}%  p50 {:.2} ms  p99 {:.2} ms{up}\n",
                    c.served,
                    100.0 * c.offload_rate(),
                    c.latency.percentile_us(50.0) / 1e3,
                    c.latency.percentile_us(99.0) / 1e3,
                ));
            }
            let mut clients: Vec<(&String, &CohortStat)> =
                self.cohorts.iter().filter(|(k, _)| k.starts_with("client:")).collect();
            clients.sort_by(|a, b| b.1.served.cmp(&a.1.served).then(a.0.cmp(b.0)));
            for (key, c) in clients.iter().take(MAX_CLIENT_ROWS) {
                out.push_str(&format!(
                    "cohort[{key}]  {} req  offload {:.1}%  p50 {:.2} ms  p99 {:.2} ms\n",
                    c.served,
                    100.0 * c.offload_rate(),
                    c.latency.percentile_us(50.0) / 1e3,
                    c.latency.percentile_us(99.0) / 1e3,
                ));
            }
            if clients.len() > MAX_CLIENT_ROWS {
                out.push_str(&format!(
                    "cohort   ... +{} more clients\n",
                    clients.len() - MAX_CLIENT_ROWS
                ));
            }
        }
        if self.snapshots_written > 0 {
            out.push_str(&format!("snapshots written {}\n", self.snapshots_written));
        }
        out.push_str("exit layers: ");
        for (layer, &count) in self.per_layer.iter().enumerate().skip(1) {
            if count > 0 {
                out.push_str(&format!("L{layer}:{count} "));
            }
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = ServingMetrics::new(12);
        m.record_request(3, false, false, 5.0, 0.5, 2.7, 2.7);
        m.record_request(12, true, false, 20.0, 1.0, 7.6, 5.1);
        m.record_batch(2, 8);
        m.record_stage_ms(3.0, 1.5);
        m.record_stage_ms(2.0, 0.0);
        m.record_launches(3, 2);
        m.record_launches(3, 0);
        m.record_coalesce(2);
        m.record_coalesce(0);
        assert_eq!(m.edge_launches, 6);
        assert_eq!(m.cloud_launches, 2);
        assert_eq!(m.cloud_groups, 1);
        assert_eq!(m.coalesced_batches, 1);
        assert!((m.edge_busy_ms - 5.0).abs() < 1e-12);
        assert!((m.cloud_busy_ms - 1.5).abs() < 1e-12);
        assert_eq!(m.served, 2);
        assert_eq!(m.offloaded, 1);
        assert_eq!(m.per_layer[3], 1);
        assert_eq!(m.per_layer[12], 1);
        assert_eq!(m.padded_rows, 6);
        assert!((m.offload_rate() - 0.5).abs() < 1e-12);
        assert!((m.cost_lambda.mean() - 5.15).abs() < 1e-9);
    }

    #[test]
    fn report_contains_key_lines() {
        let mut m = ServingMetrics::new(12);
        m.record_request(5, false, false, 1.0, 0.1, 1.0, 1.0);
        let r = m.report();
        assert!(r.contains("latency"));
        assert!(r.contains("offload"));
        assert!(r.contains("launches"));
        assert!(r.contains("spec"));
        assert!(r.contains("L5:1"));
    }

    #[test]
    fn snapshot_counter_reports_only_when_nonzero() {
        let mut m = ServingMetrics::new(6);
        assert!(!m.report().contains("snapshots written"), "zero snapshots is noise");
        m.record_snapshot();
        m.record_snapshot();
        assert_eq!(m.snapshots_written, 2);
        assert!(m.report().contains("snapshots written 2"));
    }

    #[test]
    fn fresh_metrics_have_empty_speculation_counters() {
        let m = ServingMetrics::new(6);
        let s = m.spec.snapshot();
        assert_eq!((s.issued, s.used, s.wasted), (0, 0, 0));
        assert_eq!(s.hit_rate(), 0.0, "no-division-by-zero hit rate");
    }

    #[test]
    fn empty_metrics_do_not_divide_by_zero() {
        let m = ServingMetrics::new(12);
        assert_eq!(m.offload_rate(), 0.0);
        let _ = m.report();
    }

    #[test]
    fn link_state_records_accumulate_per_label() {
        let mut m = ServingMetrics::new(6);
        m.record_link_state("good", 2, 8, 3, 0);
        m.record_link_state("good", 2, 8, 0, 0);
        m.record_link_state("good", 4, 1, 1, 0);
        m.record_link_state("degraded", 5, 8, 2, 1);
        let good = &m.link_states["good"];
        assert_eq!(good.batches, 3);
        assert_eq!(good.served, 17);
        assert_eq!(good.offloaded, 4);
        assert_eq!(good.split_hist[&2], 2);
        assert_eq!(good.split_hist[&4], 1);
        assert_eq!(good.modal_split(), Some(2));
        let deg = &m.link_states["degraded"];
        assert_eq!(deg.batches, 1);
        assert_eq!(deg.outage_fallbacks, 1);
        assert_eq!(deg.modal_split(), Some(5));
        assert!(good.wall_ms >= 0.0 && deg.wall_ms >= 0.0);
        let r = m.report();
        assert!(r.contains("link[good]"), "{r}");
        assert!(r.contains("link[degraded]"), "{r}");
    }

    #[test]
    fn static_only_link_stats_stay_out_of_the_report() {
        let mut m = ServingMetrics::new(6);
        m.record_link_state("static", 3, 8, 0, 0);
        assert!(!m.report().contains("link["), "single static entry is noise");
        assert_eq!(m.link_states["static"].batches, 1);
    }

    #[test]
    fn cohort_rows_accumulate_per_client_and_per_link() {
        let mut m = ServingMetrics::new(6);
        let a = ClientTag { client: "edge-a".into(), link: "wifi".into() };
        let b = ClientTag { client: "edge-b".into(), link: "wifi".into() };
        m.record_cohort(&a, true, 4.0, 1024, 260);
        m.record_cohort(&a, false, 6.0, 0, 0);
        m.record_cohort(&b, true, 10.0, 1024, 260);
        assert_eq!(m.cohorts["client:edge-a"].served, 2);
        assert_eq!(m.cohorts["client:edge-a"].offloaded, 1);
        assert_eq!(m.cohorts["client:edge-b"].served, 1);
        // both clients share the wifi link row
        assert_eq!(m.cohorts["link:wifi"].served, 3);
        assert_eq!(m.cohorts["link:wifi"].offloaded, 2);
        assert_eq!(m.cohorts["link:wifi"].raw_bytes, 2048);
        assert_eq!(m.cohorts["link:wifi"].enc_bytes, 520);
        assert!((m.cohorts["client:edge-b"].offload_rate() - 1.0).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("cohort[link:wifi]"), "{r}");
        assert!(r.contains("cohort[client:edge-a]"), "{r}");
    }

    #[test]
    fn cohort_report_caps_client_rows() {
        let mut m = ServingMetrics::new(6);
        for i in 0..12 {
            let t = ClientTag { client: format!("c{i:02}"), link: "4g".into() };
            // distinct served counts so the sort order is deterministic
            for _ in 0..=i {
                m.record_cohort(&t, false, 1.0, 0, 0);
            }
        }
        let r = m.report();
        assert!(r.contains("cohort[link:4g]"), "{r}");
        assert!(r.contains("+4 more clients"), "{r}");
        // busiest client printed, quietest elided
        assert!(r.contains("cohort[client:c11]"), "{r}");
        assert!(!r.contains("cohort[client:c00]"), "{r}");
    }

    #[test]
    fn untagged_sessions_report_no_cohort_lines() {
        let mut m = ServingMetrics::new(6);
        m.record_request(3, false, false, 5.0, 0.5, 1.0, 1.0);
        assert!(!m.report().contains("cohort["), "anonymous traffic is noise-free");
    }

    #[test]
    fn pool_counters_snapshot_and_balance() {
        let pool = PoolCounters::new(2);
        // replica 0: two clean completions
        pool.replica(0).dispatched.fetch_add(2, Ordering::Relaxed);
        pool.replica(0).record_completion(0);
        pool.replica(0).record_completion(2);
        pool.replica(0).add_busy_ms(3.5);
        // replica 1: one failure re-routed, one that exhausted the budget
        pool.replica(1).dispatched.fetch_add(2, Ordering::Relaxed);
        pool.replica(1).rerouted.fetch_add(1, Ordering::Relaxed);
        pool.replica(1).fallback.fetch_add(1, Ordering::Relaxed);
        pool.replica(1).timeouts.fetch_add(1, Ordering::Relaxed);
        pool.note_retry();
        pool.note_fallback_group(8);
        pool.add_backoff_ms(1.25);
        let s = pool.snapshot();
        assert_eq!(s.dispatched(), 4);
        assert_eq!(s.completed(), 2);
        assert_eq!(s.rerouted(), 1);
        assert_eq!(s.fallback(), 1);
        assert!(s.balanced(), "dispatched == completed + rerouted + fallback");
        assert_eq!(s.retries, 1);
        assert_eq!((s.fallback_groups, s.fallback_rows), (1, 8));
        assert_eq!(s.order_violations(), 0);
        assert!((s.replicas[0].busy_ms - 3.5).abs() < 1e-9);
        assert!((s.backoff_ms - 1.25).abs() < 1e-9);
    }

    #[test]
    fn cohort_rows_carry_uplink_byte_attribution() {
        // the per-link cohort accounting the codec seam threads through
        // record_cohort: raw and encoded bytes land on both the client row
        // and the shared link row, non-offloaded requests contribute zero,
        // and the printed link row carries the byte ratio
        let mut m = ServingMetrics::new(6);
        let t = ClientTag { client: "edge-a".into(), link: "wifi".into() };
        m.record_cohort(&t, true, 4.0, 1024, 516); // i8 on a 256-value row
        m.record_cohort(&t, true, 4.0, 1024, 516);
        m.record_cohort(&t, false, 1.0, 0, 0); // on-device exit: no uplink
        for key in ["client:edge-a", "link:wifi"] {
            let c = &m.cohorts[key];
            assert_eq!((c.raw_bytes, c.enc_bytes), (2048, 1032), "{key}");
            assert!(c.enc_bytes <= c.raw_bytes, "{key}");
            assert!((c.uplink_ratio() - 2048.0 / 1032.0).abs() < 1e-12, "{key}");
        }
        let r = m.report();
        assert!(r.contains("up 2048/1032 B"), "{r}");
        // a cohort that never offloaded reports no byte suffix
        let mut quiet = ServingMetrics::new(6);
        quiet.record_cohort(&t, false, 1.0, 0, 0);
        assert_eq!(quiet.cohorts["link:wifi"].uplink_ratio(), 1.0);
        assert!(!quiet.report().contains(" up "), "{}", quiet.report());
    }

    #[test]
    fn uplink_byte_totals_accumulate_and_report() {
        let mut m = ServingMetrics::new(6);
        assert!(!m.report().contains("uplink"), "zero bytes is noise");
        m.record_uplink_bytes(2048, 520, 0);
        m.record_uplink_bytes(1024, 260, 64);
        assert_eq!((m.raw_bytes, m.encoded_bytes, m.deduped_bytes), (3072, 780, 64));
        assert!(m.encoded_bytes <= m.raw_bytes);
        let r = m.report();
        assert!(r.contains("uplink   raw 3072 B   encoded 780 B"), "{r}");
        assert!(r.contains("dedup saved 64 B"), "{r}");
    }

    #[test]
    fn out_of_order_completion_is_detected() {
        let pool = PoolCounters::new(1);
        pool.replica(0).record_completion(5);
        pool.replica(0).record_completion(3);
        assert_eq!(pool.snapshot().order_violations(), 1);
    }

    #[test]
    fn report_stays_quiet_without_pool_activity() {
        let m = ServingMetrics::new(6);
        assert!(!m.report().contains("pool"), "empty pool is noise");
    }

    #[test]
    fn report_prints_pool_lines_when_the_pool_has_a_story() {
        let mut m = ServingMetrics::new(6);
        m.pool = PoolCounters::new(2);
        m.pool.replica(0).dispatched.fetch_add(1, Ordering::Relaxed);
        m.pool.replica(0).record_completion(0);
        let r = m.report();
        assert!(r.contains("pool     dispatched 1"), "{r}");
        assert!(r.contains("replica[0]"), "{r}");
        assert!(r.contains("replica[1]"), "{r}");
    }
}

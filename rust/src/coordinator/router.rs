//! Request admission and routing.
//!
//! The router owns the inbound queue: it assigns ids, enforces a bounded
//! in-flight window (backpressure instead of unbounded memory), and hands
//! requests to the batcher in arrival order.  Property tests assert the two
//! invariants serving correctness rests on: no request is ever dropped, and
//! no request is ever duplicated.
//!
//! The router is shutdown-path infrastructure: it must keep working while
//! the rest of the pipeline is tearing down after a stage panic.  Every
//! lock acquisition therefore recovers from mutex poisoning (the queue
//! state is a plain `VecDeque` + flags, valid at every instruction, so the
//! poison bit carries no information here) — a panicking client thread
//! must not cascade into a router panic on a drain path, possibly inside a
//! `Drop`, where a second panic aborts the process.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::tensor::TensorI32;

/// Identity a network client registered with its `hello` line: attribution
/// only.  The reply stage keys per-client / per-link cohort rows in
/// [`crate::coordinator::metrics::ServingMetrics`] off it; it never touches
/// the decision path, so tagged and untagged submission produce bit-identical
/// bandit decisions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientTag {
    pub client: String,
    /// link profile name (`wifi|5g|4g|3g`, or `unspecified`)
    pub link: String,
}

/// An inference request: one tokenised sample.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// [1, T] token ids
    pub tokens: TensorI32,
    pub submitted_at: Instant,
    /// reply channel
    pub reply: Sender<Response>,
    /// optional per-client identity for cohort attribution (shared, not
    /// cloned, per request — a connection submits thousands of these)
    pub tag: Option<Arc<ClientTag>>,
}

/// The served answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prediction: usize,
    pub confidence: f32,
    /// 1-based layer whose head produced the answer
    pub infer_layer: usize,
    pub offloaded: bool,
    pub latency_ms: f64,
}

/// Outcome of a non-blocking [`Router::try_submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// queued; the reply channel will receive exactly one [`Response`]
    Accepted(u64),
    /// in-flight window full — load-shed, nothing was queued
    Shed,
    /// the router no longer accepts requests
    Shutdown,
}

/// Router limits.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// maximum queued-but-unserved requests before submit blocks
    pub max_inflight: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { max_inflight: 1024 }
    }
}

struct RouterState {
    queue: VecDeque<Request>,
    next_id: u64,
    accepting: bool,
}

/// Thread-safe request router.
pub struct Router {
    state: Mutex<RouterState>,
    space: Condvar,
    items: Condvar,
    config: RouterConfig,
}

impl Router {
    pub fn new(config: RouterConfig) -> Arc<Router> {
        Arc::new(Router {
            state: Mutex::new(RouterState {
                queue: VecDeque::new(),
                next_id: 0,
                accepting: true,
            }),
            space: Condvar::new(),
            items: Condvar::new(),
            config,
        })
    }

    /// Lock the state, recovering from poisoning (see the module docs: the
    /// state is valid at every instruction, so a panic elsewhere never
    /// leaves it inconsistent and shutdown/drain must keep working).
    fn lock_state(&self) -> MutexGuard<'_, RouterState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Submit a request; blocks when the in-flight window is full
    /// (backpressure).  Returns the assigned id, or None after shutdown.
    pub fn submit(
        &self,
        tokens: TensorI32,
        reply: Sender<Response>,
    ) -> Option<u64> {
        self.submit_tagged(tokens, reply, None)
    }

    /// [`Router::submit`] with an optional client tag for cohort
    /// attribution.  In-process producers use the untagged wrapper; the TCP
    /// front end threads each connection's registered identity through here.
    pub fn submit_tagged(
        &self,
        tokens: TensorI32,
        reply: Sender<Response>,
        tag: Option<Arc<ClientTag>>,
    ) -> Option<u64> {
        let mut st = self.lock_state();
        while st.accepting && st.queue.len() >= self.config.max_inflight {
            st = self.space.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if !st.accepting {
            return None;
        }
        Some(self.enqueue(&mut st, tokens, reply, tag))
    }

    /// Non-blocking admission: accept if the in-flight window has room,
    /// otherwise report the overload instead of waiting.  This is the
    /// load-shedding path of the network front end — a shed client gets an
    /// immediate `{"error":"shed"}` reply, never a hang — while in-process
    /// producers keep the blocking [`Router::submit`] backpressure.
    pub fn try_submit(
        &self,
        tokens: TensorI32,
        reply: Sender<Response>,
        tag: Option<Arc<ClientTag>>,
    ) -> Admission {
        let mut st = self.lock_state();
        if !st.accepting {
            return Admission::Shutdown;
        }
        if st.queue.len() >= self.config.max_inflight {
            return Admission::Shed;
        }
        Admission::Accepted(self.enqueue(&mut st, tokens, reply, tag))
    }

    fn enqueue(
        &self,
        st: &mut RouterState,
        tokens: TensorI32,
        reply: Sender<Response>,
        tag: Option<Arc<ClientTag>>,
    ) -> u64 {
        let id = st.next_id;
        st.next_id += 1;
        st.queue.push_back(Request {
            id,
            tokens,
            submitted_at: Instant::now(),
            reply,
            tag,
        });
        self.items.notify_one();
        id
    }

    /// Pull up to `max` requests, blocking until at least one is available
    /// or the router is shut down (then returns what is left, possibly
    /// empty).
    pub fn pull(&self, max: usize) -> Vec<Request> {
        let mut st = self.lock_state();
        while st.queue.is_empty() && st.accepting {
            st = self.items.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        let n = st.queue.len().min(max.max(1));
        let out: Vec<Request> = st.queue.drain(..n).collect();
        self.space.notify_all();
        out
    }

    /// Pull up to `max` requests, blocking until at least one is available,
    /// the router is shut down, or `deadline` passes (then returns whatever
    /// is queued, possibly nothing).  This is the batcher's top-up wait: a
    /// condvar wait with a deadline instead of a sleep-poll loop, so a
    /// request arriving mid-wait is picked up immediately and an empty queue
    /// costs zero CPU.
    pub fn pull_deadline(&self, max: usize, deadline: Instant) -> Vec<Request> {
        let mut st = self.lock_state();
        loop {
            if !st.queue.is_empty() {
                let n = st.queue.len().min(max.max(1));
                let out: Vec<Request> = st.queue.drain(..n).collect();
                self.space.notify_all();
                return out;
            }
            if !st.accepting {
                return Vec::new();
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _timeout) = self
                .items
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Stop accepting new requests and wake all waiters.  Must succeed even
    /// with a poisoned lock — this is the call error paths rely on to
    /// unwedge blocked stages.
    pub fn shutdown(&self) {
        let mut st = self.lock_state();
        st.accepting = false;
        self.items.notify_all();
        self.space.notify_all();
    }

    pub fn queued(&self) -> usize {
        self.lock_state().queue.len()
    }

    pub fn is_accepting(&self) -> bool {
        self.lock_state().accepting
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn tokens() -> TensorI32 {
        TensorI32::zeros(vec![1, 4])
    }

    #[test]
    fn ids_are_sequential_and_unique() {
        let r = Router::new(RouterConfig::default());
        let (tx, _rx) = mpsc::channel();
        let a = r.submit(tokens(), tx.clone()).unwrap();
        let b = r.submit(tokens(), tx.clone()).unwrap();
        let c = r.submit(tokens(), tx).unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(r.queued(), 3);
    }

    #[test]
    fn pull_preserves_arrival_order() {
        let r = Router::new(RouterConfig::default());
        let (tx, _rx) = mpsc::channel();
        for _ in 0..5 {
            r.submit(tokens(), tx.clone());
        }
        let batch = r.pull(3);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let rest = r.pull(10);
        assert_eq!(rest.iter().map(|q| q.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let r = Router::new(RouterConfig::default());
        let (tx, _rx) = mpsc::channel();
        r.submit(tokens(), tx.clone());
        r.shutdown();
        assert!(r.submit(tokens(), tx).is_none());
        // queued requests can still be drained
        assert_eq!(r.pull(10).len(), 1);
        assert!(r.pull(10).is_empty());
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let r = Router::new(RouterConfig { max_inflight: 2 });
        let (tx, _rx) = mpsc::channel();
        r.submit(tokens(), tx.clone());
        r.submit(tokens(), tx.clone());
        let r2 = Arc::clone(&r);
        let handle = std::thread::spawn(move || {
            let (tx2, _rx2) = mpsc::channel();
            r2.submit(TensorI32::zeros(vec![1, 4]), tx2)
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!handle.is_finished(), "third submit should block");
        let _ = r.pull(1);
        assert_eq!(handle.join().unwrap(), Some(2));
    }

    #[test]
    fn pull_deadline_times_out_empty_and_wakes_on_arrival() {
        use std::time::Duration;
        let r = Router::new(RouterConfig::default());
        // empty queue: returns empty at the deadline, not before
        let t0 = Instant::now();
        let got = r.pull_deadline(4, Instant::now() + Duration::from_millis(30));
        assert!(got.is_empty());
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "returned after {waited:?}");
        assert!(waited < Duration::from_millis(500), "deadline overshot: {waited:?}");
        // an arrival mid-wait wakes the puller well before the deadline
        let r2 = Arc::clone(&r);
        let puller = std::thread::spawn(move || {
            let t0 = Instant::now();
            let got = r2.pull_deadline(4, Instant::now() + Duration::from_secs(5));
            (got.len(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        let (tx, _rx) = mpsc::channel();
        r.submit(tokens(), tx);
        let (n, waited) = puller.join().unwrap();
        assert_eq!(n, 1);
        assert!(waited < Duration::from_secs(2), "woke after {waited:?}");
    }

    #[test]
    fn try_submit_sheds_instead_of_blocking() {
        let r = Router::new(RouterConfig { max_inflight: 2 });
        let (tx, _rx) = mpsc::channel();
        assert_eq!(r.try_submit(tokens(), tx.clone(), None), Admission::Accepted(0));
        assert_eq!(r.try_submit(tokens(), tx.clone(), None), Admission::Accepted(1));
        // window full: an immediate shed, not a hang, and nothing queued
        let t0 = Instant::now();
        assert_eq!(r.try_submit(tokens(), tx.clone(), None), Admission::Shed);
        assert!(t0.elapsed() < std::time::Duration::from_millis(50));
        assert_eq!(r.queued(), 2);
        // draining reopens the window
        let _ = r.pull(1);
        assert_eq!(r.try_submit(tokens(), tx.clone(), None), Admission::Accepted(2));
        r.shutdown();
        assert_eq!(r.try_submit(tokens(), tx, None), Admission::Shutdown);
    }

    #[test]
    fn tags_ride_the_request_without_perturbing_ids() {
        let r = Router::new(RouterConfig::default());
        let (tx, _rx) = mpsc::channel();
        let tag = Arc::new(ClientTag { client: "edge-7".into(), link: "4g".into() });
        let a = r.submit_tagged(tokens(), tx.clone(), Some(Arc::clone(&tag))).unwrap();
        let b = r.submit(tokens(), tx).unwrap();
        assert_eq!((a, b), (0, 1));
        let pulled = r.pull(2);
        assert_eq!(pulled[0].tag.as_deref(), Some(&*tag));
        assert!(pulled[1].tag.is_none());
    }

    #[test]
    fn poisoned_router_still_shuts_down_cleanly() {
        // A client thread panicking while holding the state lock poisons
        // the mutex.  The router must still shut down, reject new
        // submissions and drain what was queued — shutdown-path calls
        // recover from the poison instead of propagating it.
        let r = Router::new(RouterConfig::default());
        let (tx, _rx) = mpsc::channel();
        r.submit(tokens(), tx.clone()).unwrap();
        let r2 = Arc::clone(&r);
        let poisoner = std::thread::spawn(move || {
            let _guard = r2.state.lock().unwrap();
            panic!("poison the router state");
        });
        assert!(poisoner.join().is_err());
        assert!(r.state.is_poisoned(), "the panic above must poison the lock");
        assert_eq!(r.queued(), 1);
        r.shutdown();
        assert!(!r.is_accepting());
        assert!(r.submit(tokens(), tx).is_none());
        assert_eq!(r.pull(10).len(), 1);
        assert!(r.pull(10).is_empty());
    }

    #[test]
    fn no_request_dropped_or_duplicated_under_concurrency() {
        // property-style stress: N producers, one consumer, every id seen once
        let r = Router::new(RouterConfig { max_inflight: 16 });
        let producers = 4;
        let per = 50;
        let mut handles = Vec::new();
        for _ in 0..producers {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                let (tx, _rx) = mpsc::channel();
                for _ in 0..per {
                    r.submit(TensorI32::zeros(vec![1, 4]), tx.clone());
                }
            }));
        }
        let consumer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while seen.len() < producers * per {
                    for q in r.pull(7) {
                        seen.push(q.id);
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        let expected: Vec<u64> = (0..(producers * per) as u64).collect();
        assert_eq!(seen, expected);
        r.shutdown();
    }
}

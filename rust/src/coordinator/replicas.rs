//! Fault-tolerant multi-replica cloud tier.
//!
//! The classic SplitEE deployment models the cloud as one immortal worker;
//! this module generalizes it to a **pool of N replica lanes** (`--replicas
//! N`), each with its own worker thread, job queue and [`CloudSim`]-derived
//! profile, and makes the offload path survive injected faults
//! ([`crate::sim::faults`]):
//!
//! * **dispatch** — each offload group goes to one lane, picked round-robin
//!   or least-loaded ([`DispatchPolicy`]); the dispatcher (the pipeline's
//!   cloud stage) waits for that group's reply before dispatching the next,
//!   so reply order — and with it every bandit/metric invariant of the
//!   single-worker stage — is preserved by construction.
//! * **deadline + retry** — every dispatch carries a simulated offload
//!   deadline ([`ReplicaConfig::deadline_ms`]); a failed or timed-out
//!   attempt re-routes to a different replica with seeded exponential
//!   backoff (simulated, charged to the group's reply latency), bounded by
//!   [`ReplicaConfig::max_attempts`].
//! * **circuit breaker** — consecutive failures open a per-replica breaker;
//!   an open breaker stops receiving dispatches until its cooldown admits a
//!   half-open probe.  With *every* breaker open, offloads are rejected
//!   outright and counted (`breaker_open_rejections`).
//! * **graceful degradation** — a group that exhausts its retry budget (or
//!   is rejected with all breakers open) is served **on-device**: the edge
//!   runs the final-exit continuation itself at edge compute scale, and the
//!   reply stage accounts those rows exactly like a link-outage fallback.
//!
//! **Accounting discipline** (inherited from the speculation PR): every
//! dispatch attempt resolves exactly once — `dispatched == completed +
//! rerouted + fallback` at shutdown ([`PoolStat::balanced`]) — and a kill
//! with groups in flight can never hang the dispatcher (wall-clock watchdog
//! per attempt).  **Determinism contract** (the weaker replacement for
//! single-worker bit-identity, see ARCHITECTURE.md): faults are keyed on
//! the pool's dispatch sequence number and all randomness (flaky draws,
//! backoff jitter) comes from seeded streams, so identical `(seed, fault
//! schedule)` runs produce identical replies and counters, and per-replica
//! completions stay in per-replica dispatch order
//! ([`ReplicaCounters::record_completion`]).
//!
//! **Speculation interaction**: a singleton group carrying a speculative
//! continuation adopts that result only if the lane the pool dispatches it
//! to is healthy — the result stands in for *that lane's* compute.  On a
//! kill/flaky verdict the handle is killed (counted wasted) and the group
//! re-routes, i.e. it is recomputed on another replica like any failed
//! dispatch.
//!
//! [`PoolStat::balanced`]: crate::coordinator::metrics::PoolStat::balanced
//! [`ReplicaCounters::record_completion`]:
//!     crate::coordinator::metrics::ReplicaCounters::record_completion

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::codec::PayloadCodec;
use crate::coordinator::metrics::PoolCounters;
use crate::coordinator::service::{CloudRow, EdgeWork, ReplyWork};
use crate::model::{plan_batches_fused, ExitOutput, MultiExitModel};
use crate::runtime::{thread_launches, SpecHandle, SpecResult};
use crate::sim::device::{CloudSim, EdgeSim};
use crate::sim::faults::{FaultSchedule, FaultState, FaultVerdict};
use crate::tensor::TensorF32;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Wall-clock bound on waiting for any single lane reply.  Purely a
/// liveness backstop (simulated deadlines govern behaviour): a wedged lane
/// thread must never hang the serve loop.
const WATCHDOG: Duration = Duration::from_secs(60);

/// How the pool picks a lane for the next offload group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchPolicy {
    /// rotate through eligible lanes in id order
    #[default]
    RoundRobin,
    /// lane with the least accumulated simulated busy time (ties to the
    /// lowest id)
    LeastLoaded,
}

impl DispatchPolicy {
    /// Parse a `--dispatch` value.  Single source of truth for accepted
    /// names — `config.rs` validates CLI input by calling it eagerly.
    pub fn from_name(name: &str) -> Result<DispatchPolicy> {
        match name {
            "round-robin" | "rr" => Ok(DispatchPolicy::RoundRobin),
            "least-loaded" | "ll" => Ok(DispatchPolicy::LeastLoaded),
            other => bail!("--dispatch must be round-robin|least-loaded, got {other:?}"),
        }
    }

    /// Canonical name (`from_name(name())` round-trips).
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Replica-pool configuration.  The `Default` — one replica, no faults —
/// reproduces the single-worker cloud stage exactly.
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    /// number of cloud replica lanes (>= 1)
    pub n: usize,
    /// lane-selection policy
    pub dispatch: DispatchPolicy,
    /// deterministic fault schedule injected into the pool
    pub faults: FaultSchedule,
    /// simulated per-dispatch offload deadline (ms): a reply whose
    /// simulated cloud latency exceeds this counts as a timeout and
    /// re-routes
    pub deadline_ms: f64,
    /// dispatch attempts per group before degrading to on-device final exit
    pub max_attempts: usize,
    /// nominal first-retry backoff (simulated ms); doubles per retry
    pub backoff_base_ms: f64,
    /// seed of the backoff jitter stream
    pub backoff_seed: u64,
    /// consecutive failures that open a replica's circuit breaker
    pub breaker_threshold: u32,
    /// pool dispatch attempts an open breaker waits before admitting a
    /// half-open probe
    pub breaker_cooldown: u64,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            n: 1,
            dispatch: DispatchPolicy::RoundRobin,
            faults: FaultSchedule::none(),
            deadline_ms: 10_000.0,
            max_attempts: 3,
            backoff_base_ms: 0.5,
            backoff_seed: 0xB0FF,
            breaker_threshold: 3,
            breaker_cooldown: 8,
        }
    }
}

impl ReplicaConfig {
    /// Configuration from the `SPLITEE_REPLICAS` / `SPLITEE_FAULTS`
    /// environment hooks (unset = one healthy replica), for tests and the
    /// CI fault matrix.  Panics on invalid values, naming the variable.
    pub fn from_env() -> ReplicaConfig {
        let n = match std::env::var("SPLITEE_REPLICAS") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => panic!("SPLITEE_REPLICAS={v:?} is invalid — expected a positive integer"),
            },
            Err(_) => 1,
        };
        ReplicaConfig { n, faults: FaultSchedule::from_env(), ..ReplicaConfig::default() }
    }
}

/// One row's final-layer result as computed by a lane (union-gather order).
#[derive(Debug)]
struct LaneRow {
    pred: usize,
    conf: f32,
    /// simulated latency of the launch this row rode in
    cloud_ms: f64,
    /// this row's pro-rata share of the launch's simulated busy time
    share_ms: f64,
}

/// A lane's answer for one dispatched group.
#[derive(Debug)]
struct LaneReply {
    rows: Vec<LaneRow>,
    /// executable launches the lane performed for this group (measured on
    /// the lane thread, attributed iff the reply is used)
    launches: u64,
}

/// Work items on a lane's queue.
enum ReplicaJob {
    /// run the final-exit continuation for a gathered union of rows
    Compute {
        union: Arc<TensorF32>,
        rows: usize,
        split: usize,
        /// this lane's cloud profile for this dispatch
        sim: CloudSim,
        /// multiplicative host-time factor from an active `slow@` fault
        slow: f64,
        reply: Sender<Result<LaneReply, String>>,
    },
    /// an injected flaky failure: answer with an error, compute nothing
    Fail { reply: Sender<Result<LaneReply, String>> },
    /// a kill fault or pool shutdown: drop the queue and exit the thread
    Die,
}

/// Why one dispatch attempt failed.
#[derive(Debug, Clone, PartialEq)]
enum AttemptError {
    /// the lane is dead (kill fault, or its thread is gone)
    Dead,
    /// an injected flaky failure
    Flaky,
    /// the reply missed the simulated offload deadline (or the wall-clock
    /// watchdog fired)
    Timeout,
    /// the lane's compute itself errored
    Lane(String),
}

/// Per-replica circuit-breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Breaker {
    /// dispatching normally; `consecutive` failures so far
    Closed { consecutive: u32 },
    /// not dispatching; `since` is the pool dispatch sequence at opening —
    /// after `breaker_cooldown` further attempts a half-open probe is
    /// admitted
    Open { since: u64 },
}

struct ReplicaLane {
    tx: Sender<ReplicaJob>,
    handle: Option<JoinHandle<()>>,
    /// per-lane compute-scale factor on the base profile (1.0 = identical
    /// to the base; the hook for heterogeneous pools)
    scale: f64,
}

/// Immutable description of one group's offload work, shared by every
/// dispatch attempt.
struct GroupJob<'a> {
    model: &'a MultiExitModel,
    cloud: &'a CloudSim,
    union: &'a Arc<TensorF32>,
    rows: usize,
    split: usize,
    /// speculative-launch geometry — (padded batch rows, offloaded row ids)
    /// — when the group is a spec-carrying singleton
    spec_geom: Option<(usize, Vec<usize>)>,
}

/// The replica pool: N lanes plus the dispatch/retry/breaker machinery.
/// Owned by the service; the pipelined serve loop moves a `&mut` into its
/// cloud stage, the serial path calls it directly — either way there is
/// exactly one dispatcher, which is what keeps the fault clock (the
/// dispatch sequence number) deterministic.
pub struct ReplicaPool {
    lanes: Vec<ReplicaLane>,
    breakers: Vec<Breaker>,
    faults: FaultState,
    cfg: ReplicaConfig,
    counters: Arc<PoolCounters>,
    /// cumulative simulated busy ms per lane (the least-loaded key)
    load_ms: Vec<f64>,
    rr_next: usize,
    /// dispatch attempts so far: the fault schedule's batch clock and the
    /// breaker cooldown clock
    seq: u64,
    backoff_rng: Rng,
}

impl ReplicaPool {
    /// Spawn `cfg.n` lanes over a shared model.  Fault events naming a
    /// replica the pool does not have are inert (warned, never applied).
    pub fn new(
        model: Arc<MultiExitModel>,
        cfg: ReplicaConfig,
        counters: Arc<PoolCounters>,
    ) -> ReplicaPool {
        let n = cfg.n.max(1);
        for event in cfg.faults.events() {
            if event.replica() >= n {
                log::warn!(
                    "fault event targets replica {} but the pool has {n} — it will never fire",
                    event.replica()
                );
            }
        }
        let lanes = (0..n)
            .map(|i| {
                let (tx, rx) = mpsc::channel();
                let model = Arc::clone(&model);
                let handle = std::thread::Builder::new()
                    .name(format!("splitee-replica-{i}"))
                    .spawn(move || lane_loop(&model, rx))
                    .expect("spawn replica lane");
                ReplicaLane { tx, handle: Some(handle), scale: 1.0 }
            })
            .collect();
        ReplicaPool {
            lanes,
            breakers: vec![Breaker::Closed { consecutive: 0 }; n],
            faults: FaultState::new(cfg.faults.clone(), n),
            backoff_rng: Rng::new(cfg.backoff_seed),
            load_ms: vec![0.0; n],
            rr_next: 0,
            seq: 0,
            cfg,
            counters,
        }
    }

    /// The pool's shared counters (also reachable as `ServingMetrics::pool`).
    pub fn counters(&self) -> &Arc<PoolCounters> {
        &self.counters
    }

    /// Replayable dispatcher state for snapshot persistence: breaker states,
    /// per-lane load accounting, round-robin cursor, the dispatch sequence
    /// number (the fault/breaker clock), and both rng streams.  Lane threads
    /// and counters are runtime objects, not state — a restarted pool
    /// re-spawns lanes and resumes the clocks.
    pub fn export_state(&self) -> Json {
        use crate::persist::{arr_f64_hex, rng_to_json, u64_hex};
        let breakers = self
            .breakers
            .iter()
            .map(|b| match *b {
                Breaker::Closed { consecutive } => Json::obj(vec![
                    ("kind", Json::Str("closed".into())),
                    ("consecutive", u64_hex(consecutive as u64)),
                ]),
                Breaker::Open { since } => Json::obj(vec![
                    ("kind", Json::Str("open".into())),
                    ("since", u64_hex(since)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("breakers", Json::Arr(breakers)),
            ("load_ms", arr_f64_hex(&self.load_ms)),
            ("rr_next", u64_hex(self.rr_next as u64)),
            ("seq", u64_hex(self.seq)),
            ("backoff_rng", rng_to_json(&self.backoff_rng)),
            ("faults", self.faults.export_state()),
        ])
    }

    /// Restore state exported by [`ReplicaPool::export_state`].  The lane
    /// count must match (a snapshot from a differently-sized pool is a
    /// configuration mismatch); everything is parsed and validated before
    /// any field is mutated, so a bad snapshot leaves the pool untouched.
    pub fn import_state(&mut self, v: &Json) -> Result<()> {
        use crate::persist::{rng_from_json, u64_from_hex, vec_f64_from_hex};
        let n = self.lanes.len();
        let breakers_arr = v.get("breakers")?.as_arr()?;
        if breakers_arr.len() != n {
            bail!("pool snapshot has {} breakers, this pool has {n}", breakers_arr.len());
        }
        let breakers = breakers_arr
            .iter()
            .map(|b| -> Result<Breaker> {
                match b.get("kind")?.as_str()? {
                    "closed" => {
                        let consecutive = u64_from_hex(b.get("consecutive")?)?;
                        if consecutive > u32::MAX as u64 {
                            bail!("breaker consecutive count {consecutive} overflows u32");
                        }
                        Ok(Breaker::Closed { consecutive: consecutive as u32 })
                    }
                    "open" => Ok(Breaker::Open { since: u64_from_hex(b.get("since")?)? }),
                    other => bail!("unknown breaker kind {other:?}"),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let load_ms = vec_f64_from_hex(v.get("load_ms")?)?;
        if load_ms.len() != n {
            bail!("pool snapshot has {} load entries, this pool has {n}", load_ms.len());
        }
        let rr_next = u64_from_hex(v.get("rr_next")?)? as usize;
        if rr_next >= n {
            bail!("pool snapshot rr cursor {rr_next} out of range for {n} lanes");
        }
        let seq = u64_from_hex(v.get("seq")?)?;
        let backoff_rng = rng_from_json(v.get("backoff_rng")?)?;
        let mut faults = self.faults.clone();
        faults.import_state(v.get("faults")?)?;
        self.breakers = breakers;
        self.load_ms = load_ms;
        self.rr_next = rr_next;
        self.seq = seq;
        self.backoff_rng = backoff_rng;
        self.faults = faults;
        Ok(())
    }

    /// Serve one coalesced group of same-split batches: gather every
    /// batch's offloaded rows into one union, dispatch it to a lane (with
    /// retry / breaker / degradation as configured), and attribute results
    /// and simulated time back to each batch.  A group of one is the
    /// uncoalesced case — the serial path always uses that.  Drop-in
    /// replacement for the single-worker `cloud_stage_group`: under the
    /// default config the replies are identical to it, bit for bit.
    pub(crate) fn serve_group(
        &mut self,
        model: &MultiExitModel,
        edge: &EdgeSim,
        cloud: &CloudSim,
        mut group: Vec<EdgeWork>,
        codecs: &[Arc<dyn PayloadCodec>],
    ) -> Result<Vec<ReplyWork>> {
        let split = group[0].split;
        // the coalescing predicate never mixes codecs in a group
        let codec = codecs
            .get(group[0].codec)
            .with_context(|| format!("codec index {} outside the menu", group[0].codec))?;

        // Speculation resolution (see the service module docs): a singleton
        // group may serve from its speculative result — on whichever lane
        // the pool dispatches it to, if that lane turns out healthy; a
        // merged group kills every member's pending launch first, so a
        // coalesced launch never mixes speculative rows with gathered rows.
        // A non-bit-transparent codec also kills: the speculation ran on
        // the *unencoded* activation, while the continuation below consumes
        // the decoded (perturbed) payload — adopting the result would leak
        // uncompressed numerics past the uplink.
        let mut spec: Option<SpecHandle> = None;
        if group.len() == 1 && codec.bit_transparent() {
            spec = group[0].spec.take();
        } else {
            for work in group.iter_mut() {
                if let Some(handle) = work.spec.take() {
                    handle.kill();
                }
            }
        }
        let spec_geom = spec
            .is_some()
            .then(|| (group[0].batch.padded_to, group[0].offload_rows.clone()));

        // union gather across the group (host-side, one contiguous copy per
        // batch) — also the buffer a degraded group's on-device
        // continuation reads
        let mut union: Option<TensorF32> = None;
        let mut origin: Vec<(usize, usize)> = Vec::new(); // (group index, batch row)
        for (gi, work) in group.iter().enumerate() {
            if work.offload_rows.is_empty() {
                continue;
            }
            let gathered = work
                .h
                .as_ref()
                .context("offloaded rows without a split-boundary hidden state")?
                .gather_rows(&work.offload_rows)?;
            match &mut union {
                Some(u) => u.extend_rows(&gathered).map_err(|e| anyhow::anyhow!(e))?,
                None => union = Some(gathered),
            }
            origin.extend(work.offload_rows.iter().map(|&r| (gi, r)));
        }

        // Split-boundary transcode: every gathered row is encoded for
        // "transmission" and decoded back before the continuation, so the
        // cloud model consumes exactly what the (possibly lossy) uplink
        // delivered.  Identity decodes to the row's own bits, so the
        // default menu leaves the union bit-identical.  Per-row byte
        // counts ride to the reply stage on each CloudRow — the transfer
        // itself is simulated there, in batch order, to keep all link
        // state single-owner.
        let mut row_bytes: Vec<(usize, usize)> = Vec::new(); // (encoded, wire) per union row
        let mut codec_ratio = 1.0;
        let mut row_td = 0usize;
        let union = match union {
            None => None,
            Some(u) => {
                let shape = u.shape().to_vec();
                row_td = shape[1] * shape[2];
                codec_ratio = codec.nominal_ratio(row_td);
                let mut decoded: Vec<f32> = Vec::with_capacity(u.data().len());
                for r in 0..shape[0] {
                    let enc = codec.encode(&u.data()[r * row_td..(r + 1) * row_td]);
                    row_bytes.push((enc.encoded_len, enc.bytes.len()));
                    let dec = codec
                        .decode(&enc.bytes, row_td)
                        .with_context(|| format!("decoding a {} uplink payload", codec.name()))?;
                    decoded.extend_from_slice(&dec);
                }
                Some(TensorF32::new(shape, decoded).map_err(|e| anyhow::anyhow!(e))?)
            }
        };

        let mut cloud_out: Vec<Vec<CloudRow>> =
            group.iter().map(|w| Vec::with_capacity(w.offload_rows.len())).collect();
        let mut busy = vec![0.0f64; group.len()];
        let mut group_launches = 0u64;

        if let Some(union) = union {
            let union = Arc::new(union);
            let job = GroupJob { model, cloud, union: &union, rows: origin.len(), split, spec_geom };
            let (reply, penalty_ms) = self.dispatch_with_retry(&mut spec, &job);
            match reply {
                Some(reply) => {
                    group_launches = reply.launches;
                    // Per-row attribution: every row in the launch saw the
                    // same simulated latency, plus the group's accrued
                    // retry penalty (failure detection + seeded backoff);
                    // busy time splits pro rata so per-batch accounting
                    // sums to the launch totals.
                    for (ui, (lr, &(gi, row))) in
                        reply.rows.iter().zip(origin.iter()).enumerate()
                    {
                        cloud_out[gi].push(CloudRow {
                            row,
                            pred: lr.pred,
                            conf: lr.conf,
                            cloud_ms: lr.cloud_ms + penalty_ms,
                            fallback: false,
                            enc_bytes: row_bytes[ui].0,
                            wire_bytes: row_bytes[ui].1,
                        });
                        busy[gi] += lr.share_ms;
                    }
                }
                None => {
                    // Graceful degradation to on-device final exit: the
                    // edge runs the continuation itself at edge compute
                    // scale.  The reply stage accounts these rows exactly
                    // like an outage fallback (no offload charge, cascade
                    // cost to the final layer).
                    self.counters.note_fallback_group(origin.len() as u64);
                    let launches0 = thread_launches();
                    let plan = plan_batches_fused(origin.len(), model.batch_sizes());
                    let mut done = 0usize;
                    for (bsz, real) in plan {
                        let chunk = union.slice_rows(done, done + real)?.pad_rows_to(bsz)?;
                        model.warm_range(bsz, split, model.n_layers())?;
                        let t0 = Instant::now();
                        let out = model.forward_rest_exit(&chunk, split - 1)?;
                        let local_ms = edge.simulated_ms(t0.elapsed().as_secs_f64() * 1e3);
                        for i in 0..real {
                            let (gi, row) = origin[done + i];
                            cloud_out[gi].push(CloudRow {
                                row,
                                pred: out.pred[i],
                                conf: out.conf[i],
                                cloud_ms: local_ms + penalty_ms,
                                fallback: true,
                                // a degraded row never transfers
                                enc_bytes: 0,
                                wire_bytes: 0,
                            });
                            busy[gi] += local_ms / real as f64;
                        }
                        done += real;
                    }
                    group_launches = thread_launches() - launches0;
                }
            }
        }
        // defensive: a handle that survived dispatch (e.g. a zero-offload
        // group, which cannot carry one) must still resolve
        if let Some(handle) = spec.take() {
            handle.kill();
        }

        // coalescing stats count only batches whose offloads shared the
        // launch
        let contributing = group.iter().filter(|w| !w.offload_rows.is_empty()).count();
        let mut replies = Vec::with_capacity(group.len());
        for (gi, work) in group.into_iter().enumerate() {
            let offloaded_any = !work.offload_rows.is_empty();
            let EdgeWork { batch, exit_out, prefix_conf, split, codec, edge_ms, launches, .. } =
                work;
            replies.push(ReplyWork {
                batch,
                exit_out,
                prefix_conf,
                split,
                codec,
                codec_ratio,
                // raw pre-codec payload per offloaded row (frame header
                // excluded — the reply stage adds it to the transfer)
                row_raw_bytes: if offloaded_any { 4 * row_td } else { 0 },
                edge_ms,
                cloud_out: std::mem::take(&mut cloud_out[gi]),
                cloud_busy_ms: busy[gi],
                edge_launches: launches,
                cloud_launches: if gi == 0 { group_launches } else { 0 },
                group: if gi == 0 { Some(contributing) } else { None },
            });
        }
        Ok(replies)
    }

    /// Dispatch one group with bounded retries: pick a lane, attempt, and
    /// on failure re-route with seeded exponential backoff.  Returns the
    /// winning reply (`None` = degrade to on-device final exit) plus the
    /// accumulated simulated penalty (failure detection time + backoff)
    /// the group's rows must carry.
    fn dispatch_with_retry(
        &mut self,
        spec: &mut Option<SpecHandle>,
        job: &GroupJob<'_>,
    ) -> (Option<LaneReply>, f64) {
        let mut penalty_ms = 0.0;
        let mut avoid = None;
        let max_attempts = self.cfg.max_attempts.max(1);
        for attempt in 1..=max_attempts {
            let Some((lane, probe)) = self.select(avoid) else {
                // every breaker is open inside its cooldown: reject the
                // offload outright and serve edge-only
                if let Some(handle) = spec.take() {
                    handle.kill();
                }
                self.counters.note_breaker_open_rejection();
                return (None, penalty_ms);
            };
            match self.attempt(lane, probe, spec, job) {
                Ok(reply) => {
                    self.breakers[lane] = Breaker::Closed { consecutive: 0 };
                    return (Some(reply), penalty_ms);
                }
                Err(err) => {
                    self.on_failure(lane, &err);
                    penalty_ms += self.failure_detect_ms(&err, job.cloud);
                    if attempt == max_attempts {
                        self.counters.replica(lane).fallback.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.counters.replica(lane).rerouted.fetch_add(1, Ordering::Relaxed);
                        self.counters.note_retry();
                        penalty_ms += self.backoff_ms(attempt);
                        avoid = Some(lane);
                    }
                }
            }
        }
        (None, penalty_ms)
    }

    /// Pick a lane for the next dispatch.  `avoid` is the lane that just
    /// failed this group: a re-route prefers any other eligible lane,
    /// falling back to the failed one only when it is the sole survivor.
    /// Returns the lane and whether the dispatch is a half-open probe;
    /// `None` when every breaker is open inside its cooldown.
    fn select(&mut self, avoid: Option<usize>) -> Option<(usize, bool)> {
        let n = self.lanes.len();
        let mut cands: Vec<(usize, bool)> = (0..n)
            .filter_map(|i| match self.breakers[i] {
                Breaker::Closed { .. } => Some((i, false)),
                Breaker::Open { since } => {
                    let cooled = self.seq.saturating_sub(since) >= self.cfg.breaker_cooldown;
                    cooled.then_some((i, true))
                }
            })
            .collect();
        if cands.len() > 1 {
            if let Some(avoid) = avoid {
                cands.retain(|&(i, _)| i != avoid);
            }
        }
        match self.cfg.dispatch {
            DispatchPolicy::RoundRobin => {
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if let Some(&(lane, probe)) = cands.iter().find(|&&(c, _)| c == i) {
                        self.rr_next = (lane + 1) % n;
                        return Some((lane, probe));
                    }
                }
                None
            }
            DispatchPolicy::LeastLoaded => cands.into_iter().min_by(|a, b| {
                self.load_ms[a.0]
                    .partial_cmp(&self.load_ms[b.0])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(&b.0))
            }),
        }
    }

    /// One dispatch attempt on `lane`: consult the fault schedule, adopt
    /// the speculative result or compute on the lane, enforce the deadline,
    /// and on success record completion (order-checked) and busy time.
    fn attempt(
        &mut self,
        lane: usize,
        probe: bool,
        spec: &mut Option<SpecHandle>,
        job: &GroupJob<'_>,
    ) -> Result<LaneReply, AttemptError> {
        let seq = self.seq;
        self.seq += 1;
        {
            let c = self.counters.replica(lane);
            c.dispatched.fetch_add(1, Ordering::Relaxed);
            if probe {
                c.probes.fetch_add(1, Ordering::Relaxed);
            }
        }
        let slow = match self.faults.verdict(seq, lane) {
            FaultVerdict::Killed => {
                // the replica process dies with this dispatch in flight:
                // its lane thread exits, and a pending speculative result
                // is killed on re-route, never consumed
                let _ = self.lanes[lane].tx.send(ReplicaJob::Die);
                if let Some(handle) = spec.take() {
                    handle.kill();
                }
                return Err(AttemptError::Dead);
            }
            FaultVerdict::Failed => {
                if let Some(handle) = spec.take() {
                    handle.kill();
                }
                let (rtx, rrx) = mpsc::channel();
                if self.lanes[lane].tx.send(ReplicaJob::Fail { reply: rtx }).is_err() {
                    return Err(AttemptError::Dead);
                }
                // drain the (error) reply so the failure is synchronous
                let _ = rrx.recv_timeout(WATCHDOG);
                return Err(AttemptError::Flaky);
            }
            FaultVerdict::Slowed(f) => f,
            FaultVerdict::Healthy => 1.0,
        };
        // healthy (possibly slowed) lane: adopt the speculative result if
        // the group carries one, otherwise compute on the lane
        let reply = match spec.take() {
            Some(handle) => match self.adopt(handle, lane, slow, job) {
                Some(reply) => reply,
                // the speculation lane itself failed — recompute on this
                // replica inside the same attempt; no replica failure is
                // charged, exactly like the single-worker recompute path
                None => self.compute_on(lane, slow, job)?,
            },
            None => self.compute_on(lane, slow, job)?,
        };
        let worst = reply.rows.iter().map(|r| r.cloud_ms).fold(0.0f64, f64::max);
        if worst > self.cfg.deadline_ms {
            return Err(AttemptError::Timeout);
        }
        let busy: f64 = reply.rows.iter().map(|r| r.share_ms).sum();
        self.load_ms[lane] += busy;
        let c = self.counters.replica(lane);
        c.add_busy_ms(busy);
        c.record_completion(seq);
        Ok(reply)
    }

    /// Consume a speculative result as `lane`'s answer.  `None` means the
    /// speculation lane failed and the caller should compute normally (the
    /// handle is already resolved either way).
    fn adopt(
        &mut self,
        handle: SpecHandle,
        lane: usize,
        slow: f64,
        job: &GroupJob<'_>,
    ) -> Option<LaneReply> {
        let (padded, offload_rows) = job.spec_geom.as_ref()?;
        let result = match handle.take() {
            Ok(result) => result,
            // already counted wasted by take(); recompute
            Err(e) => {
                log::warn!("speculative continuation failed ({e:#}) — recomputing");
                return None;
            }
        };
        let SpecResult { head, launches, host_ms } = result;
        let out = match ExitOutput::from_head(head) {
            Ok(out) => out,
            Err(e) => {
                log::warn!("speculative head unusable ({e:#}) — recomputing");
                return None;
            }
        };
        let real = offload_rows.len();
        // Normalize the simulated-time basis to the launch this result
        // replaced (see the service module docs); an active slow fault
        // scales the host time exactly as it would have scaled the lane's
        // own compute.
        let spec_rows = (*padded).max(1);
        let serial_rows = plan_batches_fused(real, job.model.batch_sizes())
            .first()
            .map(|&(bsz, _)| bsz)
            .unwrap_or(spec_rows);
        let sim = job.cloud.scaled(self.lanes[lane].scale);
        let cloud_ms = sim.simulated_ms(host_ms * slow * serial_rows as f64 / spec_rows as f64);
        let rows = offload_rows
            .iter()
            .map(|&row| LaneRow {
                pred: out.pred[row],
                conf: out.conf[row],
                cloud_ms,
                share_ms: cloud_ms / real as f64,
            })
            .collect();
        Some(LaneReply { rows, launches })
    }

    /// Send the group's compute to `lane` and wait (watchdog-bounded) for
    /// its reply.
    fn compute_on(
        &mut self,
        lane: usize,
        slow: f64,
        job: &GroupJob<'_>,
    ) -> Result<LaneReply, AttemptError> {
        let (rtx, rrx) = mpsc::channel();
        let msg = ReplicaJob::Compute {
            union: Arc::clone(job.union),
            rows: job.rows,
            split: job.split,
            sim: job.cloud.scaled(self.lanes[lane].scale),
            slow,
            reply: rtx,
        };
        if self.lanes[lane].tx.send(msg).is_err() {
            return Err(AttemptError::Dead);
        }
        match rrx.recv_timeout(WATCHDOG) {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(AttemptError::Lane(e)),
            // the lane died mid-compute
            Err(RecvTimeoutError::Disconnected) => Err(AttemptError::Dead),
            // wedged lane: the watchdog keeps the dispatcher live
            Err(RecvTimeoutError::Timeout) => Err(AttemptError::Timeout),
        }
    }

    /// Breaker bookkeeping for one failed attempt.  Timeouts are counted
    /// here so every failure site shares one accounting path.
    fn on_failure(&mut self, lane: usize, err: &AttemptError) {
        if matches!(err, AttemptError::Timeout) {
            self.counters.replica(lane).timeouts.fetch_add(1, Ordering::Relaxed);
        }
        let opened = match self.breakers[lane] {
            Breaker::Closed { consecutive } => {
                let consecutive = consecutive + 1;
                if consecutive >= self.cfg.breaker_threshold {
                    self.breakers[lane] = Breaker::Open { since: self.seq };
                    true
                } else {
                    self.breakers[lane] = Breaker::Closed { consecutive };
                    false
                }
            }
            // a failed half-open probe re-arms the cooldown
            Breaker::Open { .. } => {
                self.breakers[lane] = Breaker::Open { since: self.seq };
                true
            }
        };
        if opened {
            self.counters.replica(lane).breaker_opens.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Simulated time burned detecting one failed attempt: a timed-out
    /// dispatch consumed its whole deadline; dead/flaky/errored lanes fail
    /// at the service boundary.
    fn failure_detect_ms(&self, err: &AttemptError, cloud: &CloudSim) -> f64 {
        match err {
            AttemptError::Timeout => self.cfg.deadline_ms,
            _ => cloud.service_overhead_ms,
        }
    }

    /// Seeded exponential backoff before retry `attempt + 1`: the nominal
    /// `base * 2^(attempt-1)`, jittered to `[0.5, 1.5)` of nominal from the
    /// pool's own stream.  Part of the deterministic replay surface, and
    /// *simulated* — charged to the group's reply latency, never slept.
    fn backoff_ms(&mut self, attempt: usize) -> f64 {
        let exp = 1u64 << (attempt - 1).min(16) as u32;
        let jitter = 0.5 + self.backoff_rng.next_f64();
        let ms = self.cfg.backoff_base_ms * exp as f64 * jitter;
        self.counters.add_backoff_ms(ms);
        ms
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        // Close every lane's queue, then join.  A lane that already died
        // (kill fault) joins immediately; join errors are swallowed — drop
        // runs on error unwinds too, and must never double-panic.
        for lane in self.lanes.iter() {
            let _ = lane.tx.send(ReplicaJob::Die);
        }
        for lane in self.lanes.iter_mut() {
            if let Some(handle) = lane.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

/// A lane thread's loop: serve jobs until told to die or the pool drops
/// the queue.  Launch counts are measured here, on the lane's own thread,
/// and shipped back in the reply — the same convention as the speculation
/// lane.
fn lane_loop(model: &MultiExitModel, rx: Receiver<ReplicaJob>) {
    while let Ok(job) = rx.recv() {
        match job {
            ReplicaJob::Die => return,
            ReplicaJob::Fail { reply } => {
                let _ = reply.send(Err("injected flaky failure".to_string()));
            }
            ReplicaJob::Compute { union, rows, split, sim, slow, reply } => {
                let result =
                    lane_compute(model, &union, rows, split, &sim, slow).map_err(|e| {
                        format!("{e:#}")
                    });
                let _ = reply.send(result);
            }
        }
    }
}

/// The continuation compute for one dispatched union: the exact chunk loop
/// of the single-worker cloud stage (plan, pad, warm, fused
/// `forward_rest_exit`), so a healthy one-lane pool is bit-identical to it.
fn lane_compute(
    model: &MultiExitModel,
    union: &TensorF32,
    rows: usize,
    split: usize,
    sim: &CloudSim,
    slow: f64,
) -> Result<LaneReply> {
    let launches0 = thread_launches();
    let mut out_rows = Vec::with_capacity(rows);
    let plan = plan_batches_fused(rows, model.batch_sizes());
    let mut done = 0usize;
    for (bsz, real) in plan {
        let chunk = union.slice_rows(done, done + real)?.pad_rows_to(bsz)?;
        // compile-if-needed before the timed region (see warm_range)
        model.warm_range(bsz, split, model.n_layers())?;
        let t0 = Instant::now();
        let out = model.forward_rest_exit(&chunk, split - 1)?;
        let cloud_ms = sim.simulated_ms(t0.elapsed().as_secs_f64() * 1e3 * slow);
        for i in 0..real {
            out_rows.push(LaneRow {
                pred: out.pred[i],
                conf: out.conf[i],
                cloud_ms,
                share_ms: cloud_ms / real as f64,
            });
        }
        done += real;
    }
    Ok(LaneReply { rows: out_rows, launches: thread_launches() - launches0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_policy_names_round_trip() {
        for name in ["round-robin", "least-loaded"] {
            assert_eq!(DispatchPolicy::from_name(name).unwrap().name(), name);
        }
        assert_eq!(DispatchPolicy::from_name("rr").unwrap(), DispatchPolicy::RoundRobin);
        assert_eq!(DispatchPolicy::from_name("ll").unwrap(), DispatchPolicy::LeastLoaded);
        assert!(DispatchPolicy::from_name("fastest").is_err());
    }

    #[test]
    fn default_config_is_the_single_worker_stage() {
        let cfg = ReplicaConfig::default();
        assert_eq!(cfg.n, 1);
        assert_eq!(cfg.dispatch, DispatchPolicy::RoundRobin);
        assert!(cfg.faults.is_empty());
        assert!(cfg.max_attempts >= 1);
        assert!(cfg.breaker_threshold >= 1);
    }

    #[test]
    fn pool_state_round_trips_and_rejects_size_mismatch() {
        use crate::model::ModelWeights;
        use crate::runtime::Backend;
        let model = Arc::new(
            MultiExitModel::from_weights(
                "synthetic",
                "reference",
                ModelWeights::synthetic(3, 8, 16, 32, 4, 2, 0x57A7E),
                2,
                4,
                vec![1],
                &Backend::reference(),
            )
            .unwrap(),
        );
        let cfg = ReplicaConfig {
            n: 2,
            faults: FaultSchedule::from_name("flaky@0:0.5,seed=9").unwrap(),
            ..ReplicaConfig::default()
        };
        let mut pool = ReplicaPool::new(Arc::clone(&model), cfg.clone(), PoolCounters::new(2));
        // hand-advance the replayable fields as served traffic would
        pool.seq = 17;
        pool.rr_next = 1;
        pool.load_ms = vec![4.25, 9.5];
        pool.breakers = vec![Breaker::Closed { consecutive: 2 }, Breaker::Open { since: 11 }];
        pool.backoff_rng.next_f64();
        pool.faults.verdict(0, 0);
        let state = pool.export_state();

        let mut restored = ReplicaPool::new(Arc::clone(&model), cfg.clone(), PoolCounters::new(2));
        restored.import_state(&state).unwrap();
        assert_eq!(restored.seq, 17);
        assert_eq!(restored.rr_next, 1);
        assert_eq!(restored.breakers, pool.breakers);
        for (a, b) in restored.load_ms.iter().zip(&pool.load_ms) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // both rng streams resume in lockstep
        assert_eq!(restored.backoff_rng.next_f64(), pool.backoff_rng.next_f64());
        for seq in 20..60 {
            assert_eq!(restored.faults.verdict(seq, 0), pool.faults.verdict(seq, 0));
        }

        // a snapshot from a 2-lane pool must not load into a 3-lane pool,
        // and the rejected import must leave the target untouched
        let mut bigger = ReplicaPool::new(
            model,
            ReplicaConfig { n: 3, ..ReplicaConfig::default() },
            PoolCounters::new(3),
        );
        assert!(bigger.import_state(&state).is_err());
        assert_eq!(bigger.seq, 0);
        assert_eq!(bigger.breakers, vec![Breaker::Closed { consecutive: 0 }; 3]);
    }
}

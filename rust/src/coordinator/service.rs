//! The serving loop: policy-driven split execution over dynamic batches.
//!
//! One `Service` owns the model, the edge/cloud/link simulators, the bandit
//! policy and the metrics.  The split-layer choice is per *batch* (the
//! bandit's decision is distribution-level, exactly as in the paper — one
//! deployment has one split); exit-or-offload is per sample; the bandit is
//! updated once per sample with the realised reward.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::router::{Response, Router};
use crate::cost::CostModel;
use crate::model::{plan_batches, MultiExitModel};
use crate::policy::{SplitEePolicy, SplitEeSPolicy};
use crate::sim::device::{CloudSim, EdgeSim};
use crate::sim::link::{LinkSim, TransferResult};
use crate::tensor::TensorF32;

/// Which split policy drives the service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// UCB over split layers, single-head inference (Algorithm 1)
    SplitEe,
    /// UCB with side observations (section 4.2)
    SplitEeS,
    /// fixed split layer (1-based)
    Fixed(usize),
    /// no split: every sample to the final layer on-device
    FinalExit,
}

/// Service parameters.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub policy: PolicyKind,
    /// exit threshold alpha (from the manifest's calibrated value)
    pub alpha: f64,
    /// UCB exploration parameter
    pub beta: f64,
    pub batcher: BatcherConfig,
}

/// Policy state held by the service.
enum PolicyState {
    SplitEe(SplitEePolicy),
    SplitEeS(SplitEeSPolicy),
    Fixed(usize),
    FinalExit,
}

/// The serving engine.
pub struct Service {
    model: Arc<MultiExitModel>,
    cost: CostModel,
    pub edge: EdgeSim,
    pub cloud: CloudSim,
    pub link: LinkSim,
    policy: PolicyState,
    alpha: f64,
    pub metrics: ServingMetrics,
}

impl Service {
    pub fn new(
        model: Arc<MultiExitModel>,
        cost: CostModel,
        link: LinkSim,
        config: &ServiceConfig,
    ) -> Service {
        let l = model.n_layers();
        let policy = match config.policy {
            PolicyKind::SplitEe => {
                PolicyState::SplitEe(SplitEePolicy::new(l, config.alpha, config.beta))
            }
            PolicyKind::SplitEeS => {
                PolicyState::SplitEeS(SplitEeSPolicy::new(l, config.alpha, config.beta))
            }
            PolicyKind::Fixed(k) => PolicyState::Fixed(k.clamp(1, l)),
            PolicyKind::FinalExit => PolicyState::FinalExit,
        };
        Service {
            metrics: ServingMetrics::new(l),
            model,
            cost,
            edge: EdgeSim::default(),
            cloud: CloudSim::default(),
            link,
            policy,
            alpha: config.alpha,
        }
    }

    fn choose_split(&mut self) -> usize {
        match &mut self.policy {
            PolicyState::SplitEe(p) => p.choose_split(),
            PolicyState::SplitEeS(p) => p.choose_split(),
            PolicyState::Fixed(k) => *k,
            PolicyState::FinalExit => self.model.n_layers(),
        }
    }

    fn side_info(&self) -> bool {
        matches!(self.policy, PolicyState::SplitEeS(_))
    }

    /// Run the blocking serve loop until the router is shut down + drained.
    pub fn run(&mut self, router: Arc<Router>, batcher_config: BatcherConfig) -> Result<()> {
        let mut batcher = Batcher::new(router, batcher_config);
        while let Some(batch) = batcher.next_batch() {
            self.serve_batch(batch)?;
        }
        Ok(())
    }

    /// Serve one formed batch.
    pub fn serve_batch(&mut self, batch: Batch) -> Result<()> {
        let l = self.model.n_layers();
        let n_real = batch.real_len();
        let split = self.choose_split();
        let side = self.side_info();
        self.metrics.record_batch(n_real, batch.padded_to);

        // ---- edge share (real PJRT compute on the padded batch)
        let t0 = Instant::now();
        let mut h = self.model.embed(&batch.tokens)?;
        let mut prefix_conf: Vec<Vec<f32>> = Vec::new(); // per layer, per row
        for layer in 0..split {
            h = self.model.block(&h, layer)?;
            if side && layer + 1 < split {
                prefix_conf.push(self.model.exit_head(&h, layer)?.conf);
            }
        }
        let exit_out = self.model.exit_head(&h, split - 1)?;
        let edge_ms = self.edge.simulated_ms(t0.elapsed().as_secs_f64() * 1e3);

        // ---- per-sample exit-or-offload
        let mut offload_rows: Vec<usize> = Vec::new();
        for row in 0..n_real {
            let conf = exit_out.conf[row] as f64;
            if conf < self.alpha && split < l {
                offload_rows.push(row);
            }
        }

        // ---- cloud share for the offloaded subset
        let mut final_preds: Vec<(usize, usize, f32, f64, bool)> = Vec::new();
        // (row, pred, conf, extra_latency_ms, outage)
        if !offload_rows.is_empty() {
            let payload = LinkSim::activation_payload(self.model.seq_len(), h.shape()[2]);
            // gather offloaded rows of h into a contiguous tensor
            let rows: Vec<TensorF32> = offload_rows
                .iter()
                .map(|&r| h.slice_rows(r, r + 1).expect("row slice"))
                .collect();
            let row_refs: Vec<&TensorF32> = rows.iter().collect();
            let gathered = TensorF32::concat_rows(&row_refs).expect("gather");
            let plan = plan_batches(offload_rows.len(), self.model.batch_sizes());
            let mut done = 0usize;
            for (bsz, real) in plan {
                let chunk = gathered
                    .slice_rows(done, done + real)
                    .expect("chunk")
                    .pad_rows_to(bsz)
                    .expect("pad");
                let t1 = Instant::now();
                let h_final = self.model.forward_rest(&chunk, split - 1)?;
                let out = self.model.exit_head(&h_final, l - 1)?;
                let cloud_ms = self.cloud.simulated_ms(t1.elapsed().as_secs_f64() * 1e3);
                for i in 0..real {
                    let row = offload_rows[done + i];
                    match self.link.transfer(payload) {
                        TransferResult::Delivered { ms, .. } => {
                            final_preds.push((row, out.pred[i], out.conf[i], ms + cloud_ms, false));
                        }
                        TransferResult::Outage => {
                            // fall back: the cloud result is unreachable; the
                            // edge must finish locally (same numbers, edge
                            // timing, no offload charge)
                            let local_ms = self.edge.simulated_ms(cloud_ms / self.cloud.compute_scale.max(1e-9));
                            final_preds.push((row, out.pred[i], out.conf[i], local_ms, true));
                        }
                    }
                }
                done += real;
            }
        }

        // ---- replies + policy updates + metrics
        let mut final_by_row = vec![None; n_real];
        for (row, pred, conf, extra_ms, outage) in final_preds {
            final_by_row[row] = Some((pred, conf, extra_ms, outage));
        }
        for (row, req) in batch.requests.iter().enumerate() {
            let queue_ms = batch
                .formed_at
                .duration_since(req.submitted_at)
                .as_secs_f64()
                * 1e3;
            let (infer_layer, pred, conf, offloaded, outage, extra_ms) = match &final_by_row[row]
            {
                Some((pred, conf, extra_ms, outage)) => {
                    (l, *pred, *conf, !*outage, *outage, *extra_ms)
                }
                None => (split, exit_out.pred[row], exit_out.conf[row], false, false, 0.0),
            };
            let latency = queue_ms + edge_ms + extra_ms;
            let (cost, energy, reward) = if outage {
                let gamma = self.cost.compute_cost_cascade(l);
                (gamma, self.edge.energy(gamma, false), self.cost.reward_exit(l, conf as f64, side))
            } else if offloaded {
                (
                    self.cost.total_cost(split, true, side),
                    self.edge.energy(self.cost.gamma(split, side), true),
                    self.cost.reward_offload(split, conf as f64, side),
                )
            } else {
                (
                    self.cost.total_cost(split, false, side),
                    self.edge.energy(self.cost.gamma(split, side), false),
                    self.cost.reward_exit(split, exit_out.conf[row] as f64, side),
                )
            };

            match &mut self.policy {
                PolicyState::SplitEe(p) => p.record(split, reward),
                PolicyState::SplitEeS(p) => {
                    let mut prefix: Vec<f32> =
                        prefix_conf.iter().map(|layer| layer[row]).collect();
                    prefix.push(exit_out.conf[row]);
                    let conf_final = offloaded.then_some(conf as f64);
                    p.record_prefix(&self.cost, &prefix, conf_final);
                }
                _ => {}
            }

            self.metrics.record_request(
                infer_layer,
                offloaded,
                outage,
                latency,
                queue_ms,
                cost,
                energy,
            );
            let _ = req.reply.send(Response {
                id: req.id,
                prediction: pred,
                confidence: conf,
                infer_layer,
                offloaded,
                latency_ms: latency,
            });
        }
        Ok(())
    }

    /// Current bandit state summary, if the policy is a bandit.
    pub fn bandit_summary(&self) -> Option<(usize, Vec<(u64, f64)>)> {
        let ucb = match &self.policy {
            PolicyState::SplitEe(p) => p.ucb(),
            PolicyState::SplitEeS(p) => p.ucb(),
            _ => return None,
        };
        let arms = (0..ucb.k()).map(|i| (ucb.arm(i).n, ucb.arm(i).q)).collect();
        Some((ucb.best_empirical() + 1, arms))
    }
}

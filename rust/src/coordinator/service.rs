//! The serving loop: policy-driven split execution over dynamic batches.
//!
//! One `Service` owns the model, the edge/cloud/link simulators, the bandit
//! policy and the metrics.  The split-layer choice is per *batch* (the
//! bandit's decision is distribution-level, exactly as in the paper — one
//! deployment has one split); exit-or-offload is per sample; the bandit is
//! updated once per sample with the realised reward.
//!
//! # Pipelined execution
//!
//! [`Service::run`] executes batches through a **staged pipeline**:
//!
//! ```text
//!     batcher thread  ──►  edge stage  ──►  cloud stage  ──►  reply stage
//!     (forms batches)      (embed +         (coalesced        (link sim,
//!                           fused range      continuation      bandit updates,
//!                           to the split)    for offloads)     metrics, replies)
//! ```
//!
//! Stages are connected by **bounded channels**, so batch formation (and its
//! `max_wait` deadline) and reply delivery never block model compute, and the
//! edge stage of batch *k+1* overlaps the cloud stage of batch *k*.  Policy
//! semantics are unchanged: all bandit updates happen in the reply stage in
//! batch order, and the split for batch *k+1* is released to the edge stage
//! only after batch *k*'s updates are applied — the same decision sequence as
//! the serial path for a fixed arrival order.  (Only the split-independent
//! `embed` of batch *k+1* runs before its split is known; for fixed-split and
//! final-exit policies the whole edge stage overlaps freely.)
//!
//! # Partition launches and offload coalescing
//!
//! The edge stage runs **one fused block-range launch** per batch (plus the
//! embed and the exit head) via the `chain{n}` partition graphs; the
//! activation stays device-resident across the range and crosses the host
//! boundary only at the split point.  The cloud stage **coalesces adjacent
//! batches with the same split**: their offloaded rows merge into one fused
//! `forward_rest` launch, bounded by the largest compiled batch size and a
//! short deadline ([`CoalesceConfig`]).  Coalescing waits only under
//! static-split policies — with a bandit policy the next batch cannot reach
//! the cloud stage before this batch's rewards are applied, so waiting would
//! only add latency.  Per-row cloud-time attribution and reply order are
//! preserved, so rewards and bandit updates are unchanged (asserted by
//! `tests/integration.rs::pipelined_matches_serial_decisions`).
//!
//! # Speculative edge continuation (kill-on-exit)
//!
//! With speculation enabled ([`SpeculateMode`]), the edge stage does not
//! idle while the exit-head verdict is computed: right after the fused
//! `blocks[i..j)` range launch it issues the *next* block-range launch —
//! the continuation `blocks[j..L)` + final head — on a dedicated
//! speculation lane, concurrently with the verdict.  The in-flight handle
//! travels to the cloud stage inside the batch's `EdgeWork` (under
//! static-split policies this is the "speculative hidden" arriving ahead of
//! its resolution).  Three rules keep it provably invisible:
//!
//! * **kill-on-exit** — a batch whose rows all exit at the split kills its
//!   speculative launch; the wasted work is never attributed to any launch
//!   counter or simulated-latency account (it ran on the lane thread).
//! * **decision transparency** — speculative results are consumed only on
//!   backends where the full-batch continuation is bit-identical per row to
//!   the serial gathered launch (`ModelExecutor::speculation_transparent`),
//!   so outputs, rewards and bandit decisions are *exactly* the serial
//!   path's for any arrival order (asserted by `tests/speculation.rs`).
//! * **no mixed groups** — a coalesced group never consumes speculative
//!   rows: merging kills every member's pending launch first, and a
//!   speculative result only ever serves a singleton group.  Used results
//!   are attributed exactly like the launch they replaced: same launch
//!   count, and the measured speculative compute rescaled to the padded
//!   size the serial launch would have run — so the launch acceptance
//!   tests hold and latency metrics stay comparable with speculation on
//!   or off.
//!
//! Issued/used/wasted lifecycle counts live in `ServingMetrics::spec`
//! (`SpecCounters`, consistent snapshots).
//!
//! # Fault-tolerant replica pool (cloud tier)
//!
//! The cloud stage does not run the continuation on its own thread: it owns
//! a [`ReplicaPool`] ([`ServiceConfig::replicas`], `--replicas N`) of
//! worker lanes and dispatches each coalesced group to one of them —
//! round-robin or least-loaded — under a simulated offload deadline, with
//! bounded re-route-and-retry (seeded exponential backoff), per-replica
//! circuit breakers, and graceful degradation to on-device final-exit
//! inference when no replica can serve (see
//! [`crate::coordinator::replicas`] for the machinery and
//! [`crate::sim::faults`] for the deterministic `--faults` schedule that
//! exercises it).  Under the default config — one healthy replica — the
//! pool reproduces the single-worker cloud stage bit for bit, so the
//! pipelined-matches-serial suites are unaffected.
//!
//! With faults enabled, pipelined==serial *bit-identity* no longer holds
//! (the two paths dispatch in different sequence-number order, so faults
//! land on different groups); the service instead guarantees the **weaker
//! determinism contract** asserted by `tests/failure_injection.rs`: every
//! request is answered exactly once (`dispatched == completed + rerouted +
//! fallback` at shutdown), per-replica completions happen in per-replica
//! dispatch order, and two runs with the same `(seed, fault schedule)`
//! produce bit-identical replies and fault/retry counters.
//!
//! # Dynamic link scenarios and the context-aware split policy
//!
//! The uplink need not be constant: [`ServiceConfig::link`] selects a
//! [`LinkScenario`] (`--link static|markov|trace:<path>`) that is stepped
//! **once per batch, in batch order, in the reply stage** — the only stage
//! holding mutable policy/link state.  The sampled [`LinkState`] is what the
//! batch is served under: its effective profile drives the uplink
//! simulation, its instantaneous offloading cost replaces the cost model's
//! `o` for this batch's rewards ([`LinkState::effective_cost`]), an outage
//! state forces the on-device fallback, and its **context** id keys the
//! [`ContextualSplitPolicy`] ([`PolicyKind::Contextual`]) — the split is
//! chosen from the context observed at decision time and the realised
//! rewards are credited back to that same context.  Because the scenario
//! advances deterministically (seeded Markov chain or trace replay) and
//! both the advance and the reward updates are serialized in the reply
//! stage, the pipelined path stays decision-identical to serial replay of
//! the same link trace; `--link static` draws no extra randomness and
//! leaves the cost model untouched, so it reproduces the fixed-link
//! behaviour bit for bit.  Per-state traffic and split-choice histograms
//! land in `ServingMetrics::link_states`.
//!
//! # Split-boundary payload codecs
//!
//! [`ServiceConfig::codecs`] (`--codecs identity,f16,i8,topk:64`) installs
//! a payload codec menu at the split boundary ([`crate::codec`]): the
//! cloud stage encodes every offloaded row before "transmission", the
//! uplink transfer and the offload cost `o` are charged from the *encoded*
//! bytes, and the replica decodes before running the continuation — the
//! cloud model consumes exactly what the (possibly lossy) uplink
//! delivered.  The bandit and contextual policies learn over the joint
//! `(split, codec)` action space (one UCB arm per pair).  The identity
//! codec — the default, single-entry menu — is bit-transparent, so the
//! default service stays byte- and decision-identical to the codec-less
//! one; a non-transparent codec kills speculative launches instead of
//! adopting them, because the speculation ran on the unencoded
//! activation.  See `ARCHITECTURE.md`'s "Split-boundary codec seam".
//!
//! [`Service::run_serial`] keeps the single-threaded reference path; both
//! paths share the same stage functions, so their per-request outputs are
//! identical by construction (asserted by `tests/integration.rs`).

use std::path::Path;
use std::sync::mpsc::{self, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::codec::{CodecMenu, PayloadCodec, FRAME_OVERHEAD};
use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::metrics::{PoolCounters, ServingMetrics};
use crate::coordinator::replicas::{ReplicaConfig, ReplicaPool};
use crate::coordinator::router::{Response, Router};
use crate::cost::CostModel;
use crate::cost::NetworkProfile;
use crate::model::{ExitOutput, HiddenState, MultiExitModel};
use crate::persist::{Snapshot, SnapshotConfig};
use crate::policy::{ContextualSplitPolicy, SplitEePolicy, SplitEeSPolicy};
use crate::runtime::{thread_launches, SpecCounters, SpecHandle, SpecLane};
use crate::sim::device::{CloudSim, EdgeSim};
use crate::sim::link::{LinkScenario, LinkSim, LinkState, TransferResult};
use crate::tensor::TensorF32;
use crate::util::json::Json;

/// Bound on in-flight batches between adjacent pipeline stages.  Small on
/// purpose: enough to keep every stage busy, shallow enough that queue wait
/// stays visible as backpressure instead of hidden buffering.
const PIPELINE_DEPTH: usize = 2;

/// Which split policy drives the service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// UCB over split layers, single-head inference (Algorithm 1)
    SplitEe,
    /// UCB with side observations (section 4.2)
    SplitEeS,
    /// context-aware UCB: independent arm statistics per link context, for
    /// time-varying uplink scenarios (I-SplitEE-style adaptation)
    Contextual,
    /// fixed split layer (1-based)
    Fixed(usize),
    /// no split: every sample to the final layer on-device
    FinalExit,
}

/// Cross-batch offload coalescing parameters (cloud stage).
#[derive(Debug, Clone, Copy)]
pub struct CoalesceConfig {
    /// merge adjacent same-split batches' offloads into one fused launch
    pub enabled: bool,
    /// how long the cloud stage may hold a group open for the next batch
    /// (wall clock; simulated latency is unaffected)
    pub max_wait: Duration,
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        CoalesceConfig { enabled: true, max_wait: Duration::from_micros(200) }
    }
}

/// When the edge stage issues speculative continuations past the split
/// while the exit-head verdict is in flight (kill-on-exit; see the module
/// docs for the invariants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpeculateMode {
    /// speculate whenever the backend's speculative results are decision-
    /// transparent (bit-identical to the serial path); on other backends
    /// this silently degrades to `Off` rather than risking ulp-level
    /// decision drift
    On,
    /// never speculate (the serial-identical default)
    #[default]
    Off,
    /// speculate when the backend is decision-transparent *and* the host
    /// has spare parallelism for the speculation lane (>= 4 hardware
    /// threads) — otherwise the lane would steal cycles from the serving
    /// stages instead of overlapping them
    Auto,
}

impl SpeculateMode {
    /// Parse a `--speculate` value.
    pub fn from_name(name: &str) -> Result<SpeculateMode> {
        match name {
            "on" => Ok(SpeculateMode::On),
            "off" => Ok(SpeculateMode::Off),
            "auto" => Ok(SpeculateMode::Auto),
            other => anyhow::bail!("--speculate must be on, off or auto, got {other:?}"),
        }
    }

    /// Test-matrix hook: `SPLITEE_SPECULATE=on|off|auto` (default `Off`
    /// when unset).  The integration and speculation suites build their
    /// services with this, so CI gates both speculation paths over the same
    /// tests.  An unparseable value panics — naming the variable, the
    /// rejected value and the accepted values — rather than silently
    /// testing the off path under an "on" job label.
    pub fn from_env() -> SpeculateMode {
        match std::env::var("SPLITEE_SPECULATE") {
            Ok(v) => match SpeculateMode::from_name(&v) {
                Ok(m) => m,
                Err(_) => panic!(
                    "SPLITEE_SPECULATE={v:?} is invalid — accepted values: on, off, auto"
                ),
            },
            Err(_) => SpeculateMode::Off,
        }
    }
}

/// Service parameters.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub policy: PolicyKind,
    /// exit threshold alpha (from the manifest's calibrated value)
    pub alpha: f64,
    /// UCB exploration parameter
    pub beta: f64,
    pub batcher: BatcherConfig,
    /// cloud-stage cross-batch offload coalescing
    pub coalesce: CoalesceConfig,
    /// speculative edge continuation past the split (kill-on-exit)
    pub speculate: SpeculateMode,
    /// time-varying uplink scenario, stepped once per batch.  The service
    /// clones this, so every service built from one config replays the
    /// identical condition sequence; [`LinkScenario::Static`] (the
    /// `Default`) is the fixed-link behaviour, bit for bit.
    pub link: LinkScenario,
    /// cloud-tier replica pool: lane count, dispatch policy, fault
    /// schedule, deadline/retry/breaker parameters.  The `Default` — one
    /// healthy replica — reproduces the single-worker cloud stage exactly.
    pub replicas: ReplicaConfig,
    /// split-boundary payload codec menu (`--codecs`): the codec axis of
    /// the bandit's `(split, codec)` action space.  Bandit and contextual
    /// policies learn over every `(split, codec)` pair; fixed policies
    /// always use entry 0.  The `Default` — `[identity]` — reproduces the
    /// codec-less byte stream and decision sequence bit for bit.
    pub codecs: CodecMenu,
}

/// Policy state held by the service.
#[derive(Clone)]
enum PolicyState {
    SplitEe(SplitEePolicy),
    SplitEeS(SplitEeSPolicy),
    Contextual(ContextualSplitPolicy),
    Fixed(usize),
    FinalExit,
}

impl PolicyState {
    /// Next `(split layer, codec index)` from the current bandit state —
    /// split 1-based, codec an index into the service's codec menu.
    /// `context` is the link context observed at decision time — only the
    /// contextual policy reads it.
    ///
    /// SplitEE and the contextual policy are constructed with
    /// `n_layers * n_codecs` arms (arm `c * n_layers + (split - 1)` is the
    /// pair `(split, codec c)`), so one UCB instance learns over the whole
    /// `(split, codec)` menu; with the default single-codec menu the arm
    /// space — and every decision — is exactly the codec-less one.
    /// SplitEE-S keeps per-layer arms (its side observations credit one
    /// arm per prefix layer) and the fixed policies carry no bandit, so
    /// they always use codec 0.
    fn choose_split_codec(
        &mut self,
        n_layers: usize,
        n_codecs: usize,
        context: usize,
    ) -> (usize, usize) {
        let l = n_layers;
        let (split, codec) = match self {
            PolicyState::SplitEe(p) => {
                let a0 = p.choose_split() - 1;
                (a0 % l + 1, a0 / l)
            }
            PolicyState::Contextual(p) => {
                let a0 = p.choose_split(context) - 1;
                (a0 % l + 1, a0 / l)
            }
            PolicyState::SplitEeS(p) => (p.choose_split(), 0),
            PolicyState::Fixed(k) => (*k, 0),
            PolicyState::FinalExit => (l, 0),
        };
        debug_assert!(codec < n_codecs.max(1));
        (split, codec)
    }

    /// Split choice that needs no bandit state (fixed policies), if any.
    /// When `Some`, the edge stage never has to wait on the reply stage.
    fn static_split(&self, n_layers: usize) -> Option<usize> {
        match self {
            PolicyState::Fixed(k) => Some(*k),
            PolicyState::FinalExit => Some(n_layers),
            _ => None,
        }
    }

    fn kind_name(&self) -> &'static str {
        match self {
            PolicyState::SplitEe(_) => "splitee",
            PolicyState::SplitEeS(_) => "splitee-s",
            PolicyState::Contextual(_) => "contextual",
            PolicyState::Fixed(_) => "fixed",
            PolicyState::FinalExit => "final-exit",
        }
    }

    /// Learned state for snapshot persistence, tagged with the policy kind.
    /// The fixed policies carry no learned state — only the tag, so a
    /// restore still verifies the snapshot matches the configured policy.
    fn export_state(&self) -> Json {
        let mut fields = vec![("kind", Json::Str(self.kind_name().into()))];
        match self {
            PolicyState::SplitEe(p) => fields.push(("state", p.export_state())),
            PolicyState::SplitEeS(p) => fields.push(("state", p.export_state())),
            PolicyState::Contextual(p) => fields.push(("state", p.export_state())),
            PolicyState::Fixed(_) | PolicyState::FinalExit => {}
        }
        Json::obj(fields)
    }

    /// Restore state exported by [`PolicyState::export_state`].
    fn import_state(&mut self, v: &Json) -> Result<()> {
        let kind = v.get("kind")?.as_str()?;
        if kind != self.kind_name() {
            anyhow::bail!(
                "snapshot holds a {kind:?} policy, this service runs {:?}",
                self.kind_name()
            );
        }
        match self {
            PolicyState::SplitEe(p) => p.import_state(v.get("state")?),
            PolicyState::SplitEeS(p) => p.import_state(v.get("state")?),
            PolicyState::Contextual(p) => p.import_state(v.get("state")?),
            PolicyState::Fixed(_) | PolicyState::FinalExit => Ok(()),
        }
    }
}

/// What the edge stage hands to the cloud stage for one batch.
/// `pub(crate)` because the replica pool ([`crate::coordinator::replicas`])
/// is the cloud stage's serving backend and consumes it directly.
pub(crate) struct EdgeWork {
    pub(crate) batch: Batch,
    /// hidden state at the split layer (consumed by the cloud continuation;
    /// this is the one host transfer the split boundary requires) — `None`
    /// when no row offloads, so fully-exiting batches skip the transfer.
    /// Arc-shared with an in-flight speculative launch, so speculation
    /// never copies the activation buffer
    pub(crate) h: Option<Arc<TensorF32>>,
    pub(crate) exit_out: ExitOutput,
    /// per earlier layer, per row: exit-head confidences (SplitEE-S only)
    pub(crate) prefix_conf: Vec<Vec<f32>>,
    /// rows (by batch index) whose confidence fell below alpha
    pub(crate) offload_rows: Vec<usize>,
    pub(crate) split: usize,
    /// codec-menu index this batch's uplink payload is encoded with (the
    /// other half of the bandit's `(split, codec)` decision; coalesced
    /// groups never mix codecs)
    pub(crate) codec: usize,
    pub(crate) edge_ms: f64,
    /// executable launches this batch's edge stage performed
    pub(crate) launches: u64,
    /// in-flight speculative continuation (blocks past the split + final
    /// head over the full batch), issued concurrently with the exit-head
    /// verdict.  `None` when speculation is off or the batch fully exited
    /// (kill-on-exit happens in the edge stage).
    pub(crate) spec: Option<SpecHandle>,
}

/// One offloaded row's final-layer result from the cloud continuation.
pub(crate) struct CloudRow {
    pub(crate) row: usize,
    pub(crate) pred: usize,
    pub(crate) conf: f32,
    pub(crate) cloud_ms: f64,
    /// the pool degraded this row to on-device final exit (no replica could
    /// serve it): `cloud_ms` is already on the edge-time basis, includes
    /// the retry penalty, and the reply stage must not draw a link transfer
    pub(crate) fallback: bool,
    /// this row's encoded uplink payload bytes — the codec output before
    /// dedup, excluding the fixed frame header (0 for fallback rows, which
    /// never transfer)
    pub(crate) enc_bytes: usize,
    /// bytes actually shipped after the dedup layer (what the transfer is
    /// charged for, still excluding the frame header); equals `enc_bytes`
    /// for non-dedup codecs
    pub(crate) wire_bytes: usize,
}

/// Edge work plus cloud results, ready for the reply stage (the hidden
/// state has been dropped — replies only need the head outputs).
pub(crate) struct ReplyWork {
    pub(crate) batch: Batch,
    pub(crate) exit_out: ExitOutput,
    pub(crate) prefix_conf: Vec<Vec<f32>>,
    pub(crate) split: usize,
    /// codec-menu index the batch's offloads were encoded with
    pub(crate) codec: usize,
    /// the codec's deterministic raw/encoded payload ratio for this
    /// model's rows — scales the offload cost in the rewards (1.0 when
    /// nothing offloaded or the codec is identity)
    pub(crate) codec_ratio: f64,
    /// raw (pre-codec) uplink payload bytes per offloaded row, excluding
    /// the frame header (0 when nothing offloaded)
    pub(crate) row_raw_bytes: usize,
    pub(crate) edge_ms: f64,
    pub(crate) cloud_out: Vec<CloudRow>,
    /// this batch's share of the simulated cloud compute (pro-rata within
    /// each coalesced launch, so shares sum to the launch totals)
    pub(crate) cloud_busy_ms: f64,
    pub(crate) edge_launches: u64,
    /// cloud-stage launches, attributed to the group head (0 elsewhere)
    pub(crate) cloud_launches: u64,
    /// on the group head: how many batches contributed offloaded rows to
    /// the group's launch (0 = the group launched nothing)
    pub(crate) group: Option<usize>,
}

/// Edge share: embed + one fused block-range launch to the split + the
/// split's exit head, plus the per-row exit-or-offload decision.
#[allow(clippy::too_many_arguments)]
fn edge_stage(
    model: &MultiExitModel,
    edge: &EdgeSim,
    alpha: f64,
    side: bool,
    n_layers: usize,
    split: usize,
    codec: usize,
    batch: Batch,
    spec: Option<(&SpecLane, &Arc<SpecCounters>)>,
) -> Result<EdgeWork> {
    let launches0 = thread_launches();
    let t0 = Instant::now();
    let h0 = model.embed_hidden(&batch.tokens)?;
    let embed_ms = t0.elapsed().as_secs_f64() * 1e3;
    edge_stage_after_embed(
        model, edge, alpha, side, n_layers, split, codec, batch, h0, embed_ms, launches0, spec,
    )
}

/// The split-dependent part of the edge stage.  Separated so the pipelined
/// path can run the split-independent `embed` before the previous batch's
/// bandit updates have released this batch's split.
#[allow(clippy::too_many_arguments)]
fn edge_stage_after_embed(
    model: &MultiExitModel,
    edge: &EdgeSim,
    alpha: f64,
    side: bool,
    n_layers: usize,
    split: usize,
    codec: usize,
    batch: Batch,
    h0: HiddenState,
    embed_ms: f64,
    launches0: u64,
    spec: Option<(&SpecLane, &Arc<SpecCounters>)>,
) -> Result<EdgeWork> {
    // compile-if-needed outside the timed region, so a first-use chain
    // compile never shows up as simulated edge latency (the side path runs
    // per-block launches and never touches the fused chain — don't compile
    // modules it will never use)
    if !side {
        model.warm_range(h0.batch(), 0, split)?;
    }
    let t0 = Instant::now();
    let mut prefix_conf: Vec<Vec<f32>> = Vec::new(); // per layer, per row
    let h_split = if side {
        // SplitEE-S observes every prefix exit head, so the range decomposes
        // into per-block launches — the activation still stays in device
        // format between them.
        let mut h = h0;
        for layer in 0..split {
            h = model.blocks_between(&h, layer, layer + 1)?;
            if layer + 1 < split {
                prefix_conf.push(model.exit_head_hidden(&h, layer)?.conf);
            }
        }
        h
    } else {
        // one fused launch covers the whole edge partition
        model.blocks_between(&h0, 0, split)?
    };
    let mut compute_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Speculative continuation: issue blocks[split..L) + final head on the
    // speculation lane *now*, so it runs concurrently with the exit-head
    // verdict below.  Deliberately outside the timed region — speculative
    // work must never be attributed to simulated edge latency (kill-on-exit
    // discards it entirely; a used result is attributed as cloud compute by
    // the cloud stage, exactly like the launch it replaces).
    let mut spec_handle: Option<SpecHandle> = None;
    let mut spec_h: Option<Arc<TensorF32>> = None;
    let mut spec_transfer_ms = 0.0;
    if split < n_layers {
        if let Some((lane, counters)) = spec {
            // the transfer is timed separately: it is charged to edge_ms
            // below only if some row offloads — exactly where (and only
            // where) the non-speculative path pays the same copy, so on/off
            // latency accounting stays comparable
            let tt = Instant::now();
            let hh = Arc::new(h_split.to_tensor()?);
            spec_transfer_ms = tt.elapsed().as_secs_f64() * 1e3;
            spec_handle =
                Some(model.speculate_rest_exit(lane, Arc::clone(&hh), split - 1, counters)?);
            spec_h = Some(hh);
        }
    }

    let t1 = Instant::now();
    let exit_out = model.exit_head_hidden(&h_split, split - 1)?;

    // per-sample exit-or-offload, decided before any host transfer
    let n_real = batch.real_len();
    let mut offload_rows: Vec<usize> = Vec::new();
    for row in 0..n_real {
        if (exit_out.conf[row] as f64) < alpha && split < n_layers {
            offload_rows.push(row);
        }
    }
    // the split-boundary host transfer: this buffer is what the uplink
    // ships (after the codec encodes it, in the cloud stage), so it
    // happens only when some row actually crosses the split (when
    // speculating, the buffer already exists — it was the speculative
    // launch's input)
    let h = if offload_rows.is_empty() {
        None
    } else {
        Some(match spec_h {
            Some(hh) => hh,
            None => Arc::new(h_split.to_tensor()?),
        })
    };
    compute_ms += t1.elapsed().as_secs_f64() * 1e3;
    if !offload_rows.is_empty() {
        // charge the split-boundary transfer where the non-speculative path
        // pays it (zero when it ran inside the timed window above); a
        // killed speculation's transfer stays unattributed, like the rest
        // of its work
        compute_ms += spec_transfer_ms;
    }
    let edge_ms = edge.simulated_ms(embed_ms + compute_ms);
    // kill-on-exit: a fully-exiting batch discards its speculative launch
    // and its cost is attributed nowhere
    let spec_handle = if offload_rows.is_empty() {
        if let Some(handle) = spec_handle {
            handle.kill();
        }
        None
    } else {
        spec_handle
    };
    let launches = thread_launches() - launches0;
    Ok(EdgeWork {
        batch,
        h,
        exit_out,
        prefix_conf,
        offload_rows,
        split,
        codec,
        edge_ms,
        launches,
        spec: spec_handle,
    })
}

/// Reply share: uplink simulation for offloaded rows, reward computation,
/// bandit updates, metrics and reply delivery.  Everything stateful lives
/// here, in batch order — this is what keeps pipelined decisions identical
/// to the serial path.
///
/// `state` is the instantaneous link condition this batch was decided and
/// served under (stepped by the caller, once per batch): it modulates the
/// uplink profile, replaces the offloading cost for this batch's rewards,
/// forces the on-device fallback during an outage, and keys the contextual
/// policy's updates.
#[allow(clippy::too_many_arguments)]
fn reply_stage(
    work: ReplyWork,
    n_layers: usize,
    side: bool,
    cost: &CostModel,
    edge: &EdgeSim,
    cloud: &CloudSim,
    link: &mut LinkSim,
    policy: &mut PolicyState,
    metrics: &mut ServingMetrics,
    state: &LinkState,
) {
    let l = n_layers;
    // this batch's rewards/costs are charged at the instantaneous
    // communication cost (identity under the static scenario), scaled by
    // the codec's deterministic raw/encoded payload ratio — the offload
    // charge is per transmitted byte, and the codec shrinks the bytes.
    // The nominal (not measured) ratio keeps the reward a pure function of
    // the decision sequence; the identity codec's ratio is exactly 1.0 and
    // skips the scaling entirely, so the default menu reproduces the
    // codec-less rewards bit for bit.
    let mut eff = state.effective_cost(cost);
    if work.codec_ratio != 1.0 {
        eff = eff.with_offload(eff.offload / eff.lambda / work.codec_ratio);
    }
    let cost = &eff;
    if !state.outage {
        // the uplink simulator serves this batch at the sampled condition
        link.profile = state.profile;
    }
    let ReplyWork {
        batch,
        exit_out,
        prefix_conf,
        split,
        codec,
        codec_ratio: _,
        row_raw_bytes,
        edge_ms,
        cloud_out,
        cloud_busy_ms,
        edge_launches,
        cloud_launches,
        group,
    } = work;
    let n_real = batch.real_len();
    metrics.record_batch(n_real, batch.padded_to);
    metrics.record_stage_ms(edge_ms, cloud_busy_ms);
    metrics.record_launches(edge_launches, cloud_launches);
    if let Some(contributing) = group {
        metrics.record_coalesce(contributing);
    }

    // (pred, conf, extra_latency_ms, outage) for rows that were offloaded
    let mut final_by_row: Vec<Option<(usize, f32, f64, bool)>> = vec![None; n_real];
    // per-row delivered uplink payload bytes (raw, encoded) for the cohort
    // attribution below; stays (0, 0) for exits, outages and fallbacks
    let mut bytes_by_row: Vec<(u64, u64)> = vec![(0, 0); n_real];
    let (mut raw_up, mut enc_up, mut saved_up) = (0u64, 0u64, 0u64);
    for cr in cloud_out {
        // a pool-degraded row already carries its on-device latency (edge
        // compute basis, plus the simulated retry/backoff penalty): no
        // transfer is attempted — and no link rng drawn, which keeps the
        // fault replay deterministic — and the row accounts exactly like an
        // outage fallback below
        if cr.fallback {
            final_by_row[cr.row] = Some((cr.pred, cr.conf, cr.cloud_ms, true));
            continue;
        }
        // a scenario-level outage fails every transfer deterministically
        // (no rng drawn); otherwise the stochastic link decides.  The
        // transfer is charged for the bytes the codec actually ships —
        // post-dedup payload plus the fixed frame header.
        let result = if state.outage {
            TransferResult::Outage
        } else {
            link.transfer(cr.wire_bytes + FRAME_OVERHEAD)
        };
        match result {
            TransferResult::Delivered { ms, .. } => {
                final_by_row[cr.row] = Some((cr.pred, cr.conf, ms + cr.cloud_ms, false));
                bytes_by_row[cr.row] = (row_raw_bytes as u64, cr.enc_bytes as u64);
                raw_up += row_raw_bytes as u64;
                enc_up += cr.enc_bytes as u64;
                saved_up += cr.enc_bytes.saturating_sub(cr.wire_bytes) as u64;
            }
            TransferResult::Outage => {
                // fall back: the cloud result is unreachable; the edge must
                // finish locally (same numbers, edge timing, no offload
                // charge)
                let local_ms = edge.simulated_ms(cr.cloud_ms / cloud.compute_scale.max(1e-9));
                final_by_row[cr.row] = Some((cr.pred, cr.conf, local_ms, true));
            }
        }
    }
    let state_offloads = final_by_row.iter().flatten().filter(|r| !r.3).count() as u64;
    let state_outages = final_by_row.iter().flatten().filter(|r| r.3).count() as u64;
    metrics.record_link_state(&state.label, split, n_real, state_offloads, state_outages);
    metrics.record_uplink_bytes(raw_up, enc_up, saved_up);

    for (row, req) in batch.requests.iter().enumerate() {
        let queue_ms = batch
            .formed_at
            .duration_since(req.submitted_at)
            .as_secs_f64()
            * 1e3;
        let (infer_layer, pred, conf, offloaded, outage, extra_ms) = match &final_by_row[row] {
            Some((pred, conf, extra_ms, outage)) => {
                (l, *pred, *conf, !*outage, *outage, *extra_ms)
            }
            None => (split, exit_out.pred[row], exit_out.conf[row], false, false, 0.0),
        };
        // Simulated service latency: queue-until-formed + simulated edge
        // compute + simulated link/cloud extra.  Deliberately excludes
        // wall-clock pipeline-channel residency (bounded by PIPELINE_DEPTH
        // batches) — it models the deployed edge device, where no such
        // pipeline exists, and stays comparable with the serial path.
        let latency = queue_ms + edge_ms + extra_ms;
        let (cost_l, energy, reward) = if outage {
            let gamma = cost.compute_cost_cascade(l);
            (gamma, edge.energy(gamma, false), cost.reward_exit(l, conf as f64, side))
        } else if offloaded {
            (
                cost.total_cost(split, true, side),
                edge.energy(cost.gamma(split, side), true),
                cost.reward_offload(split, conf as f64, side),
            )
        } else {
            (
                cost.total_cost(split, false, side),
                edge.energy(cost.gamma(split, side), false),
                cost.reward_exit(split, exit_out.conf[row] as f64, side),
            )
        };

        match policy {
            // arm `codec * l + (split - 1)` is the `(split, codec)` pair —
            // the inverse of `PolicyState::choose_split_codec`'s decode
            // (the 1-based arithmetic works out: `codec * l + split` is the
            // 1-based index of that arm)
            PolicyState::SplitEe(p) => p.record(codec * l + split, reward),
            // keyed by the context observed at decision time — `state` is
            // exactly the condition under which this batch's split was
            // chosen, whatever the link has drifted to since
            PolicyState::Contextual(p) => p.record(state.context, codec * l + split, reward),
            PolicyState::SplitEeS(p) => {
                let mut prefix: Vec<f32> = prefix_conf.iter().map(|layer| layer[row]).collect();
                prefix.push(exit_out.conf[row]);
                let conf_final = offloaded.then_some(conf as f64);
                p.record_prefix(cost, &prefix, conf_final);
            }
            _ => {}
        }

        metrics.record_request(
            infer_layer,
            offloaded,
            outage,
            latency,
            queue_ms,
            cost_l,
            energy,
        );
        if let Some(tag) = &req.tag {
            let (row_raw, row_enc) = bytes_by_row[row];
            metrics.record_cohort(tag, offloaded, latency, row_raw, row_enc);
        }
        let _ = req.reply.send(Response {
            id: req.id,
            prediction: pred,
            confidence: conf,
            infer_layer,
            offloaded,
            latency_ms: latency,
        });
    }
}

/// Join a pipeline stage, converting a stage panic into an error naming the
/// stage — instead of letting the panic propagate (directly, or via
/// `thread::scope`'s implicit-join re-panic) and abort the whole serve
/// call.  The payload text is preserved when it is a string, the common
/// case for `panic!`/`assert!`/`expect`.
fn join_stage<T>(handle: std::thread::ScopedJoinHandle<'_, Result<T>>, stage: &str) -> Result<T> {
    match handle.join() {
        Ok(res) => res,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(anyhow::anyhow!("{stage} stage panicked: {msg}"))
        }
    }
}

/// The serving engine.
pub struct Service {
    model: Arc<MultiExitModel>,
    cost: CostModel,
    pub edge: EdgeSim,
    pub cloud: CloudSim,
    pub link: LinkSim,
    /// time-varying uplink scenario, stepped once per batch in the reply
    /// stage (see the module docs)
    scenario: LinkScenario,
    /// the configured profile the scenario modulates — kept separately
    /// because `link.profile` is overwritten per batch with the effective
    /// one, and compounding modulations would drift
    base_profile: NetworkProfile,
    policy: PolicyState,
    alpha: f64,
    coalesce: CoalesceConfig,
    /// the instantiated `(split, codec)` menu's codec axis, indexed by the
    /// codec id the policy chooses; `dedup:*` entries share one chunk
    /// store whose counters are wired into `metrics.dedup`
    codecs: Vec<Arc<dyn PayloadCodec>>,
    /// the speculation lane (worker thread) when speculation resolved on
    spec_lane: Option<SpecLane>,
    /// the cloud tier: a pool of replica lanes with fault injection,
    /// deadline/retry, circuit breakers and edge-only degradation (its
    /// counters are shared with `metrics.pool`).  Behind a mutex because the
    /// pipelined loop's cloud stage dispatches through it while the reply
    /// stage exports its state into periodic snapshots; the cloud stage is
    /// still the only dispatcher, so the fault clock stays deterministic.
    replicas: Arc<Mutex<ReplicaPool>>,
    /// durable-state snapshot destination + cadence (None = no snapshots)
    snapshot_cfg: Option<SnapshotConfig>,
    /// configuration fingerprint stamped into (and checked against) every
    /// snapshot
    fingerprint: String,
    /// batches fully accounted by the reply stage — the snapshot's
    /// consistency point and its `batches` stamp
    batches_done: u64,
    pub metrics: ServingMetrics,
}

/// Lock the replica pool, recovering from poisoning: the pool's own state
/// is import-validated and lane failures are handled inside `serve_group`,
/// so a panic elsewhere must not wedge serving or snapshotting.
fn lock_pool(pool: &Mutex<ReplicaPool>) -> MutexGuard<'_, ReplicaPool> {
    pool.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Configuration fingerprint for snapshot compatibility: everything that
/// shapes the learned state's meaning (policy + its hyper-parameters, layer
/// count, link scenario, pool geometry, backend).  Two services with equal
/// fingerprints interpret each other's snapshots; anything else must cold-
/// start.  f64 hyper-parameters are fingerprinted by bit pattern — "close"
/// is not "equal" for replay.
fn fingerprint_of(config: &ServiceConfig, model: &MultiExitModel) -> String {
    let policy = match config.policy {
        PolicyKind::SplitEe => "splitee".to_string(),
        PolicyKind::SplitEeS => "splitee-s".to_string(),
        PolicyKind::Contextual => "contextual".to_string(),
        PolicyKind::Fixed(k) => format!("fixed:{k}"),
        PolicyKind::FinalExit => "final-exit".to_string(),
    };
    format!(
        "v1 policy={policy} alpha={:016x} beta={:016x} layers={} link={}:{} \
         replicas={} dispatch={} faults={} backend={} codecs={}",
        config.alpha.to_bits(),
        config.beta.to_bits(),
        model.n_layers(),
        config.link.name(),
        config.link.n_contexts(),
        config.replicas.n.max(1),
        config.replicas.dispatch.name(),
        config.replicas.faults.name(),
        model.backend_name(),
        // the codec menu reshapes the bandit's arm space, so snapshots
        // only interchange between services with the identical menu
        config.codecs.names(),
    )
}

/// Assemble and write one snapshot (the reply stage and `serve_batch` call
/// this at their consistency point; `Service::write_snapshot` at shutdown).
/// A failed write is logged and survived — persistence is an availability
/// feature and must never take serving down with it.
#[allow(clippy::too_many_arguments)]
fn write_snapshot_parts(
    cfg: &SnapshotConfig,
    fingerprint: &str,
    batches: u64,
    policy: &PolicyState,
    link: &LinkSim,
    scenario: &LinkScenario,
    replicas: &Mutex<ReplicaPool>,
    model: &MultiExitModel,
    metrics: &mut ServingMetrics,
) {
    let mut snap = Snapshot::new(fingerprint, batches);
    snap.insert("policy", policy.export_state());
    snap.insert("link", link.export_state());
    snap.insert("scenario", scenario.export_state());
    snap.insert("pool", lock_pool(replicas).export_state());
    let keys = model.warm_keys();
    if !keys.is_empty() {
        snap.insert("warm_keys", Json::Arr(keys.into_iter().map(Json::Str).collect()));
    }
    match snap.save(&cfg.path) {
        Ok(()) => metrics.record_snapshot(),
        Err(e) => log::warn!(
            "snapshot write to {} failed ({e:#}) — serving continues",
            cfg.path.display()
        ),
    }
}

impl Service {
    pub fn new(
        model: Arc<MultiExitModel>,
        cost: CostModel,
        link: LinkSim,
        config: &ServiceConfig,
    ) -> Service {
        let l = model.n_layers();
        // The bandit policies learn over the full (split, codec) menu: one
        // UCB with l * n_codecs arms (see PolicyState::choose_split_codec
        // for the arm <-> pair mapping).  SplitEE-S keeps per-layer arms —
        // its side observations credit one arm per prefix layer — and uses
        // codec 0.  With the default single-codec menu every arm count is
        // exactly the codec-less one.
        let n_codecs = config.codecs.len().max(1);
        let policy = match config.policy {
            PolicyKind::SplitEe => {
                PolicyState::SplitEe(SplitEePolicy::new(l * n_codecs, config.alpha, config.beta))
            }
            PolicyKind::SplitEeS => {
                PolicyState::SplitEeS(SplitEeSPolicy::new(l, config.alpha, config.beta))
            }
            PolicyKind::Contextual => PolicyState::Contextual(ContextualSplitPolicy::new(
                l * n_codecs,
                config.link.n_contexts(),
                config.alpha,
                config.beta,
            )),
            PolicyKind::Fixed(k) => PolicyState::Fixed(k.clamp(1, l)),
            PolicyKind::FinalExit => PolicyState::FinalExit,
        };
        // Resolve the speculation mode against the backend: results are
        // consumed only when decision-transparent (see the module docs), so
        // speculating on an opaque backend would be pure wasted work.
        let speculate = match config.speculate {
            SpeculateMode::Off => false,
            SpeculateMode::On => {
                let ok = model.speculation_transparent();
                if !ok {
                    log::info!(
                        "--speculate on ignored: the {} backend's speculative results \
                         are not decision-transparent",
                        model.backend_name()
                    );
                }
                ok
            }
            SpeculateMode::Auto => {
                model.speculation_transparent()
                    && std::thread::available_parallelism()
                        .map(|n| n.get() >= 4)
                        .unwrap_or(false)
            }
        };
        // the pool's counters are shared with the metrics report, so
        // per-replica accounting survives the pool (and prints with the
        // rest of the serving summary)
        let pool_counters = PoolCounters::new(config.replicas.n.max(1));
        let mut metrics = ServingMetrics::new(l);
        metrics.pool = Arc::clone(&pool_counters);
        // instantiate the codec menu; the shared dedup chunk store's
        // counters ride into the metrics report the same way the pool's do
        let (codecs, dedup_cache) = config.codecs.build();
        metrics.dedup = Arc::clone(&dedup_cache.counters);
        let replicas = ReplicaPool::new(Arc::clone(&model), config.replicas.clone(), pool_counters);
        let fingerprint = fingerprint_of(config, &model);
        Service {
            metrics,
            replicas: Arc::new(Mutex::new(replicas)),
            fingerprint,
            model,
            cost,
            edge: EdgeSim::default(),
            cloud: CloudSim::default(),
            scenario: config.link.clone(),
            base_profile: link.profile,
            link,
            policy,
            alpha: config.alpha,
            coalesce: config.coalesce,
            codecs,
            spec_lane: speculate.then(SpecLane::new),
            snapshot_cfg: None,
            batches_done: 0,
        }
    }

    /// Enable durable-state snapshots: write to `cfg.path` every `cfg.every`
    /// batches (`0` = only when [`Service::write_snapshot`] is called, e.g.
    /// at shutdown).
    pub fn set_snapshot(&mut self, cfg: SnapshotConfig) {
        self.snapshot_cfg = Some(cfg);
    }

    /// The configuration fingerprint stamped into this service's snapshots.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Batches fully accounted so far (the snapshot consistency clock).
    pub fn batches_done(&self) -> u64 {
        self.batches_done
    }

    /// Warm-restart from a snapshot file.  Returns `true` when the learned
    /// state was restored; `false` — with a logged reason, never a panic or
    /// an error — on a missing, corrupt, wrong-version or fingerprint-
    /// mismatched snapshot, leaving the service cold-started and fully
    /// usable either way.
    pub fn restore(&mut self, path: &Path) -> bool {
        let snap = match Snapshot::load(path, &self.fingerprint) {
            Some(s) => s,
            None => return false,
        };
        match self.apply_snapshot(&snap) {
            Ok(()) => {
                log::info!(
                    "warm restart from {} ({} batches of learned state)",
                    path.display(),
                    snap.batches
                );
                true
            }
            Err(e) => {
                log::warn!(
                    "snapshot {} did not apply ({e:#}) — cold start",
                    path.display()
                );
                false
            }
        }
    }

    /// All-or-nothing snapshot application: every section is staged (or
    /// internally validated-before-mutate, for the pool) before any service
    /// state changes, so a failing section can never leave a half-restored
    /// service.
    fn apply_snapshot(&mut self, snap: &Snapshot) -> Result<()> {
        let section = |name: &str| -> Result<&Json> {
            snap.section(name)
                .ok_or_else(|| anyhow::anyhow!("snapshot has no {name:?} section"))
        };
        let mut policy = self.policy.clone();
        policy.import_state(section("policy")?).context("policy section")?;
        let mut link = self.link.clone();
        link.import_state(section("link")?).context("link section")?;
        let mut scenario = self.scenario.clone();
        scenario.import_state(section("scenario")?).context("scenario section")?;
        // the pool imports last: its import validates everything before
        // mutating, so a failure here still leaves the whole service cold
        lock_pool(&self.replicas)
            .import_state(section("pool")?)
            .context("pool section")?;
        self.policy = policy;
        self.link = link;
        self.scenario = scenario;
        self.batches_done = snap.batches;
        // cache warmup is best-effort: a stale working set must not block a
        // warm restart of the learned state
        if let Some(keys) = snap.section("warm_keys") {
            if let Ok(arr) = keys.as_arr() {
                let keys: Vec<String> =
                    arr.iter().filter_map(|k| k.as_str().ok().map(str::to_string)).collect();
                if let Err(e) = self.model.rewarm(&keys) {
                    log::warn!("cache re-warm skipped ({e:#})");
                }
            }
        }
        Ok(())
    }

    /// Write a snapshot now (the graceful-shutdown hook; periodic writes
    /// happen inside the serve loops).  No-op without a configured snapshot
    /// destination.  Returns whether a snapshot was written.
    pub fn write_snapshot(&mut self) -> bool {
        let cfg = match &self.snapshot_cfg {
            Some(c) => c,
            None => return false,
        };
        let before = self.metrics.snapshots_written;
        write_snapshot_parts(
            cfg,
            &self.fingerprint,
            self.batches_done,
            &self.policy,
            &self.link,
            &self.scenario,
            &self.replicas,
            &self.model,
            &mut self.metrics,
        );
        self.metrics.snapshots_written > before
    }

    fn side_info(&self) -> bool {
        matches!(self.policy, PolicyState::SplitEeS(_))
    }

    /// Run the blocking serve loop until the router is shut down + drained.
    /// Uses the staged pipeline; [`Service::run_serial`] is the
    /// single-threaded reference with identical per-request behaviour.
    pub fn run(&mut self, router: Arc<Router>, batcher_config: BatcherConfig) -> Result<()> {
        self.run_pipelined(router, batcher_config)
    }

    /// Single-threaded reference loop: form a batch, serve it, repeat.
    pub fn run_serial(&mut self, router: Arc<Router>, batcher_config: BatcherConfig) -> Result<()> {
        let mut batcher = Batcher::new(router, batcher_config);
        while let Some(batch) = batcher.next_batch() {
            self.serve_batch(batch)?;
        }
        Ok(())
    }

    /// Staged-pipeline serve loop (see the module docs for the stage graph
    /// and the argument for why its decisions match the serial path).
    pub fn run_pipelined(
        &mut self,
        router: Arc<Router>,
        batcher_config: BatcherConfig,
    ) -> Result<()> {
        let l = self.model.n_layers();
        let side = self.side_info();
        let alpha = self.alpha;
        let edge = self.edge;
        let cloud = self.cloud;
        let cost = self.cost;
        let coalesce = self.coalesce;
        let max_rows = self.model.max_batch().context("sizing the coalescing bound")?;
        let static_split = self.policy.static_split(l);
        // Only static-split policies can have two batches in the cloud stage
        // at once (a bandit releases batch k+1's split after batch k's
        // replies), so only they ever wait out the coalescing deadline.
        let coalesce_wait = coalesce.enabled && static_split.is_some();

        let base_profile = self.base_profile;

        let (batch_tx, batch_rx) = mpsc::sync_channel::<Batch>(PIPELINE_DEPTH);
        let (edge_tx, edge_rx) = mpsc::sync_channel::<EdgeWork>(PIPELINE_DEPTH);
        let (cloud_tx, cloud_rx) = mpsc::sync_channel::<ReplyWork>(PIPELINE_DEPTH);
        // (split, codec) tokens: reply stage -> edge stage.  At most one
        // token is in flight per batch; the seed token below covers the
        // first batch.
        let (split_tx, split_rx) = mpsc::channel::<(usize, usize)>();
        // the edge stage's handle on the speculation lane + the shared
        // lifecycle counters (cloned before `self` is destructured below)
        let spec_lane = self.spec_lane.clone();
        let spec_counters = Arc::clone(&self.metrics.spec);
        let n_codecs = self.codecs.len();
        let codecs_cloud: Vec<Arc<dyn PayloadCodec>> = self.codecs.clone();

        let Service {
            model,
            policy,
            metrics,
            link,
            scenario,
            replicas,
            snapshot_cfg,
            fingerprint,
            batches_done,
            ..
        } = self;
        let replicas_cloud = Arc::clone(replicas);
        // The link scenario advances once per batch, here in the reply
        // stage's ownership: the state sampled when a batch's split is
        // chosen is the state its replies are accounted (and its contextual
        // updates keyed) under — the same sequence the serial loop walks.
        let mut cur_state = scenario.next_state(&base_profile);
        if static_split.is_none() {
            let _ = split_tx.send(policy.choose_split_codec(l, n_codecs, cur_state.context));
        }
        let model_edge = Arc::clone(model);
        let model_cloud = Arc::clone(model);
        let router_batcher = Arc::clone(&router);

        std::thread::scope(|s| -> Result<()> {
            // ---- stage 1: batch formation (owns the max_wait deadline).
            // The handle is kept (and joined below) so a batcher panic —
            // e.g. ragged request widths reaching tensor concat — surfaces
            // as a named error instead of aborting via thread::scope's
            // implicit-join re-panic.
            let batcher_handle = s.spawn(move || -> Result<()> {
                let mut batcher = Batcher::new(router_batcher, batcher_config);
                while let Some(batch) = batcher.next_batch() {
                    if batch_tx.send(batch).is_err() {
                        break; // downstream stage is gone (error shutdown)
                    }
                }
                Ok(())
            });

            // ---- stage 2: edge compute
            let edge_handle = s.spawn(move || -> Result<()> {
                while let Ok(batch) = batch_rx.recv() {
                    // embed is split-independent: overlap it with the
                    // previous batch's cloud/reply work
                    let launches0 = thread_launches();
                    let t0 = Instant::now();
                    let h0 = model_edge.embed_hidden(&batch.tokens)?;
                    let embed_ms = t0.elapsed().as_secs_f64() * 1e3;
                    // fixed policies carry no bandit: they always serve
                    // with codec-menu entry 0
                    let (split, codec) = match static_split {
                        Some(k) => (k, 0),
                        None => match split_rx.recv() {
                            Ok(pair) => pair,
                            Err(_) => break, // reply stage is gone
                        },
                    };
                    let work = edge_stage_after_embed(
                        &model_edge,
                        &edge,
                        alpha,
                        side,
                        l,
                        split,
                        codec,
                        batch,
                        h0,
                        embed_ms,
                        launches0,
                        spec_lane.as_ref().map(|lane| (lane, &spec_counters)),
                    )?;
                    if edge_tx.send(work).is_err() {
                        break;
                    }
                }
                Ok(())
            });

            // ---- stage 3: cloud continuation, coalescing adjacent
            // same-split batches' offloads into one fused launch
            let cloud_handle = s.spawn(move || -> Result<()> {
                let mut pending: Option<EdgeWork> = None;
                loop {
                    let first = match pending.take() {
                        Some(w) => w,
                        None => match edge_rx.recv() {
                            Ok(w) => w,
                            Err(_) => break, // edge stage done
                        },
                    };
                    let mut rows = first.offload_rows.len();
                    let mut group = vec![first];
                    if coalesce_wait && rows > 0 {
                        let deadline = Instant::now() + coalesce.max_wait;
                        // the deadline bounds the whole group, including the
                        // try_recv fast path — a stream of zero-offload
                        // batches must not hold replies open past max_wait
                        while rows < max_rows && Instant::now() < deadline {
                            // harvest queued work immediately; otherwise wait
                            // out the remaining deadline
                            let next = match edge_rx.try_recv() {
                                Ok(w) => w,
                                Err(TryRecvError::Disconnected) => break,
                                Err(TryRecvError::Empty) => {
                                    let now = Instant::now();
                                    if now >= deadline {
                                        break;
                                    }
                                    match edge_rx.recv_timeout(deadline - now) {
                                        Ok(w) => w,
                                        Err(RecvTimeoutError::Timeout)
                                        | Err(RecvTimeoutError::Disconnected) => break,
                                    }
                                }
                            };
                            if next.split == group[0].split
                                && next.codec == group[0].codec
                                && rows + next.offload_rows.len() <= max_rows
                            {
                                rows += next.offload_rows.len();
                                group.push(next);
                            } else {
                                // different split or over the row bound:
                                // flush this group, start the next with it
                                pending = Some(next);
                                break;
                            }
                        }
                    }
                    // the pool lock is scoped to the dispatch: released
                    // before the channel send so the reply stage's snapshot
                    // export can never deadlock against a blocked send
                    let replies = lock_pool(&replicas_cloud)
                        .serve_group(&model_cloud, &edge, &cloud, group, &codecs_cloud)?;
                    let mut closed = false;
                    for reply in replies {
                        if cloud_tx.send(reply).is_err() {
                            closed = true;
                            break;
                        }
                    }
                    if closed {
                        break;
                    }
                }
                Ok(())
            });

            // ---- stage 4 (this thread): link sim, bandit updates, replies.
            // Updates are serialized here in batch order; the next split is
            // released only after they are applied.
            while let Ok(work) = cloud_rx.recv() {
                reply_stage(
                    work, l, side, &cost, &edge, &cloud, link, policy, metrics, &cur_state,
                );
                // Snapshot point: this batch is fully accounted and the
                // scenario/policy have not yet advanced for the next one —
                // exactly the state a warm restart must resume from.  (The
                // pool's dispatch clock may already be up to PIPELINE_DEPTH
                // batches ahead; see ARCHITECTURE.md on the weaker
                // determinism contract under faults.)
                *batches_done += 1;
                if let Some(cfg) = snapshot_cfg.as_ref() {
                    if cfg.every > 0 && *batches_done % cfg.every == 0 {
                        write_snapshot_parts(
                            cfg,
                            fingerprint,
                            *batches_done,
                            policy,
                            link,
                            scenario,
                            replicas,
                            model,
                            metrics,
                        );
                    }
                }
                // Advance the link and decide for the batch after this one.
                // A final state/token may go unconsumed when the stream
                // ends; `choose` without a subsequent update only advances
                // the UCB round counter, never the arm statistics.
                cur_state = scenario.next_state(&base_profile);
                if static_split.is_none() {
                    let _ =
                        split_tx.send(policy.choose_split_codec(l, n_codecs, cur_state.context));
                }
            }

            // The reply loop ending means the cloud stage has exited (its
            // sender dropped on return *or* unwind), so this join is
            // immediate.  Each join converts a stage panic into an error
            // naming the stage; on any failure the router is shut down so
            // sibling stages blocked on it unwedge and join too.
            let cloud_res = join_stage(cloud_handle, "cloud");
            // Unblock an edge stage waiting for a split token...
            drop(split_tx);
            if cloud_res.is_err() {
                // ...and, on an error shutdown, a batcher blocked on the
                // router, so every stage can be joined.
                router.shutdown();
            }
            let edge_res = join_stage(edge_handle, "edge");
            if edge_res.is_err() {
                router.shutdown();
            }
            let batcher_res = join_stage(batcher_handle, "batcher");
            if batcher_res.is_err() {
                router.shutdown();
            }
            edge_res.and(cloud_res).and(batcher_res)
        })
    }

    /// Serve one formed batch on the caller's thread (the serial reference
    /// path; also used directly by failure-injection tests).  The cloud
    /// share runs as a group of one — identical math to a coalesced group.
    pub fn serve_batch(&mut self, batch: Batch) -> Result<()> {
        let l = self.model.n_layers();
        // one scenario step per batch, observed before the split decision —
        // the exact sequence the pipelined reply stage walks
        let base_profile = self.base_profile;
        let state = self.scenario.next_state(&base_profile);
        let (split, codec) =
            self.policy.choose_split_codec(l, self.codecs.len(), state.context);
        let side = self.side_info();
        // The serial path never speculates: it is the pristine reference
        // whose decisions the speculative pipeline must reproduce exactly
        // (tests/speculation.rs), and with one thread there is nothing to
        // overlap the continuation with.
        let work =
            edge_stage(&self.model, &self.edge, self.alpha, side, l, split, codec, batch, None)?;
        let mut replies = lock_pool(&self.replicas).serve_group(
            &self.model,
            &self.edge,
            &self.cloud,
            vec![work],
            &self.codecs,
        )?;
        let work = replies.pop().expect("one reply per batch");
        reply_stage(
            work,
            l,
            side,
            &self.cost,
            &self.edge,
            &self.cloud,
            &mut self.link,
            &mut self.policy,
            &mut self.metrics,
            &state,
        );
        // same snapshot point as the pipelined reply loop — and on the
        // serial path the pool's dispatch clock is exactly in step, so the
        // snapshot is fully consistent
        self.batches_done += 1;
        if let Some(cfg) = &self.snapshot_cfg {
            if cfg.every > 0 && self.batches_done % cfg.every == 0 {
                write_snapshot_parts(
                    cfg,
                    &self.fingerprint,
                    self.batches_done,
                    &self.policy,
                    &self.link,
                    &self.scenario,
                    &self.replicas,
                    &self.model,
                    &mut self.metrics,
                );
            }
        }
        Ok(())
    }

    /// Current bandit state summary, if the policy is a bandit.  For the
    /// contextual policy this is the context-aggregated view (total pulls
    /// per arm, pull-weighted mean reward); use
    /// [`Service::contextual_summary`] for the per-context statistics.
    pub fn bandit_summary(&self) -> Option<(usize, Vec<(u64, f64)>)> {
        let ucb = match &self.policy {
            PolicyState::SplitEe(p) => p.ucb(),
            PolicyState::SplitEeS(p) => p.ucb(),
            PolicyState::Contextual(p) => return Some(p.aggregate_summary()),
            _ => return None,
        };
        let arms = (0..ucb.k()).map(|i| (ucb.arm(i).n, ucb.arm(i).q)).collect();
        Some((ucb.best_empirical() + 1, arms))
    }

    /// Per-context arm statistics `(pulls, mean reward)` when the policy is
    /// context-aware; outer index is the link context id.
    pub fn contextual_summary(&self) -> Option<Vec<Vec<(u64, f64)>>> {
        match &self.policy {
            PolicyState::Contextual(p) => Some(p.per_context_arms()),
            _ => None,
        }
    }
}

//! Multi-armed-bandit primitives: UCB index, arm statistics, regret tracking.
//!
//! SplitEE (Algorithm 1) is classical UCB1 over the `L` candidate split
//! layers with reward eq. 1; SplitEE-S additionally updates every arm
//! `j <= i_t` from side observations.  These primitives are policy-agnostic —
//! the policies in [`crate::policy`] compose them with the cost model: one
//! [`Ucb`] per deployment for the paper's stationary setting, one per link
//! context for the time-varying setting
//! ([`crate::policy::ContextualSplitPolicy`]).

/// Running statistics of one arm.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArmStats {
    /// pull (update) count N(i)
    pub n: u64,
    /// empirical mean reward Q(i)
    pub q: f64,
}

impl ArmStats {
    /// Incremental mean update.
    #[inline]
    pub fn update(&mut self, reward: f64) {
        self.n += 1;
        self.q += (reward - self.q) / self.n as f64;
    }
}

/// UCB1 state over `k` arms (paper line 6: `argmax Q(i) + beta sqrt(ln t / N(i))`).
#[derive(Debug, Clone)]
pub struct Ucb {
    arms: Vec<ArmStats>,
    /// exploration coefficient beta (paper: 1.0)
    pub beta: f64,
    /// round counter t (number of choose() calls)
    pub t: u64,
}

impl Ucb {
    pub fn new(k: usize, beta: f64) -> Ucb {
        assert!(k > 0, "need at least one arm");
        Ucb { arms: vec![ArmStats::default(); k], beta, t: 0 }
    }

    pub fn k(&self) -> usize {
        self.arms.len()
    }

    pub fn arm(&self, i: usize) -> &ArmStats {
        &self.arms[i]
    }

    /// UCB index of arm `i` at the current round; infinite for unpulled arms
    /// (realises "play each arm once" initialisation without a special phase).
    pub fn index(&self, i: usize) -> f64 {
        let a = &self.arms[i];
        if a.n == 0 {
            return f64::INFINITY;
        }
        let t = self.t.max(1) as f64;
        a.q + self.beta * (t.ln() / a.n as f64).sqrt()
    }

    /// Choose the arm with the highest UCB index.  Ties (including the
    /// initial all-infinite round) break to the lowest index, which matches
    /// the algorithm's "play each arm once" warm start in layer order.
    pub fn choose(&mut self) -> usize {
        self.t += 1;
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..self.arms.len() {
            let v = self.index(i);
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Record a reward for `arm`.
    pub fn update(&mut self, arm: usize, reward: f64) {
        self.arms[arm].update(reward);
    }

    /// The arm with the highest empirical mean (for reporting convergence).
    pub fn best_empirical(&self) -> usize {
        let mut best = 0;
        for i in 1..self.arms.len() {
            if self.arms[i].q > self.arms[best].q {
                best = i;
            }
        }
        best
    }

    pub fn reset(&mut self) {
        for a in &mut self.arms {
            *a = ArmStats::default();
        }
        self.t = 0;
    }

    /// Export the learned state (round counter + per-arm pull counts and
    /// bit-exact mean rewards) for snapshot persistence.  `beta`/`k` are
    /// configuration, not learned state — they live in the snapshot's config
    /// fingerprint instead.
    pub fn export_state(&self) -> crate::util::json::Json {
        use crate::persist::{arr_f64_hex, u64_hex};
        use crate::util::json::Json;
        Json::obj(vec![
            ("t", u64_hex(self.t)),
            ("n", Json::Arr(self.arms.iter().map(|a| u64_hex(a.n)).collect())),
            ("q", arr_f64_hex(&self.arms.iter().map(|a| a.q).collect::<Vec<_>>())),
        ])
    }

    /// Restore state exported by [`Ucb::export_state`].  The arm count must
    /// match this instance's `k` — a snapshot from a different action menu
    /// is a configuration mismatch, not a resumable state.
    pub fn import_state(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        use crate::persist::{u64_from_hex, vec_f64_from_hex};
        let n_arr = v.get("n")?.as_arr()?;
        let q = vec_f64_from_hex(v.get("q")?)?;
        if n_arr.len() != self.arms.len() || q.len() != self.arms.len() {
            anyhow::bail!(
                "ucb state has {} arms, this policy has {}",
                n_arr.len(),
                self.arms.len()
            );
        }
        let t = u64_from_hex(v.get("t")?)?;
        let n = n_arr.iter().map(u64_from_hex).collect::<anyhow::Result<Vec<_>>>()?;
        self.t = t;
        for (arm, (n, q)) in self.arms.iter_mut().zip(n.into_iter().zip(q)) {
            arm.n = n;
            arm.q = q;
        }
        Ok(())
    }
}

/// Cumulative-regret accumulator for one run (paper eq. 3 / figure 7).
#[derive(Debug, Clone, Default)]
pub struct RegretTracker {
    cumulative: f64,
    /// cumulative regret after each round (the figure-7 curve)
    pub curve: Vec<f64>,
}

impl RegretTracker {
    pub fn new() -> RegretTracker {
        RegretTracker::default()
    }

    /// Record one round: the oracle's reward minus the played reward.
    pub fn record(&mut self, reward_opt: f64, reward_played: f64) {
        self.cumulative += reward_opt - reward_played;
        self.curve.push(self.cumulative);
    }

    pub fn total(&self) -> f64 {
        self.cumulative
    }

    pub fn rounds(&self) -> usize {
        self.curve.len()
    }

    /// Downsample the curve to at most `points` entries (for reports).
    pub fn downsample(&self, points: usize) -> Vec<(usize, f64)> {
        if self.curve.is_empty() || points == 0 {
            return Vec::new();
        }
        let step = (self.curve.len() as f64 / points as f64).max(1.0);
        let mut out = Vec::new();
        let mut x = 0.0;
        while (x as usize) < self.curve.len() {
            let i = x as usize;
            out.push((i + 1, self.curve[i]));
            x += step;
        }
        if out.last().map(|&(i, _)| i) != Some(self.curve.len()) {
            out.push((self.curve.len(), self.cumulative));
        }
        out
    }
}

/// A deterministic environment for bandit unit tests: Bernoulli-ish arms with
/// fixed means and bounded noise.
#[cfg(test)]
pub(crate) fn simulate_ucb(means: &[f64], rounds: usize, beta: f64, seed: u64) -> (Ucb, f64) {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut ucb = Ucb::new(means.len(), beta);
    let best = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut regret = 0.0;
    for _ in 0..rounds {
        let arm = ucb.choose();
        let reward = means[arm] + (rng.next_f64() - 0.5) * 0.1;
        ucb.update(arm, reward);
        regret += best - means[arm];
    }
    (ucb, regret)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_stats_running_mean() {
        let mut a = ArmStats::default();
        a.update(1.0);
        a.update(0.0);
        a.update(0.5);
        assert_eq!(a.n, 3);
        assert!((a.q - 0.5).abs() < 1e-12);
    }

    #[test]
    fn plays_every_arm_once_first() {
        let mut ucb = Ucb::new(5, 1.0);
        let mut seen = Vec::new();
        for _ in 0..5 {
            let arm = ucb.choose();
            seen.push(arm);
            ucb.update(arm, 0.1);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn converges_to_best_arm() {
        let means = [0.2, 0.5, 0.8, 0.4];
        let (ucb, _) = simulate_ucb(&means, 5000, 1.0, 42);
        assert_eq!(ucb.best_empirical(), 2);
        // the best arm must dominate pulls
        assert!(ucb.arm(2).n > 3000, "best arm pulled {} times", ucb.arm(2).n);
    }

    #[test]
    fn regret_is_sublinear() {
        let means = [0.2, 0.5, 0.8, 0.4];
        let (_, r1k) = simulate_ucb(&means, 1000, 1.0, 7);
        let (_, r10k) = simulate_ucb(&means, 10_000, 1.0, 7);
        // 10x the rounds must cost far less than 10x the regret
        assert!(r10k < r1k * 4.0, "r1k={r1k:.1} r10k={r10k:.1}");
    }

    #[test]
    fn pulls_every_arm_infinitely_often() {
        let means = [0.2, 0.9];
        let (ucb, _) = simulate_ucb(&means, 20_000, 1.0, 3);
        assert!(ucb.arm(0).n > 10, "suboptimal arm still explored");
    }

    #[test]
    fn higher_beta_explores_more() {
        let means = [0.2, 0.8];
        let (low, _) = simulate_ucb(&means, 5000, 0.3, 11);
        let (high, _) = simulate_ucb(&means, 5000, 3.0, 11);
        assert!(high.arm(0).n > low.arm(0).n);
    }

    #[test]
    fn regret_tracker_accumulates() {
        let mut rt = RegretTracker::new();
        rt.record(1.0, 0.5);
        rt.record(1.0, 1.0);
        rt.record(1.0, 0.0);
        assert!((rt.total() - 1.5).abs() < 1e-12);
        assert_eq!(rt.curve, vec![0.5, 0.5, 1.5]);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut rt = RegretTracker::new();
        for _ in 0..1000 {
            rt.record(1.0, 0.9);
        }
        let ds = rt.downsample(10);
        assert!(ds.len() >= 10 && ds.len() <= 12);
        assert_eq!(ds.first().unwrap().0, 1);
        assert_eq!(ds.last().unwrap().0, 1000);
        assert!((ds.last().unwrap().1 - rt.total()).abs() < 1e-9);
    }

    #[test]
    fn export_import_round_trip_is_bit_exact() {
        let (ucb, _) = simulate_ucb(&[0.2, 0.5, 0.8], 500, 1.0, 42);
        let state = ucb.export_state();
        let mut restored = Ucb::new(3, 1.0);
        restored.import_state(&state).unwrap();
        assert_eq!(restored.t, ucb.t);
        for i in 0..3 {
            assert_eq!(restored.arm(i).n, ucb.arm(i).n);
            assert_eq!(restored.arm(i).q.to_bits(), ucb.arm(i).q.to_bits());
        }
    }

    #[test]
    fn import_rejects_arm_count_mismatch_and_tolerates_unknown_fields() {
        let (ucb, _) = simulate_ucb(&[0.2, 0.8], 100, 1.0, 7);
        let state = ucb.export_state();
        let mut wrong_k = Ucb::new(5, 1.0);
        assert!(wrong_k.import_state(&state).is_err());
        // a future writer may add fields — the reader must ignore them
        let mut extended = state.clone();
        if let crate::util::json::Json::Obj(o) = &mut extended {
            o.insert("future".into(), crate::util::json::Json::Num(1.0));
        }
        let mut restored = Ucb::new(2, 1.0);
        restored.import_state(&extended).unwrap();
        assert_eq!(restored.t, ucb.t);
    }

    #[test]
    fn reset_clears_state() {
        let mut ucb = Ucb::new(3, 1.0);
        for _ in 0..10 {
            let a = ucb.choose();
            ucb.update(a, 1.0);
        }
        ucb.reset();
        assert_eq!(ucb.t, 0);
        assert_eq!(ucb.arm(0).n, 0);
        assert_eq!(ucb.index(0), f64::INFINITY);
    }
}

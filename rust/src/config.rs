//! Typed configuration: artifact manifest + runtime settings.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for model geometry, dataset inventory, calibrated
//! thresholds and artifact paths.  Runtime settings (cost model knobs,
//! experiment parameters) layer CLI overrides on top of defaults.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::args::Args;
use crate::util::json::{self, Json};

/// Model geometry (mirrors python `ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelGeometry {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

/// One fine-tuning task (source dataset) with its trained weight files and
/// calibrated thresholds.
#[derive(Debug, Clone)]
pub struct TaskInfo {
    pub name: String,
    pub classes: usize,
    /// confidence threshold alpha (SplitEE / ElasticBERT policies)
    pub alpha: f64,
    /// entropy threshold tau (DeeBERT policy)
    pub tau: f64,
    /// style -> weights file (relative to artifact dir)
    pub weights: BTreeMap<String, String>,
    pub val_acc_per_exit: Vec<f64>,
}

/// One dataset (source or eval).
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub name: String,
    pub file: String,
    pub classes: usize,
    pub samples: usize,
    pub role: String,
    pub family: String,
    pub paper_name: String,
    pub paper_samples: usize,
    /// eval datasets: the source task whose weights/thresholds apply
    pub source: Option<String>,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub model: ModelGeometry,
    pub batch_sizes: Vec<usize>,
    pub cache_batch: usize,
    pub tasks: BTreeMap<String, TaskInfo>,
    pub datasets: BTreeMap<String, DatasetInfo>,
    /// graph name -> batch size -> HLO path (relative to root)
    pub hlo: BTreeMap<String, BTreeMap<usize, String>>,
    pub quick: bool,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(root.to_path_buf(), &v)
    }

    fn from_json(root: PathBuf, v: &Json) -> Result<Manifest> {
        let m = v.get("model")?;
        let model = ModelGeometry {
            vocab: m.get("vocab")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
        };
        let batch_sizes = v
            .get("batch_sizes")?
            .as_arr()?
            .iter()
            .map(|b| b.as_usize())
            .collect::<Result<Vec<_>, _>>()?;
        let cache_batch = v.get("cache_batch")?.as_usize()?;

        let mut tasks = BTreeMap::new();
        for (name, t) in v.get("tasks")?.as_obj()? {
            let mut weights = BTreeMap::new();
            for (style, path) in t.get("weights")?.as_obj()? {
                weights.insert(style.clone(), path.as_str()?.to_string());
            }
            tasks.insert(
                name.clone(),
                TaskInfo {
                    name: name.clone(),
                    classes: t.get("classes")?.as_usize()?,
                    alpha: t.get("alpha")?.as_f64()?,
                    tau: t.get("tau")?.as_f64()?,
                    weights,
                    val_acc_per_exit: t
                        .get("val_acc_per_exit")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_f64())
                        .collect::<Result<Vec<_>, _>>()?,
                },
            );
        }

        let mut datasets = BTreeMap::new();
        for (name, d) in v.get("datasets")?.as_obj()? {
            datasets.insert(
                name.clone(),
                DatasetInfo {
                    name: name.clone(),
                    file: d.get("file")?.as_str()?.to_string(),
                    classes: d.get("classes")?.as_usize()?,
                    samples: d.get("samples")?.as_usize()?,
                    role: d.get("role")?.as_str()?.to_string(),
                    family: d.get("family")?.as_str()?.to_string(),
                    paper_name: d.get("paper_name")?.as_str()?.to_string(),
                    paper_samples: d.get("paper_samples")?.as_usize()?,
                    source: d.opt("source").map(|s| s.as_str().unwrap_or("").to_string()),
                },
            );
        }

        let mut hlo = BTreeMap::new();
        for (graph, by_batch) in v.get("hlo")?.as_obj()? {
            let mut inner = BTreeMap::new();
            for (b, path) in by_batch.as_obj()? {
                inner.insert(
                    b.parse::<usize>().context("batch size key")?,
                    path.as_str()?.to_string(),
                );
            }
            hlo.insert(graph.clone(), inner);
        }

        let quick = v.opt("quick").map(|q| q.as_bool().unwrap_or(false)).unwrap_or(false);

        Ok(Manifest {
            root,
            model,
            batch_sizes,
            cache_batch,
            tasks,
            datasets,
            hlo,
            quick,
        })
    }

    /// Absolute path of an HLO artifact.
    pub fn hlo_path(&self, graph: &str, batch: usize) -> Result<PathBuf> {
        let by_batch = self
            .hlo
            .get(graph)
            .with_context(|| format!("manifest has no graph {graph:?}"))?;
        let rel = by_batch
            .get(&batch)
            .with_context(|| format!("graph {graph:?} not compiled for batch {batch}"))?;
        Ok(self.root.join(rel))
    }

    /// Absolute path of a weights file.
    pub fn weights_path(&self, task: &str, style: &str) -> Result<PathBuf> {
        let t = self.task(task)?;
        let rel = t
            .weights
            .get(style)
            .with_context(|| format!("task {task:?} has no style {style:?}"))?;
        Ok(self.root.join(rel))
    }

    pub fn task(&self, name: &str) -> Result<&TaskInfo> {
        self.tasks
            .get(name)
            .with_context(|| format!("unknown task {name:?}"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetInfo> {
        self.datasets
            .get(name)
            .with_context(|| format!("unknown dataset {name:?}"))
    }

    /// The source task of an eval dataset (e.g. imdb -> sst2).
    pub fn source_task(&self, dataset: &str) -> Result<&TaskInfo> {
        let d = self.dataset(dataset)?;
        let src = d
            .source
            .as_ref()
            .with_context(|| format!("dataset {dataset:?} has no source task"))?;
        self.task(src)
    }

    /// All eval dataset names in canonical (paper) order.
    pub fn eval_datasets(&self) -> Vec<String> {
        // Paper order: IMDb, Yelp, SciTail, SNLI, QQP.
        let paper_order = ["imdb", "yelp", "scitail", "snli", "qqp"];
        let mut out: Vec<String> = paper_order
            .iter()
            .filter(|n| self.datasets.contains_key(**n))
            .map(|n| n.to_string())
            .collect();
        // anything else (custom datasets), alphabetically after
        for (name, d) in &self.datasets {
            if d.role == "eval" && !out.contains(name) {
                out.push(name.clone());
            }
        }
        out
    }
}

/// Runtime settings assembled from defaults + CLI flags.
#[derive(Debug, Clone)]
pub struct Settings {
    pub artifacts_dir: PathBuf,
    pub results_dir: PathBuf,
    /// compute backend: "auto" (pjrt when built + available, else
    /// reference), "reference", or "pjrt"
    pub backend: String,
    /// speculative edge continuation past the split: "on", "off" or "auto"
    /// (auto = on when the backend is decision-transparent and the host has
    /// spare parallelism; parsed into `coordinator::SpeculateMode`)
    pub speculate: String,
    /// uplink scenario: `static`, `markov`, `markov:<seed>` or
    /// `trace:<path>` (parsed into `sim::link::LinkScenario`; dynamic
    /// scenarios vary bandwidth/latency/offload-cost per batch)
    pub link: String,
    /// split-boundary payload codec menu the bandit learns over:
    /// comma-joined `identity|f16|i8|topk:<k>|dedup:<inner>` names
    /// (parsed into `codec::CodecMenu`; `identity` alone reproduces the
    /// codec-less byte stream and decisions bit for bit)
    pub codecs: String,
    /// cloud-tier replica lanes (>= 1; parsed into
    /// `coordinator::ReplicaConfig`)
    pub replicas: usize,
    /// replica dispatch policy: "round-robin" or "least-loaded"
    pub dispatch: String,
    /// deterministic replica fault schedule: "" / "none", or
    /// `kill@<batch>:<replica>|slow@<batch>:<replica>x<factor>|`
    /// `flaky@<replica>:<p>` events joined by `|`, optionally with a
    /// trailing `,seed=<n>` (parsed into `sim::faults::FaultSchedule`)
    pub faults: String,
    /// TCP bind address for the `serve` front end ("" = in-process serving
    /// only; validated as a socket address at parse time)
    pub listen: String,
    /// durable-state snapshot path ("" = snapshots disabled; parsed with
    /// `--snapshot-every` into `persist::SnapshotConfig`)
    pub snapshot: String,
    /// write a snapshot every N batches (0 = only at graceful shutdown)
    pub snapshot_every: u64,
    /// reference-backend kernel-pool threads (`--ref-threads`; 0 = decide
    /// automatically: the `SPLITEE_REF_THREADS` env hook, else available
    /// parallelism; applied via [`Settings::configure_kernel_pool`])
    pub ref_threads: usize,
    /// cost-confidence conversion factor mu (paper: 0.1)
    pub mu: f64,
    /// UCB exploration parameter beta (paper: 1.0)
    pub beta: f64,
    /// offloading cost in lambda units (paper sweeps 1..5, table 2 uses 5)
    pub offload_cost: f64,
    /// experiment repetitions (paper: 20)
    pub reps: usize,
    pub seed: u64,
    pub verbosity: u8,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            backend: "auto".to_string(),
            speculate: "auto".to_string(),
            link: "static".to_string(),
            codecs: "identity".to_string(),
            replicas: 1,
            dispatch: "round-robin".to_string(),
            faults: String::new(),
            listen: String::new(),
            snapshot: String::new(),
            snapshot_every: 0,
            ref_threads: 0,
            mu: 0.1,
            beta: 1.0,
            offload_cost: 5.0,
            reps: 20,
            seed: 0xB0BA,
            verbosity: 1,
        }
    }
}

impl Settings {
    /// Apply CLI overrides.
    pub fn from_args(args: &Args) -> Result<Settings> {
        let mut s = Settings::default();
        if let Some(dir) = args.get("artifacts") {
            s.artifacts_dir = PathBuf::from(dir);
        }
        if let Some(dir) = args.get("results") {
            s.results_dir = PathBuf::from(dir);
        }
        if let Some(b) = args.get("backend") {
            s.backend = b.to_string();
        }
        if let Some(sp) = args.get("speculate") {
            s.speculate = sp.to_string();
        }
        if let Some(link) = args.get("link") {
            s.link = link.to_string();
        }
        if let Some(d) = args.get("dispatch") {
            s.dispatch = d.to_string();
        }
        if let Some(f) = args.get("faults") {
            s.faults = f.to_string();
        }
        if let Some(c) = args.get("codecs") {
            s.codecs = c.to_string();
        }
        // single source of truth for the accepted values (and the error
        // messages) are the coordinator's and the scenario engine's parsers;
        // a trace file is read eagerly here so a bad path fails at startup
        crate::coordinator::service::SpeculateMode::from_name(&s.speculate)?;
        crate::sim::link::LinkScenario::from_name(&s.link)?;
        crate::coordinator::replicas::DispatchPolicy::from_name(&s.dispatch)?;
        crate::sim::faults::FaultSchedule::from_name(&s.faults)?;
        crate::codec::CodecMenu::from_list(&s.codecs)?;
        s.replicas = args.get_num("replicas", s.replicas).map_err(anyhow::Error::msg)?;
        if s.replicas == 0 {
            bail!("--replicas must be a positive integer");
        }
        if let Some(addr) = args.get("listen") {
            s.listen = addr.to_string();
            // fail at startup like --link/--faults, not at bind time
            s.listen
                .parse::<std::net::SocketAddr>()
                .with_context(|| format!("--listen wants host:port, got {:?}", s.listen))?;
        }
        if let Some(p) = args.get("snapshot") {
            s.snapshot = p.to_string();
            if s.snapshot.is_empty() {
                bail!("--snapshot needs a file path");
            }
        }
        s.snapshot_every =
            args.get_num("snapshot-every", s.snapshot_every).map_err(anyhow::Error::msg)?;
        if s.snapshot_every > 0 && s.snapshot.is_empty() {
            bail!("--snapshot-every needs --snapshot <path>");
        }
        s.ref_threads = args.get_num("ref-threads", s.ref_threads).map_err(anyhow::Error::msg)?;
        if args.get("ref-threads").is_some() && s.ref_threads == 0 {
            bail!("--ref-threads must be a positive thread count");
        }
        s.mu = args.get_num("mu", s.mu).map_err(anyhow::Error::msg)?;
        s.beta = args.get_num("beta", s.beta).map_err(anyhow::Error::msg)?;
        s.offload_cost = args.get_num("o", s.offload_cost).map_err(anyhow::Error::msg)?;
        s.reps = args.get_num("reps", s.reps).map_err(anyhow::Error::msg)?;
        s.seed = args.get_num("seed", s.seed).map_err(anyhow::Error::msg)?;
        if args.has("quiet") {
            s.verbosity = 0;
        } else if args.has("debug") {
            s.verbosity = 2;
        }
        if s.mu < 0.0 {
            bail!("--mu must be non-negative, got {}", s.mu);
        }
        if s.reps == 0 {
            bail!("--reps must be positive");
        }
        Ok(s)
    }

    /// The cloud-tier replica-pool configuration these settings describe
    /// (`--replicas` / `--dispatch` / `--faults`; the retry/breaker knobs
    /// keep their defaults).  Values were validated by [`Settings::
    /// from_args`], but hand-built settings re-validate here.
    pub fn replica_config(&self) -> Result<crate::coordinator::ReplicaConfig> {
        Ok(crate::coordinator::ReplicaConfig {
            n: self.replicas.max(1),
            dispatch: crate::coordinator::replicas::DispatchPolicy::from_name(&self.dispatch)?,
            faults: crate::sim::faults::FaultSchedule::from_name(&self.faults)?,
            ..crate::coordinator::ReplicaConfig::default()
        })
    }

    /// The split-boundary codec menu these settings describe (`--codecs`).
    /// Validated by [`Settings::from_args`], but hand-built settings
    /// re-validate here.
    pub fn codec_menu(&self) -> Result<crate::codec::CodecMenu> {
        crate::codec::CodecMenu::from_list(&self.codecs)
    }

    /// Apply `--ref-threads` to the reference backend's shared kernel pool.
    /// Call once at startup, before the first model load — the pool's size
    /// freezes when it is first used.  A `ref_threads` of 0 leaves the
    /// automatic sizing (the `SPLITEE_REF_THREADS` env hook, else available
    /// parallelism) in effect.
    pub fn configure_kernel_pool(&self) {
        if self.ref_threads > 0 {
            crate::runtime::reference::set_kernel_threads(self.ref_threads);
        }
    }

    /// The durable-state snapshot destination these settings describe
    /// (`--snapshot` / `--snapshot-every`), falling back to the
    /// `SPLITEE_SNAPSHOT=<path>[@<every>]` environment hook when the flag
    /// is absent.  `None` = snapshots disabled.
    pub fn snapshot_config(&self) -> Option<crate::persist::SnapshotConfig> {
        if self.snapshot.is_empty() {
            return crate::persist::SnapshotConfig::from_env();
        }
        Some(crate::persist::SnapshotConfig {
            path: PathBuf::from(&self.snapshot),
            every: self.snapshot_every,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> String {
        r#"{
 "batch_sizes": [1, 8],
 "cache_batch": 32,
 "datasets": {
  "imdb": {"file": "data/imdb.bin", "classes": 2, "samples": 100,
           "role": "eval", "family": "sentiment", "paper_name": "IMDb",
           "paper_samples": 25000, "source": "sst2"},
  "sst2": {"file": "data/sst2.bin", "classes": 2, "samples": 50,
           "role": "source", "family": "sentiment", "paper_name": "SST-2",
           "paper_samples": 68000}
 },
 "hlo": {"block": {"1": "hlo/block_b1.hlo.txt", "8": "hlo/block_b8.hlo.txt"}},
 "model": {"vocab": 1024, "seq_len": 32, "d_model": 64, "n_heads": 4,
           "d_ff": 128, "n_layers": 12},
 "quick": true,
 "tasks": {
  "sst2": {"classes": 2, "alpha": 0.86, "tau": 0.35,
           "weights": {"elasticbert": "weights/sst2_elasticbert.bin"},
           "val_acc_per_exit": [0.9, 0.95]}
 }
}"#
        .to_string()
    }

    #[test]
    fn parse_manifest() {
        let v = json::parse(&sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/a"), &v).unwrap();
        assert_eq!(m.model.n_layers, 12);
        assert_eq!(m.batch_sizes, vec![1, 8]);
        assert!(m.quick);
        assert_eq!(m.task("sst2").unwrap().alpha, 0.86);
        assert_eq!(m.dataset("imdb").unwrap().source.as_deref(), Some("sst2"));
        assert_eq!(m.source_task("imdb").unwrap().name, "sst2");
        assert_eq!(
            m.hlo_path("block", 8).unwrap(),
            PathBuf::from("/tmp/a/hlo/block_b8.hlo.txt")
        );
        assert!(m.hlo_path("block", 4).is_err());
        assert!(m.hlo_path("nope", 1).is_err());
        assert_eq!(m.eval_datasets(), vec!["imdb".to_string()]);
    }

    #[test]
    fn settings_defaults_match_paper() {
        let s = Settings::default();
        assert_eq!(s.mu, 0.1);
        assert_eq!(s.beta, 1.0);
        assert_eq!(s.offload_cost, 5.0);
        assert_eq!(s.reps, 20);
    }

    #[test]
    fn settings_overrides() {
        let args = Args::parse(
            ["x", "--mu", "0.2", "--reps", "5", "--o", "3"]
                .iter()
                .map(|s| s.to_string()),
        );
        let s = Settings::from_args(&args).unwrap();
        assert_eq!(s.mu, 0.2);
        assert_eq!(s.reps, 5);
        assert_eq!(s.offload_cost, 3.0);
        assert_eq!(s.backend, "auto", "backend defaults to auto");
        assert_eq!(s.speculate, "auto", "speculation defaults to auto");
        assert_eq!(s.link, "static", "link scenario defaults to static");
        let args = Args::parse(
            ["x", "--backend", "reference", "--speculate", "on", "--link", "markov:9"]
                .iter()
                .map(|s| s.to_string()),
        );
        let s = Settings::from_args(&args).unwrap();
        assert_eq!(s.backend, "reference");
        assert_eq!(s.speculate, "on");
        assert_eq!(s.link, "markov:9");
    }

    #[test]
    fn settings_replica_flags_parse_and_round_trip() {
        let s = Settings::from_args(&Args::parse(["x"].iter().map(|s| s.to_string()))).unwrap();
        assert_eq!((s.replicas, s.dispatch.as_str()), (1, "round-robin"));
        assert!(s.faults.is_empty());
        let cfg = s.replica_config().unwrap();
        assert_eq!(cfg.n, 1);
        assert!(cfg.faults.is_empty());

        let args = Args::parse(
            ["x", "--replicas", "3", "--dispatch", "least-loaded", "--faults",
             "kill@2:0|flaky@1:0.25,seed=7"]
                .iter()
                .map(|s| s.to_string()),
        );
        let s = Settings::from_args(&args).unwrap();
        let cfg = s.replica_config().unwrap();
        assert_eq!(cfg.n, 3);
        assert_eq!(
            cfg.dispatch,
            crate::coordinator::replicas::DispatchPolicy::LeastLoaded
        );
        assert_eq!(cfg.faults.name(), "kill@2:0|flaky@1:0.25,seed=7");
    }

    #[test]
    fn settings_codec_flags_parse_and_round_trip() {
        let s = Settings::from_args(&Args::parse(["x"].iter().map(|s| s.to_string()))).unwrap();
        assert_eq!(s.codecs, "identity", "default menu = bit-transparent identity");
        let menu = s.codec_menu().unwrap();
        assert_eq!(menu.names(), "identity");

        let args = Args::parse(
            ["x", "--codecs", "identity,f16,i8,topk:64,dedup:i8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let s = Settings::from_args(&args).unwrap();
        let menu = s.codec_menu().unwrap();
        assert_eq!(menu.len(), 5);
        assert_eq!(menu.names(), "identity,f16,i8,topk:64,dedup:i8");

        for bad in ["", "identity,", "gzip", "topk:0", "i8,i8", "dedup:dedup:i8"] {
            let args = Args::parse(["x", "--codecs", bad].iter().map(|s| s.to_string()));
            assert!(Settings::from_args(&args).is_err(), "accepted {bad:?}");
        }
        let args = Args::parse(["x", "--codecs", "gzip"].iter().map(|s| s.to_string()));
        let err = Settings::from_args(&args).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gzip") && msg.contains("identity"), "unhelpful error: {msg}");
    }

    #[test]
    fn settings_snapshot_flags_parse_and_validate() {
        let s = Settings::from_args(&Args::parse(["x"].iter().map(|s| s.to_string()))).unwrap();
        assert!(s.snapshot.is_empty());
        assert_eq!(s.snapshot_every, 0);

        let args = Args::parse(
            ["x", "--snapshot", "state.json", "--snapshot-every", "25"]
                .iter()
                .map(|s| s.to_string()),
        );
        let s = Settings::from_args(&args).unwrap();
        let cfg = s.snapshot_config().expect("snapshot configured");
        assert_eq!(cfg.path, PathBuf::from("state.json"));
        assert_eq!(cfg.every, 25);

        // --snapshot alone means write-on-shutdown only
        let args = Args::parse(["x", "--snapshot", "s.json"].iter().map(|s| s.to_string()));
        let cfg = Settings::from_args(&args).unwrap().snapshot_config().unwrap();
        assert_eq!(cfg.every, 0);

        // a cadence without a destination is a configuration error
        let args =
            Args::parse(["x", "--snapshot-every", "10"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
    }

    #[test]
    fn settings_ref_threads_parse_and_validate() {
        let s = Settings::from_args(&Args::parse(["x"].iter().map(|s| s.to_string()))).unwrap();
        assert_eq!(s.ref_threads, 0, "default = automatic kernel-pool sizing");
        let args = Args::parse(["x", "--ref-threads", "4"].iter().map(|s| s.to_string()));
        assert_eq!(Settings::from_args(&args).unwrap().ref_threads, 4);
        // an explicit zero is a configuration error, not silent auto
        let args = Args::parse(["x", "--ref-threads", "0"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
        let args = Args::parse(["x", "--ref-threads", "lots"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
    }

    #[test]
    fn settings_listen_parses_and_validates() {
        let s = Settings::from_args(&Args::parse(["x"].iter().map(|s| s.to_string()))).unwrap();
        assert!(s.listen.is_empty(), "default = no TCP front end");
        let args =
            Args::parse(["x", "--listen", "127.0.0.1:7070"].iter().map(|s| s.to_string()));
        assert_eq!(Settings::from_args(&args).unwrap().listen, "127.0.0.1:7070");
        for bad in ["localhost", "127.0.0.1", "no:such:port", ":-1"] {
            let args = Args::parse(["x", "--listen", bad].iter().map(|s| s.to_string()));
            assert!(Settings::from_args(&args).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn settings_rejects_bad_replica_flags() {
        for bad in [
            vec!["x", "--replicas", "0"],
            vec!["x", "--dispatch", "fastest"],
            vec!["x", "--faults", "explode@1:2"],
            vec!["x", "--faults", "flaky@0:1.5"],
        ] {
            let args = Args::parse(bad.iter().map(|s| s.to_string()));
            assert!(Settings::from_args(&args).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn settings_rejects_bad_values() {
        let args = Args::parse(["x", "--reps", "0"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
        let args = Args::parse(["x", "--mu", "-1"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
        let args = Args::parse(["x", "--speculate", "maybe"].iter().map(|s| s.to_string()));
        assert!(Settings::from_args(&args).is_err());
        let args = Args::parse(["x", "--link", "wobbly"].iter().map(|s| s.to_string()));
        let err = Settings::from_args(&args).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("wobbly") && msg.contains("static"), "unhelpful error: {msg}");
        // a trace scenario with a missing file fails at configuration time
        let args = Args::parse(
            ["x", "--link", "trace:/no/such/file.trace"].iter().map(|s| s.to_string()),
        );
        assert!(Settings::from_args(&args).is_err());
    }
}

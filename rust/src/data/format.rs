//! SPLD dataset reader (written by `python/compile/export.py`).
//!
//! Format (little-endian):
//!
//! ```text
//!     u32 magic = 0x53504C44 ("SPLD")    u32 version = 1
//!     u32 n_samples, u32 seq_len, u32 n_classes
//!     i32 tokens[n * seq_len]
//!     i32 labels[n]
//!     i32 difficulty[n]
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt};

use crate::tensor::TensorI32;

pub const DATA_MAGIC: u32 = 0x53504C44;
pub const FORMAT_VERSION: u32 = 1;

/// An evaluation or source dataset held in memory.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub seq_len: usize,
    pub n_classes: usize,
    /// [N, T] token ids
    pub tokens: TensorI32,
    /// gold labels — used only for *metrics*, never by the policies
    /// (the paper's setup is unsupervised)
    pub labels: Vec<i32>,
    /// difficulty-mixture index per sample (0=easy .. 4=flip2)
    pub difficulty: Vec<i32>,
}

impl Dataset {
    pub fn load(path: &Path, name: &str) -> Result<Dataset> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading dataset {path:?}"))?;
        let mut r = std::io::Cursor::new(&bytes);
        let magic = r.read_u32::<LittleEndian>().context("magic")?;
        if magic != DATA_MAGIC {
            bail!("{path:?}: bad magic {magic:#x} (expected SPLD)");
        }
        let version = r.read_u32::<LittleEndian>()?;
        if version != FORMAT_VERSION {
            bail!("{path:?}: unsupported version {version}");
        }
        let n = r.read_u32::<LittleEndian>()? as usize;
        let t = r.read_u32::<LittleEndian>()? as usize;
        let c = r.read_u32::<LittleEndian>()? as usize;
        let mut tokens = vec![0i32; n * t];
        r.read_i32_into::<LittleEndian>(&mut tokens)
            .context("tokens truncated")?;
        let mut labels = vec![0i32; n];
        r.read_i32_into::<LittleEndian>(&mut labels)
            .context("labels truncated")?;
        let mut difficulty = vec![0i32; n];
        r.read_i32_into::<LittleEndian>(&mut difficulty)
            .context("difficulty truncated")?;
        if (r.position() as usize) != bytes.len() {
            bail!(
                "{path:?}: {} trailing bytes",
                bytes.len() - r.position() as usize
            );
        }
        for &l in &labels {
            if l < 0 || l as usize >= c {
                bail!("{path:?}: label {l} out of range [0, {c})");
            }
        }
        Ok(Dataset {
            name: name.to_string(),
            seq_len: t,
            n_classes: c,
            tokens: TensorI32::new(vec![n, t], tokens).map_err(|e| anyhow::anyhow!(e))?,
            labels,
            difficulty,
        })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Tokens of one sample as a [1, T] tensor.
    pub fn sample_tokens(&self, i: usize) -> TensorI32 {
        self.tokens.slice_rows(i, i + 1).expect("sample index")
    }

    /// Tokens of a contiguous range as a [n, T] tensor.
    pub fn range_tokens(&self, lo: usize, hi: usize) -> TensorI32 {
        self.tokens.slice_rows(lo, hi).expect("range")
    }

    /// Gather rows by index (for shuffled batching).
    pub fn gather_tokens(&self, idx: &[usize]) -> TensorI32 {
        self.tokens.gather_rows(idx).expect("gather index")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use byteorder::WriteBytesExt;

    pub(crate) fn fake_dataset_bytes(n: usize, t: usize, c: usize) -> Vec<u8> {
        let mut f = Vec::new();
        f.write_u32::<LittleEndian>(DATA_MAGIC).unwrap();
        f.write_u32::<LittleEndian>(FORMAT_VERSION).unwrap();
        f.write_u32::<LittleEndian>(n as u32).unwrap();
        f.write_u32::<LittleEndian>(t as u32).unwrap();
        f.write_u32::<LittleEndian>(c as u32).unwrap();
        for i in 0..n * t {
            f.write_i32::<LittleEndian>((i % 100) as i32).unwrap();
        }
        for i in 0..n {
            f.write_i32::<LittleEndian>((i % c) as i32).unwrap();
        }
        for i in 0..n {
            f.write_i32::<LittleEndian>((i % 5) as i32).unwrap();
        }
        f
    }

    fn temp(bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!(
            "splitee_d_{}_{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn roundtrip() {
        let path = temp(&fake_dataset_bytes(10, 4, 3));
        let d = Dataset::load(&path, "test").unwrap();
        assert_eq!(d.len(), 10);
        assert_eq!(d.seq_len, 4);
        assert_eq!(d.n_classes, 3);
        assert_eq!(d.tokens.shape(), &[10, 4]);
        assert_eq!(d.labels[4], 1);
        assert_eq!(d.sample_tokens(2).shape(), &[1, 4]);
        assert_eq!(d.range_tokens(2, 5).shape(), &[3, 4]);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn gather_matches_slices() {
        let path = temp(&fake_dataset_bytes(6, 3, 2));
        let d = Dataset::load(&path, "test").unwrap();
        let g = d.gather_tokens(&[4, 0, 2]);
        assert_eq!(g.shape(), &[3, 3]);
        assert_eq!(g.slice_rows(0, 1).unwrap(), d.sample_tokens(4));
        assert_eq!(g.slice_rows(1, 2).unwrap(), d.sample_tokens(0));
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = fake_dataset_bytes(2, 2, 2);
        bytes[0] ^= 0xFF;
        let path = temp(&bytes);
        assert!(Dataset::load(&path, "x").is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let mut bytes = fake_dataset_bytes(4, 4, 2);
        bytes.truncate(bytes.len() - 3);
        let path = temp(&bytes);
        assert!(Dataset::load(&path, "x").is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_out_of_range_labels() {
        let n = 2;
        let t = 2;
        let mut f = Vec::new();
        f.write_u32::<LittleEndian>(DATA_MAGIC).unwrap();
        f.write_u32::<LittleEndian>(FORMAT_VERSION).unwrap();
        f.write_u32::<LittleEndian>(n).unwrap();
        f.write_u32::<LittleEndian>(t).unwrap();
        f.write_u32::<LittleEndian>(2).unwrap();
        for _ in 0..n * t {
            f.write_i32::<LittleEndian>(0).unwrap();
        }
        f.write_i32::<LittleEndian>(0).unwrap();
        f.write_i32::<LittleEndian>(5).unwrap(); // label out of range
        for _ in 0..n {
            f.write_i32::<LittleEndian>(0).unwrap();
        }
        let path = temp(&f);
        assert!(Dataset::load(&path, "x").is_err());
        std::fs::remove_file(path).unwrap();
    }
}

//! Rust-side synthetic *confidence profiles* for tests and benches that must
//! run without AOT artifacts (unit tests, property tests, policy benches).
//!
//! This does NOT replace the real model — it generates per-sample per-layer
//! (confidence, correctness) matrices with the same qualitative structure the
//! trained multi-exit encoder produces: confidence and accuracy grow with
//! depth, easy samples saturate early, a configurable share is confidently
//! wrong at shallow exits (the QQP anomaly).

use crate::util::rng::Rng;

/// Synthetic per-sample, per-layer exit observations.
#[derive(Debug, Clone)]
pub struct SynthProfile {
    pub n_layers: usize,
    /// [N][L] confidence in the prediction at each exit
    pub conf: Vec<Vec<f32>>,
    /// [N][L] whether the exit's prediction is correct
    pub correct: Vec<Vec<bool>>,
    /// [N] ground-truth difficulty class (0 easy, 1 medium, 2 hard, 3 trap)
    pub kind: Vec<u8>,
}

/// Mixture weights for the synthetic profile generator.
#[derive(Debug, Clone, Copy)]
pub struct SynthMix {
    pub easy: f64,
    pub medium: f64,
    pub hard: f64,
    /// "trap" samples: confidently wrong at shallow exits (QQP-like)
    pub trap: f64,
}

impl Default for SynthMix {
    fn default() -> Self {
        SynthMix { easy: 0.45, medium: 0.3, hard: 0.15, trap: 0.1 }
    }
}

/// Logistic saturation helper.
fn sat(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl SynthProfile {
    pub fn generate(n: usize, n_layers: usize, mix: SynthMix, rng: &mut Rng) -> SynthProfile {
        let weights = [mix.easy, mix.medium, mix.hard, mix.trap];
        let mut conf = Vec::with_capacity(n);
        let mut correct = Vec::with_capacity(n);
        let mut kind = Vec::with_capacity(n);
        for _ in 0..n {
            let k = rng.weighted(&weights) as u8;
            let (mut cs, mut os) = (Vec::with_capacity(n_layers), Vec::with_capacity(n_layers));
            // Depth at which this sample's signal is resolved.
            let resolve = match k {
                0 => 1.0 + rng.next_f64() * 2.0,       // easy: layer ~1-3
                1 => 3.0 + rng.next_f64() * 4.0,       // medium: layer ~3-7
                2 => 7.0 + rng.next_f64() * 6.0,       // hard: layer ~7-13
                _ => 5.0 + rng.next_f64() * 4.0,       // trap: resolved mid-deep
            };
            for l in 0..n_layers {
                let depth = (l + 1) as f64;
                let noise = rng.normal() * 0.04;
                let c = match k {
                    // confidence rises as depth crosses the resolve point
                    0 | 1 | 2 => 0.5 + 0.49 * sat(1.4 * (depth - resolve)) + noise,
                    // trap: *high* confidence early (wrong), dip, then correct
                    _ => {
                        if depth < resolve {
                            0.85 + noise
                        } else {
                            0.55 + 0.4 * sat(1.2 * (depth - resolve)) + noise
                        }
                    }
                };
                let c = c.clamp(0.5, 0.999) as f32;
                let p_correct = match k {
                    _ if k < 3 => sat(2.0 * (depth - resolve) + 1.0),
                    _ => {
                        if depth < resolve {
                            0.1 // confidently wrong
                        } else {
                            sat(1.5 * (depth - resolve) + 0.5)
                        }
                    }
                };
                cs.push(c);
                os.push(rng.chance(p_correct));
            }
            conf.push(cs);
            correct.push(os);
            kind.push(k);
        }
        SynthProfile { n_layers, conf, correct, kind }
    }

    pub fn len(&self) -> usize {
        self.kind.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kind.is_empty()
    }

    /// Accuracy at a fixed exit layer (0-based) across all samples.
    pub fn accuracy_at(&self, layer: usize) -> f64 {
        let hits = self.correct.iter().filter(|c| c[layer]).count();
        hits as f64 / self.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> SynthProfile {
        let mut rng = Rng::new(42);
        SynthProfile::generate(4000, 12, SynthMix::default(), &mut rng)
    }

    #[test]
    fn shapes() {
        let p = profile();
        assert_eq!(p.len(), 4000);
        assert_eq!(p.conf[0].len(), 12);
        assert_eq!(p.correct[0].len(), 12);
    }

    #[test]
    fn confidence_in_valid_range() {
        let p = profile();
        for cs in &p.conf {
            for &c in cs {
                assert!((0.5..=1.0).contains(&c));
            }
        }
    }

    #[test]
    fn accuracy_grows_with_depth() {
        let p = profile();
        let first = p.accuracy_at(0);
        let last = p.accuracy_at(11);
        assert!(last > first + 0.15, "first {first}, last {last}");
        assert!(last > 0.85, "deep accuracy {last}");
    }

    #[test]
    fn trap_samples_confidently_wrong_early() {
        let p = profile();
        let traps: Vec<usize> = (0..p.len()).filter(|&i| p.kind[i] == 3).collect();
        assert!(!traps.is_empty());
        let early_conf: f64 =
            traps.iter().map(|&i| p.conf[i][0] as f64).sum::<f64>() / traps.len() as f64;
        let early_acc: f64 = traps.iter().filter(|&&i| p.correct[i][0]).count() as f64
            / traps.len() as f64;
        assert!(early_conf > 0.75, "trap early confidence {early_conf}");
        assert!(early_acc < 0.3, "trap early accuracy {early_acc}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = SynthProfile::generate(100, 12, SynthMix::default(), &mut r1);
        let b = SynthProfile::generate(100, 12, SynthMix::default(), &mut r2);
        assert_eq!(a.conf, b.conf);
        assert_eq!(a.correct, b.correct);
    }
}

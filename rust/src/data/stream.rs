//! Online sample stream: the paper's setting feeds samples one by one, in a
//! random order that is reshuffled per repetition.

use super::Dataset;
use crate::util::rng::Rng;

/// A shuffled pass over a dataset, yielding sample indices online.
#[derive(Debug, Clone)]
pub struct SampleStream {
    order: Vec<usize>,
    pos: usize,
}

impl SampleStream {
    /// Shuffled stream over the whole dataset.
    pub fn shuffled(dataset: &Dataset, rng: &mut Rng) -> SampleStream {
        SampleStream { order: rng.permutation(dataset.len()), pos: 0 }
    }

    /// In-order stream (for deterministic tests).
    pub fn sequential(n: usize) -> SampleStream {
        SampleStream { order: (0..n).collect(), pos: 0 }
    }

    /// Stream over an explicit index set.
    pub fn from_order(order: Vec<usize>) -> SampleStream {
        SampleStream { order, pos: 0 }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn remaining(&self) -> usize {
        self.order.len() - self.pos
    }

    /// Peek the next `k` indices without consuming (for batching).
    pub fn peek(&self, k: usize) -> &[usize] {
        &self.order[self.pos..(self.pos + k).min(self.order.len())]
    }

    /// Consume `k` indices.
    pub fn take_n(&mut self, k: usize) -> &[usize] {
        let lo = self.pos;
        self.pos = (self.pos + k).min(self.order.len());
        &self.order[lo..self.pos]
    }
}

impl Iterator for SampleStream {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.pos < self.order.len() {
            let i = self.order[self.pos];
            self.pos += 1;
            Some(i)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_yields_in_order() {
        let s = SampleStream::sequential(5);
        assert_eq!(s.collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn shuffled_is_a_permutation_and_seed_dependent() {
        let mut rng1 = Rng::new(1);
        let mut rng2 = Rng::new(1);
        let mut rng3 = Rng::new(2);
        let d = fake_dataset(100);
        let a: Vec<_> = SampleStream::shuffled(&d, &mut rng1).collect();
        let b: Vec<_> = SampleStream::shuffled(&d, &mut rng2).collect();
        let c: Vec<_> = SampleStream::shuffled(&d, &mut rng3).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn peek_take_consume() {
        let mut s = SampleStream::sequential(6);
        assert_eq!(s.peek(3), &[0, 1, 2]);
        assert_eq!(s.take_n(2), &[0, 1]);
        assert_eq!(s.remaining(), 4);
        assert_eq!(s.take_n(10), &[2, 3, 4, 5]);
        assert_eq!(s.remaining(), 0);
        assert!(s.next().is_none());
    }

    fn fake_dataset(n: usize) -> Dataset {
        Dataset {
            name: "fake".into(),
            seq_len: 2,
            n_classes: 2,
            tokens: crate::tensor::TensorI32::zeros(vec![n, 2]),
            labels: vec![0; n],
            difficulty: vec![0; n],
        }
    }
}

//! Dataset substrate: binary readers for the AOT-exported corpora, online
//! sample streams, and a rust-side synthetic generator for tests that must
//! run without artifacts.

pub mod format;
pub mod stream;
pub mod synth;

pub use format::Dataset;
pub use stream::SampleStream;
